"""Many-task engine: completion, load balancing, stragglers, failures,
dataflow semantics (paper §III, Figs. 4/5, 12/13)."""
import random

import pytest
from hypothesis_compat import given, settings, st

from repro.core.dataflow import Dataflow
from repro.core.fabric import Fabric
from repro.core.manytask import EngineStats, ManyTaskEngine, Task


def test_all_tasks_complete_exactly_once():
    fab = Fabric(n_hosts=4)
    eng = ManyTaskEngine(fab, n_workers=8)
    stats = eng.run([Task(task_id=i, duration=1.0) for i in range(100)])
    done = [e.task_id for e in stats.events]
    assert sorted(set(done)) == list(range(100))


def test_makespan_scales_with_workers():
    fab = Fabric(n_hosts=20, ranks_per_host=16)
    r = random.Random(1)
    durations = [r.uniform(5, 160) for _ in range(720)]   # FF stage 1 (Fig 12)
    spans = {}
    for w in (40, 80, 160, 320):
        eng = ManyTaskEngine(fab, n_workers=w)
        st_ = eng.run([Task(task_id=i, duration=d)
                       for i, d in enumerate(durations)])
        spans[w] = st_.makespan
    assert spans[80] < spans[40]
    assert spans[160] < spans[80]
    assert spans[320] <= spans[160]
    # lower bound: total work / workers
    assert spans[320] >= sum(durations) / 320


def test_dependencies_respected():
    fab = Fabric(n_hosts=2)
    eng = ManyTaskEngine(fab, n_workers=4)
    tasks = [Task(task_id=0, duration=5.0),
             Task(task_id=1, duration=1.0, deps=(0,)),
             Task(task_id=2, duration=1.0, deps=(1,))]
    stats = eng.run(tasks)
    t = {e.task_id: (e.start, e.end) for e in stats.events}
    assert t[1][0] >= t[0][1]
    assert t[2][0] >= t[1][1]


def test_straggler_backup_tasks_win():
    fab = Fabric(n_hosts=8, ranks_per_host=16)
    eng = ManyTaskEngine(fab, n_workers=64, straggler_factor=0.08,
                         backup_threshold=1.5, seed=5)
    stats = eng.run([Task(task_id=i, duration=10.0) for i in range(400)])
    assert stats.backups_launched > 0
    assert stats.backups_won > 0
    # with backups the makespan stays near the no-straggler ideal
    assert stats.makespan < 400 * 10.0 / 64 * 3


def test_worker_failure_recovery():
    fab = Fabric(n_hosts=4, ranks_per_host=16)
    eng = ManyTaskEngine(fab, n_workers=16, failure_times={0: 5.0, 1: 12.0})
    stats = eng.run([Task(task_id=i, duration=3.0) for i in range(200)])
    assert stats.failures_recovered >= 1
    assert sorted({e.task_id for e in stats.events}) == list(range(200))


def test_locality_cache_hits():
    import numpy as np
    fab = Fabric(n_hosts=2, ranks_per_host=2)
    blob = np.ones(1 << 10, np.uint8)
    fab.fs.put("d/in.bin", blob)
    for h in fab.hosts:
        h.store.write("d/in.bin", blob, 0.0)
    eng = ManyTaskEngine(fab, n_workers=4)
    stats = eng.run([Task(task_id=i, duration=1.0, inputs=("d/in.bin",))
                     for i in range(8)])
    assert stats.cache_hits == 8
    assert stats.cache_misses == 0


def test_dataflow_mapreduce_no_barrier():
    """Fig. 4/5: merges become eligible before the map phase finishes."""
    fab = Fabric(n_hosts=4)
    df = Dataflow(fab)
    maps = df.foreach(lambda x: x, list(range(16)),
                      durations=[1.0 if i < 15 else 50.0 for i in range(16)])
    total = df.merge_pairwise(lambda a, b: a + b, maps, duration=0.5)
    stats = df.run(n_workers=4)
    assert total.result() == sum(range(16))
    events = {e.task_id: e for e in stats.events}
    slow_map_end = events[15].end
    merge_starts = [e.start for tid, e in events.items() if tid >= 16]
    assert min(merge_starts) < slow_map_end     # no stage barrier


@given(n_tasks=st.integers(1, 60), n_workers=st.integers(1, 16),
       seed=st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_makespan_bounds_property(n_tasks, n_workers, seed):
    """work/W <= makespan <= work (independent equal tasks)."""
    fab = Fabric(n_hosts=2, ranks_per_host=max(1, n_workers // 2))
    eng = ManyTaskEngine(fab, n_workers=n_workers, seed=seed)
    stats = eng.run([Task(task_id=i, duration=2.0) for i in range(n_tasks)])
    total = 2.0 * n_tasks
    assert stats.makespan >= total / n_workers - 1e-6
    assert stats.makespan <= total + 1e-6


def test_task_priority_dispatches_first_among_queued():
    """QoS classes in the task queue: with one worker, queued tasks
    dispatch highest-priority-first (stable FIFO among equals)."""
    fab = Fabric(n_hosts=2, ranks_per_host=1)
    eng = ManyTaskEngine(fab, n_workers=1)
    tasks = [Task(task_id=0, duration=1.0),                   # runs first
             Task(task_id=1, duration=1.0, priority=0),
             Task(task_id=2, duration=1.0, priority=5),
             Task(task_id=3, duration=1.0, priority=5),
             Task(task_id=4, duration=1.0, priority=1)]
    stats = eng.run(tasks)
    start = {e.task_id: e.start for e in stats.events}
    # while 0 runs, 1..4 queue: then 2, 3 (FIFO among the 5s), 4, 1
    assert start[2] < start[3] < start[4] < start[1]


def test_default_priority_keeps_fifo_dispatch():
    """All-default priorities must schedule exactly as before the knob
    existed: submission order on a single worker."""
    fab = Fabric(n_hosts=2, ranks_per_host=1)
    eng = ManyTaskEngine(fab, n_workers=1)
    stats = eng.run([Task(task_id=i, duration=1.0) for i in range(6)])
    starts = sorted(stats.events, key=lambda e: e.start)
    assert [e.task_id for e in starts] == list(range(6))

"""Cross-facility WAN ingest (`repro.core.wan`): parity anchor,
determinism, credit flow control, pub/sub fan-out, loss/jitter models."""
import json
from dataclasses import fields

import numpy as np
import pytest

from conftest import make_fabric

from repro.core.api import (ENGINES, BroadcastEntry, ServiceConfig,
                            StagingClient, StagingSpec, StreamConfig,
                            WanStreamConfig)
from repro.core.collectives import CollectivePlanner, LinkPartitionedError
from repro.core.events import CausalityError, EventLoop
from repro.core.fabric import BGQ, Fabric
from repro.core.faults import FaultEvent, FaultKind, FaultSchedule
from repro.core.streaming import DetectorSource, stage_stream
from repro.core.telemetry import Tracer, flight_recorder
from repro.core.topology import (TOPOLOGIES, WAN_BEAMLINE, LinkTier,
                                 Topology, resolve_topology)
from repro.core.wan import WanFanout, WanSession, stage_wan

FRAME = 1 << 12


def wan_fabric(n_files=6, n_hosts=8, **kw):
    kw.setdefault("size", FRAME)
    return make_fabric(n_hosts=n_hosts, n_files=n_files, **kw)


def assert_reports_equal(a, b, ignore=("mode",)):
    for f in fields(a):
        if f.name in ignore:
            continue
        assert getattr(a, f.name) == getattr(b, f.name), \
            f"{f.name}: {getattr(a, f.name)!r} != {getattr(b, f.name)!r}"


def assert_stores_equal(f1, f2, pins=True):
    for h1, h2 in zip(f1.hosts, f2.hosts):
        assert set(h1.store.data) == set(h2.store.data)
        for p in h1.store.data:
            assert np.array_equal(h1.store.data[p], h2.store.data[p])
        if pins:
            assert set(h1.store.pinned) == set(h2.store.pinned)


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------

def test_wan_beamline_registered_with_wan_ingest_tier():
    assert "wan_beamline" in TOPOLOGIES
    topo = resolve_topology("wan_beamline")
    assert topo is WAN_BEAMLINE
    assert topo.ingest_tier.name == "wan"
    # the whole pod is one rack: delivery collectives stay on the
    # cluster tier, only the ingest hop crosses the WAN
    assert topo.hosts_per_rack >= 4096
    assert topo.inter.latency > topo.intra.latency
    assert topo.inter.bw < topo.intra.bw


def test_wan_ingest_hop_pays_wan_latency():
    fab, paths = wan_fabric()
    rep, _ = stage_wan(fab, paths, topology="wan_beamline")
    planner = CollectivePlanner(WAN_BEAMLINE, fab.constants)
    one_hop = planner.plan_point_to_point(FRAME).time
    assert one_hop > 25e-3                         # latency-dominated
    assert rep.wan.wan_time == pytest.approx(len(paths) * one_hop)
    assert rep.tier_bytes["wan"] == rep.total_bytes


# ---------------------------------------------------------------------------
# the regression anchor: defaults are bit-exact vs stage_stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rate_hz", [None, 20.0])
def test_wan_defaults_byte_and_time_exact_vs_stage_stream(rate_hz):
    f1, paths = wan_fabric()
    f2, _ = wan_fabric()
    rs, ts = stage_stream(f1, paths, rate_hz=rate_hz)
    rw, tw = stage_wan(f2, paths, rate_hz=rate_hz)
    assert ts == tw
    assert_reports_equal(rs, rw)
    assert rw.mode == "wan" and rw.fs_bytes == 0
    assert_stores_equal(f1, f2, pins=False)
    # the WAN side confirms nothing was dropped, stalled or retried
    assert rw.wan.frames_dropped == 0
    assert rw.wan.retransmits == 0
    assert rw.wan.credit_stall_time == 0.0


def test_wan_client_path_parity_including_pins():
    f1, paths = wan_fabric()
    f2, _ = wan_fabric()
    spec = StagingSpec([BroadcastEntry(["d/*.bin"], pin=True)])
    r1 = StagingClient(f1).stage(spec, StreamConfig(rate_hz=20.0))
    r2 = StagingClient(f2).stage(spec, WanStreamConfig(rate_hz=20.0))
    assert r1.total_time == r2.total_time
    assert r2.engine == "wan"
    assert_stores_equal(f1, f2)


def test_wan_traced_run_matches_untraced_accounting():
    f1, paths = wan_fabric()
    f2, _ = wan_fabric()
    kw = dict(topology="wan_beamline", subscribers=2, consume_hz=10.0,
              loss_rate=0.3, loss_seed=3, jitter_seed=5, jitter_windows=4)
    r1, t1 = stage_wan(f1, paths, rate_hz=50.0, **kw)
    tracer = f2.attach_tracer(Tracer())
    r2, t2 = stage_wan(f2, paths, rate_hz=50.0, **kw)
    assert t1 == t2
    assert_reports_equal(r1, r2)
    names = {s.name for s in tracer.spans}
    assert "wan.pull" in names and "stage.wan" in names
    if r2.wan.retransmits:
        assert "wan.retransmit" in names
    assert "WAN" in flight_recorder(tracer)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def run_noisy(seed_pair=(7, 11)):
    fab, paths = wan_fabric()
    rep, t = stage_wan(fab, paths, rate_hz=50.0, topology="wan_beamline",
                       window_bytes=3 * FRAME, credit_window=3,
                       buffer_frames=4, subscribers=2, consume_hz=5.0,
                       loss_rate=0.25, loss_seed=seed_pair[0],
                       jitter_seed=seed_pair[1], jitter_windows=6)
    return rep, t


def test_seeded_wan_replays_bit_exactly():
    (r1, t1), (r2, t2) = run_noisy(), run_noisy()
    assert t1 == t2
    assert_reports_equal(r1, r2)
    for f in fields(r1.wan):
        if f.name == "stream":
            continue
        assert getattr(r1.wan, f.name) == getattr(r2.wan, f.name), f.name
    assert_reports_equal(r1.wan.stream, r2.wan.stream, ignore=())


def test_seeded_jitter_schedule_replays_bit_exactly():
    s1 = FaultSchedule.wan_jitter(42, 10.0, n_windows=5)
    s2 = FaultSchedule.wan_jitter(42, 10.0, n_windows=5)
    assert s1.events == s2.events
    assert len(s1.events) == 5
    for ev in s1.events:
        assert ev.kind is FaultKind.LINK_DEGRADE and ev.tier == "wan"
        assert 0.3 <= ev.factor <= 0.9
    assert FaultSchedule.wan_jitter(43, 10.0, n_windows=5).events != s1.events


def test_wan_jitter_rejects_partition_factors_and_bad_shapes():
    with pytest.raises(ValueError, match="partition"):
        FaultSchedule.wan_jitter(0, 10.0, factor_range=(0.0, 0.5))
    with pytest.raises(ValueError, match="horizon"):
        FaultSchedule.wan_jitter(0, 0.0)
    with pytest.raises(ValueError, match="n_windows"):
        FaultSchedule.wan_jitter(0, 10.0, n_windows=0)


def test_jitter_slows_delivery_but_moves_no_extra_bytes():
    fab, paths = wan_fabric(n_files=4, size=1 << 20)
    clean, _ = stage_wan(fab, paths, topology="wan_beamline")
    fab2, _ = wan_fabric(n_files=4, size=1 << 20)
    noisy, _ = stage_wan(fab2, paths, topology="wan_beamline",
                         jitter_seed=1, jitter_windows=16,
                         jitter_window_s=1.0, jitter_factors=(0.2, 0.5))
    assert noisy.wan.makespan > clean.wan.makespan
    assert noisy.tier_bytes["wan"] == clean.tier_bytes["wan"]


def test_jitter_composes_with_fabric_fault_schedule():
    fab, paths = wan_fabric()
    # a brownout the fabric already carries must not be REPLACED by the
    # jitter overlay: with both active the stage is slower than with
    # jitter alone
    fab.faults = FaultSchedule([FaultEvent(
        0.0, FaultKind.LINK_DEGRADE, tier="wan", t_end=999.0, factor=0.1)])
    both, _ = stage_wan(fab, paths, topology="wan_beamline",
                        jitter_seed=1, jitter_windows=4)
    fab2, _ = wan_fabric()
    jitter_only, _ = stage_wan(fab2, paths, topology="wan_beamline",
                               jitter_seed=1, jitter_windows=4)
    assert both.wan.makespan > jitter_only.wan.makespan


# ---------------------------------------------------------------------------
# pull-based credit flow control
# ---------------------------------------------------------------------------

def test_credit_window_stalls_producer_without_dropping():
    fab, paths = wan_fabric()
    rep, _ = stage_wan(fab, paths, rate_hz=200.0, topology="wan_beamline",
                       window_bytes=3 * FRAME, credit_window=2,
                       subscribers=1, consume_hz=4.0)
    wan = rep.wan
    assert wan.frames_delivered == len(paths)
    assert wan.frames_dropped == 0               # unbounded DAQ buffer
    assert wan.credit_stall_time > 0.0           # credits did bind
    assert wan.credits_granted == len(paths)
    assert wan.buffer_peak > 1


def test_bounded_buffer_drops_oldest_and_accounts_every_frame():
    fab, paths = wan_fabric(n_files=12)
    rep, _ = stage_wan(fab, paths, rate_hz=500.0, topology="wan_beamline",
                       window_bytes=3 * FRAME, credit_window=2,
                       buffer_frames=2, subscribers=1, consume_hz=2.0)
    wan = rep.wan
    assert wan.frames_dropped > 0
    assert wan.frames_delivered + wan.frames_dropped == wan.n_frames
    assert rep.n_chunks == wan.frames_delivered
    assert rep.total_bytes == wan.frames_delivered * FRAME
    # drop-oldest: the LAST frame always survives (freshest data wins)
    fab_hosts = fab.hosts
    assert paths[-1] in fab_hosts[0].store.data


def test_flow_control_never_wedges_under_jitter_sweep():
    for seed in range(5):
        fab, paths = wan_fabric(n_files=10)
        rep, _ = stage_wan(fab, paths, rate_hz=300.0,
                           topology="wan_beamline",
                           window_bytes=4 * FRAME, credit_window=3,
                           buffer_frames=4, subscribers=2, consume_hz=8.0,
                           loss_rate=0.2, loss_seed=seed,
                           jitter_seed=seed, jitter_windows=6,
                           jitter_factors=(0.2, 0.6))
        wan = rep.wan
        assert wan.frames_delivered + wan.frames_dropped == wan.n_frames
        assert wan.frames_delivered > 0


def test_credit_window_validated_against_node_window():
    fab, paths = wan_fabric()
    with pytest.raises(ValueError, match="credit_window"):
        stage_wan(fab, paths, window_bytes=2 * FRAME, credit_window=8)


def test_wedge_guard_counts_pinned_bytes():
    fab, paths = wan_fabric()
    with pytest.raises(ValueError, match="pinned"):
        stage_wan(fab, paths, window_bytes=3 * FRAME, credit_window=2,
                  pin_paths=paths[:2])


# ---------------------------------------------------------------------------
# pub/sub fan-out + watermark retention
# ---------------------------------------------------------------------------

def test_fanout_crosses_wan_once_regardless_of_subscribers():
    per_n = {}
    for n in (1, 2, 4):
        fab, paths = wan_fabric()
        rep, _ = stage_wan(fab, paths, topology="wan_beamline",
                           subscribers=n, consume_hz=50.0)
        per_n[n] = rep.tier_bytes["wan"]
    assert per_n[1] == per_n[2] == per_n[4] == len(paths) * FRAME


def test_slowest_subscriber_governs_watermark_and_lag():
    fab, paths = wan_fabric(n_files=8)
    rep, _ = stage_wan(fab, paths, rate_hz=100.0, topology="wan_beamline",
                       window_bytes=3 * FRAME, credit_window=2,
                       subscribers=["fast", "slow"],
                       consume_hz=(100.0, 2.0))
    srep = rep.wan.stream
    assert srep.consumer_lag["slow"] > srep.consumer_lag["fast"]
    assert srep.watermark_lag > 0.0          # slow consumer held frames
    assert srep.watermark_frame == len(paths) - 1   # all fully released
    # the slow subscriber's acks gate the credits: stalls reflect it
    assert rep.wan.credit_stall_time > 0.0


def test_single_consumer_stream_report_defaults_stay_empty():
    fab, paths = wan_fabric()
    rep, _ = stage_stream(fab, paths)
    assert rep is not None
    fab2, paths2 = wan_fabric()
    from repro.core.streaming import StreamStager
    stager = StreamStager(fab2, window_bytes=len(paths2) * FRAME)
    for _, p, buf, t in DetectorSource.replay_fs(fab2, paths2):
        stager.ingest(p, buf, t)
    srep = stager.finish()
    assert srep.consumer_lag == {}
    assert srep.watermark_frame == -1
    assert srep.watermark_lag == 0.0


# ---------------------------------------------------------------------------
# loss / retransmission
# ---------------------------------------------------------------------------

def test_seeded_loss_retransmits_cost_time_and_wan_bytes():
    fab, paths = wan_fabric(n_files=12)
    clean, _ = stage_wan(fab, paths, topology="wan_beamline")
    fab2, _ = wan_fabric(n_files=12)
    lossy, _ = stage_wan(fab2, paths, topology="wan_beamline",
                         loss_rate=0.5, loss_seed=0)
    assert lossy.wan.retransmits > 0
    assert lossy.wan.wan_bytes == (
        clean.wan.wan_bytes + lossy.wan.retransmits * FRAME)
    assert lossy.tier_bytes["wan"] == lossy.wan.wan_bytes
    assert lossy.wan.wan_time > clean.wan.wan_time
    # the local fan-out still delivers every frame byte-exactly
    assert lossy.n_chunks == len(paths)


def test_zero_loss_draws_nothing_from_the_rng():
    fab, _ = wan_fabric()
    stager = WanFanout(fab, window_bytes=1 << 20, loss_rate=0.0,
                       loss_seed=123)
    state0 = stager._loss_rng.bit_generator.state
    stager._pull_time(FRAME, 0.0)
    assert stager._loss_rng.bit_generator.state == state0


def test_wan_fanout_rejects_certain_loss():
    fab, _ = wan_fabric()
    with pytest.raises(ValueError, match="loss_rate"):
        WanFanout(fab, window_bytes=1 << 20, loss_rate=1.0)


# ---------------------------------------------------------------------------
# point-to-point plans under degradation (satellite: partition coverage)
# ---------------------------------------------------------------------------

def test_point_to_point_partitioned_at_tier_factor_zero():
    dead = Topology(name="dead", hosts_per_rack=8,
                    intra=LinkTier("optical", bw=1e9, latency=1e-6,
                                   scale=0.0))
    planner = CollectivePlanner(dead, BGQ)
    with pytest.raises(LinkPartitionedError, match="partitioned"):
        planner.plan_point_to_point(FRAME)


def test_point_to_point_partitioned_via_degraded_and_fault_schedule():
    with pytest.raises(LinkPartitionedError):
        CollectivePlanner(WAN_BEAMLINE.degraded({"wan": 0.0}),
                          BGQ).plan_point_to_point(FRAME)
    fab, paths = wan_fabric(topology="wan_beamline")
    fab.faults = FaultSchedule([FaultEvent(
        0.0, FaultKind.LINK_DEGRADE, tier="wan", t_end=99.0, factor=0.0)])
    with pytest.raises(LinkPartitionedError):
        fab.net.point_to_point_time(FRAME, t=1.0)
    with pytest.raises(LinkPartitionedError):
        stage_wan(fab, paths, topology="wan_beamline")


def test_point_to_point_attempts_scale_time_and_bytes():
    planner = CollectivePlanner(WAN_BEAMLINE, BGQ)
    one = planner.plan_point_to_point(FRAME)
    three = planner.plan_point_to_point(FRAME, attempts=3)
    assert one.algorithm == "direct"
    assert three.algorithm == "retransmit"
    assert three.time == pytest.approx(3 * one.time)
    assert three.tier_bytes["wan"] == 3 * one.tier_bytes["wan"]
    with pytest.raises(ValueError, match="attempts"):
        planner.plan_point_to_point(FRAME, attempts=0)
    with pytest.raises(ValueError, match="nbytes"):
        planner.plan_point_to_point(-1)


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_wan_config_registered_and_distinct_from_stream():
    assert "wan" in ENGINES
    assert ENGINES.name_of(WanStreamConfig()) == "wan"
    assert ENGINES.name_of(StreamConfig()) == "stream"
    assert not ENGINES.entry("wan").batch


def test_wan_config_validation():
    with pytest.raises(ValueError, match="subscribers"):
        WanStreamConfig(subscribers=0)
    with pytest.raises(ValueError, match="loss_rate"):
        WanStreamConfig(loss_rate=1.0)
    with pytest.raises(ValueError, match="credit_window"):
        WanStreamConfig(credit_window=0)
    with pytest.raises(ValueError, match="buffer_frames"):
        WanStreamConfig(buffer_frames=0)
    with pytest.raises(ValueError, match="consume_hz"):
        WanStreamConfig(subscribers=2, consume_hz=(1.0,))
    with pytest.raises(ValueError, match="jitter_factors"):
        WanStreamConfig(jitter_factors=(0.0, 0.5))
    with pytest.raises(ValueError, match="jitter_window_s"):
        WanStreamConfig(jitter_window_s=0.0)
    cfg = WanStreamConfig(subscribers=2, consume_hz=[4.0, 2.0],
                          jitter_factors=[0.4, 0.8])
    assert cfg.consume_hz == (4.0, 2.0)
    assert cfg.jitter_factors == (0.4, 0.8)


def test_wan_spec_json_round_trip():
    spec = StagingSpec([BroadcastEntry(["d/*.bin"], pin=True)],
                       config=WanStreamConfig(
                           topology="wan_beamline", subscribers=3,
                           consume_hz=(8.0, 4.0, 2.0), credit_window=4,
                           loss_rate=0.1, jitter_seed=9,
                           jitter_windows=5))
    again = StagingSpec.from_json(spec.to_json())
    assert again.config == spec.config
    assert isinstance(again.config, WanStreamConfig)
    parsed = json.loads(spec.to_json())
    assert parsed["engine"]["name"] == "wan"


def test_service_config_rejects_wan_engine():
    with pytest.raises(ValueError, match="batch"):
        ServiceConfig(budget_bytes=1 << 20, engine=WanStreamConfig())


# ---------------------------------------------------------------------------
# event-loop surface grown for the session
# ---------------------------------------------------------------------------

def test_schedule_after_fires_relative_to_now():
    loop = EventLoop(t0=5.0)
    seen = []
    loop.schedule_after(1.0, lambda: seen.append(loop.now))
    loop.run()
    assert seen == [6.0]
    with pytest.raises(CausalityError):
        loop.schedule_after(-0.1, lambda: None)


def test_wan_session_runs_on_a_shared_event_loop():
    fab, paths = wan_fabric()
    loop = EventLoop(t0=0.0)
    src = DetectorSource.replay_fs(fab, paths, rate_hz=20.0)
    session = WanSession(fab, src, subscribers=2, consume_hz=10.0,
                         topology="wan_beamline", loop=loop)
    rep = session.run()
    assert rep.frames_delivered == len(paths)
    assert loop.now == rep.drain_makespan
    keys = {ev.key for ev in loop.history}
    assert "wan.detector" in keys
    assert "wan.sub.sub0" in keys and "wan.sub.sub1" in keys

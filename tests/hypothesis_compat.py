"""Optional-``hypothesis`` shim: property tests skip cleanly when the
dependency is absent (the container does not ship it; see
requirements-dev.txt to enable the full property suite)."""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub: strategy constructors only feed @given, which skips."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

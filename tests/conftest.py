import os
import sys

# Tests run on the default single CPU device (the dry-run alone uses 512
# placeholder devices — set ONLY inside launch/dryrun.py, never globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

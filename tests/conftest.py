import os
import sys

import numpy as np
import pytest

# Tests run on the default single CPU device (the dry-run alone uses 512
# placeholder devices — set ONLY inside launch/dryrun.py, never globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# shared scenario builders (hoisted from test_staging / test_datasvc /
# test_faults / test_topology, which each carried a copy-pasted variant).
# Import them with `from conftest import ...`; the fixtures below wrap the
# common default shapes for tests that just need "a fabric" or "a service".
# ---------------------------------------------------------------------------

def make_fabric(n_hosts=8, n_files=4, size=1 << 16, seed=0, topology=None,
                prefix="d", **kw):
    """A BGQ-calibrated fabric with `n_files` random files of `size` bytes
    installed at ``{prefix}/f{i}.bin``. Returns ``(fabric, paths)``.
    Extra keywords (``faults=``, ``ranks_per_host=``...) pass through to
    :class:`repro.core.fabric.Fabric`."""
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=n_hosts, constants=BGQ, topology=topology, **kw)
    rng = np.random.default_rng(seed)
    paths = []
    for i in range(n_files):
        p = f"{prefix}/f{i}.bin"
        fab.fs.put(p, rng.integers(0, 255, size, dtype=np.uint8))
        paths.append(p)
    return fab, paths


def make_service(n_hosts=8, sizes=(4, 4, 4), file_bytes=1 << 12,
                 budget_files=8, seed=0, **service_kw):
    """A fabric with datasets d0..dN of `sizes[i]` files each, registered
    on a service whose budget holds `budget_files` files. Returns
    ``(fabric, service)``; extra keywords pass through to
    :class:`repro.core.datasvc.StagingService`."""
    from repro.core.datasvc import StagingService
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    rng = np.random.default_rng(seed)
    svc = StagingService(fab, budget_bytes=budget_files * file_bytes,
                         **service_kw)
    for d, n_files in enumerate(sizes):
        paths = []
        for i in range(n_files):
            p = f"d{d}/f{i}.bin"
            fab.fs.put(p, rng.integers(0, 255, file_bytes, dtype=np.uint8))
            paths.append(p)
        svc.register(f"d{d}", paths=paths)
    return fab, svc


@pytest.fixture
def small_fabric():
    """Default 8-host fabric with 4 x 64 KiB files: ``(fabric, paths)``."""
    return make_fabric()


@pytest.fixture
def service8():
    """Default 8-host service with three 4-file datasets under an
    8-file budget: ``(fabric, service)``."""
    return make_service()

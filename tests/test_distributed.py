"""Device-level distribution tests (run in a subprocess with 8 fake devices
so the main pytest process keeps the default single device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_device_replicate_and_staged_restore():
    out = run_with_devices(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.staging import device_replicate, staged_restore
        from repro.core.compat import make_auto_mesh
        mesh = make_auto_mesh((4, 2), ("data", "model"))
        x = np.arange(64 * 8, dtype=np.float32).reshape(64, 8)
        xs = jax.device_put(x, NamedSharding(mesh, P("data")))
        rep = device_replicate(mesh, xs, "data")
        assert np.array_equal(np.asarray(rep), x)
        shards = {i: x[i * 16:(i + 1) * 16] for i in range(4)}
        r2 = staged_restore(mesh, shards, "data")
        assert np.array_equal(np.asarray(r2), x)
        print("OK")
    """))
    assert "OK" in out


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    out = run_with_devices(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ShapeConfig
        from repro.configs.registry import get_smoke_config
        from repro.distributed.sharding import (make_ctx, param_pspecs,
                                                input_pspecs)
        from repro.launch.mesh import make_mesh
        from repro.train.optimizer import OptConfig
        from repro.train.train_step import init_train_state, make_train_step

        cfg = get_smoke_config("qwen3_32b")
        opt = OptConfig(total_steps=10, warmup_steps=2)
        shape = ShapeConfig("s", "train", 32, 4, 1, True)
        batch = {"tokens": jnp.ones((4, 32), jnp.int32),
                 "labels": jnp.ones((4, 32), jnp.int32)}
        # single device reference
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step = jax.jit(make_train_step(cfg, shape, opt))
        _, _, m_ref = step(params, opt_state, batch)
        # sharded over (2,4) mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = make_ctx(mesh)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        pspecs = param_pspecs(cfg, params, ctx)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(jax.device_put, params, sh)
        step2 = jax.jit(make_train_step(cfg, shape, opt, ctx=ctx))
        _, _, m = step2(params, opt_state, batch)
        assert abs(float(m["loss"]) - float(m_ref["loss"])) < 1e-2, \\
            (float(m["loss"]), float(m_ref["loss"]))
        print("OK", float(m["loss"]))
    """))
    assert "OK" in out


def _partial_manual_shard_map_supported() -> bool:
    """Partial-manual shard_map (manual 'pod', auto data/model) crashes XLA's
    SPMD partitioner on jax 0.4.x (Check failed: sharding.IsManualSubgroup());
    it needs the jax>=0.6 axis_names API generation."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.core.compat import _NEW_API
    return _NEW_API


@pytest.mark.skipif(not _partial_manual_shard_map_supported(),
                    reason="partial-manual shard_map unsupported by this "
                           "jax/XLA (crashes the SPMD partitioner)")
def test_compressed_dcn_train_step_on_pod_mesh():
    out = run_with_devices(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ShapeConfig
        from repro.configs.registry import get_smoke_config
        from repro.distributed.sharding import make_ctx, param_pspecs
        from repro.launch.mesh import make_mesh
        from repro.train.optimizer import OptConfig
        from repro.train.train_step import init_train_state, make_train_step

        cfg = get_smoke_config("internlm2_20b")
        opt = OptConfig(total_steps=10, warmup_steps=2)
        shape = ShapeConfig("s", "train", 16, 4, 1, True)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        ctx = make_ctx(mesh)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt,
                                             compress_dcn=True)
        step = jax.jit(make_train_step(cfg, shape, opt, ctx=ctx,
                                       compress_dcn=True))
        batch = {"tokens": jnp.ones((16, 16), jnp.int32),
                 "labels": jnp.ones((16, 16), jnp.int32)}
        p, o, m = step(params, opt_state, batch)
        assert jnp.isfinite(m["loss"])
        print("OK", float(m["loss"]))
    """))
    assert "OK" in out


def test_elastic_reshard_checkpoint_across_meshes():
    out = run_with_devices(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.store import CheckpointStore
        from repro.launch.mesh import make_mesh
        tree = {"w": np.arange(64 * 16, dtype=np.float32).reshape(64, 16)}
        with tempfile.TemporaryDirectory() as d:
            store = CheckpointStore(d)
            store.save(1, tree)
            mesh8 = make_mesh((8,), ("data",))
            specs = {"w": P("data")}
            back = store.restore_resharded(tree, mesh8, specs)
            assert np.array_equal(np.asarray(back["w"]), tree["w"])
            mesh2 = make_mesh((2,), ("data",))
            back2 = store.restore_resharded(tree, mesh2, specs)
            assert np.array_equal(np.asarray(back2["w"]), tree["w"])
        print("OK")
    """))
    assert "OK" in out

"""Checkpointing: roundtrip exactness, async, resharded restore, driver
restart/rescale recovery."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointError, CheckpointStore
from repro.runtime.driver import HeartbeatMonitor, TrainDriver

key = jax.random.PRNGKey(0)


def make_tree():
    return {
        "w": jax.random.normal(key, (64, 32), jnp.float32),
        "emb": {"table": jax.random.normal(key, (100, 16)).astype(jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_exact(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = make_tree()
    store.save(3, tree, n_shards=4)
    back = store.restore(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_latest_and_multiple_steps(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = make_tree()
    store.save(1, t)
    store.save(5, t)
    assert store.latest_step() == 5


def test_async_save(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = make_tree()
    store.save_async(9, t)
    store.wait()
    back = store.restore(t)
    assert np.array_equal(np.asarray(back["w"]), np.asarray(t["w"]))


def test_driver_restart_from_failure(tmp_path):
    """Node failure at step 7 -> restart resumes from checkpoint 5 and still
    reaches the target step count."""
    store = CheckpointStore(str(tmp_path))

    def build_step(mesh_spec):
        state = {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}

        def step_fn(s):
            s = {"x": s["x"] + 1.0, "step": s["step"] + 1}
            return s, {"loss": 1.0 / (1.0 + float(s["x"]))}
        return step_fn, state

    driver = TrainDriver(store, build_step, checkpoint_every=5,
                         failure_schedule={7: "fail"})
    report = driver.run(total_steps=10, mesh_spec={})
    assert report.restarts == 1
    assert report.checkpoints[-1] == 10
    final = store.restore({"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)})
    assert float(final["x"]) == 10.0


def test_driver_elastic_rescale(tmp_path):
    store = CheckpointStore(str(tmp_path))
    seen_meshes = []

    def build_step(mesh_spec):
        seen_meshes.append(dict(mesh_spec))
        state = {"x": jnp.zeros(())}

        def step_fn(s):
            return {"x": s["x"] + 1.0}, {"loss": 0.0}
        return step_fn, state

    driver = TrainDriver(store, build_step, checkpoint_every=4,
                         failure_schedule={6: "rescale"})
    report = driver.run(total_steps=8, mesh_spec={"n_devices": 8})
    assert report.rescales == 1
    assert seen_meshes[-1]["n_devices"] == 4      # shrunk after rescale


def test_heartbeat_monitor():
    mon = HeartbeatMonitor(n_workers=4, timeout=5.0)
    for w in range(4):
        mon.beat(w, 0.0)
    mon.beat(0, 8.0)
    assert set(mon.dead_workers(9.0)) == {1, 2, 3}


# ---------------------------------------------------------------------------
# restore hardening: damaged checkpoints fail loudly, naming the bad object
# ---------------------------------------------------------------------------

def test_restore_missing_shard_names_the_shard(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = make_tree()
    store.save(3, tree, n_shards=4)
    os.remove(os.path.join(str(tmp_path), "step_00000003", "w.shard2.npy"))
    with pytest.raises(CheckpointError, match=r"w\.shard2\.npy"):
        store.restore(tree)


def test_restore_truncated_shard_names_the_shard(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = make_tree()
    store.save(3, tree, n_shards=4)
    bad = os.path.join(str(tmp_path), "step_00000003",
                       "emb__table.shard1.npy")
    with open(bad, "r+b") as f:
        f.truncate(12)                       # mid-header: unreadable
    with pytest.raises(CheckpointError,
                       match=r"emb__table\.shard1\.npy.*unreadable"):
        store.restore(tree)


def test_restore_missing_full_object_names_it(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = make_tree()
    store.save(3, tree, n_shards=4)          # scalar "step" saves full
    os.remove(os.path.join(str(tmp_path), "step_00000003", "step.full.npy"))
    with pytest.raises(CheckpointError, match=r"step\.full\.npy"):
        store.restore(tree)


def test_restore_truncated_full_object_names_it(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = make_tree()
    store.save(3, tree, n_shards=4)
    bad = os.path.join(str(tmp_path), "step_00000003", "step.full.npy")
    with open(bad, "r+b") as f:
        f.truncate(4)
    with pytest.raises(CheckpointError,
                       match=r"step\.full\.npy.*unreadable"):
        store.restore(tree)


def test_restore_missing_manifest_is_loud(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(3, make_tree(), n_shards=4)
    os.remove(os.path.join(str(tmp_path), "step_00000003", "meta.json"))
    with pytest.raises(CheckpointError, match="manifest"):
        store.restore(make_tree())

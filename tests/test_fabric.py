"""Fabric primitives: node-local store eviction/pinning, batched striped
reads, bulk replica delivery."""
import numpy as np
import pytest

from repro.core.fabric import BGQ, Fabric, NodeLocalStore
from repro.core.staging import _stripes


def make_store():
    return NodeLocalStore(host_id=0, constants=BGQ)


def test_evict_lru_respects_budget_and_order():
    store = make_store()
    for i in range(4):
        store.write(f"f{i}", np.ones(100, np.uint8), 0.0)
    store.evict_lru(250)
    # insertion order ~ LRU: oldest unpinned entries dropped first
    assert set(store.data) == {"f2", "f3"}


def test_evict_lru_never_drops_pinned():
    store = make_store()
    for i in range(4):
        store.write(f"f{i}", np.ones(100, np.uint8), 0.0)
    store.pin("f0")
    store.pin("f1")
    store.evict_lru(250)
    assert "f0" in store.data and "f1" in store.data
    assert "f2" not in store.data            # oldest unpinned went first
    # pinned entries survive even when they alone exceed the budget
    store2 = make_store()
    store2.write("keep", np.ones(500, np.uint8), 0.0)
    store2.pin("keep")
    store2.write("drop", np.ones(100, np.uint8), 0.0)
    store2.evict_lru(50)
    assert set(store2.data) == {"keep"}


def test_evict_lru_noop_under_budget():
    store = make_store()
    store.write("a", np.ones(10, np.uint8), 0.0)
    store.evict_lru(1000)
    assert "a" in store.data


def test_evict_lru_read_hit_refreshes_recency():
    """True LRU, not FIFO: a read hit promotes the entry to most-recently
    used, so a hot-but-old entry outlives a colder, newer one."""
    store = make_store()
    for i in range(4):
        store.write(f"f{i}", np.ones(100, np.uint8), 0.0)
    assert store.read("f0") is not None      # touch the oldest entry
    store.evict_lru(250)
    # f1 is now coldest and goes first; the touched f0 survives
    assert set(store.data) == {"f0", "f3"}
    # a miss must not perturb recency
    store.write("f4", np.ones(100, np.uint8), 0.0)
    assert store.read("nope") is None
    store.evict_lru(250)
    assert set(store.data) == {"f0", "f4"}


def test_read_striped_matches_per_stripe_reads():
    """Batched striped read: same data view, same simulated completion time
    and byte accounting as issuing each stripe through fs.read."""
    fab_a = Fabric(n_hosts=4, constants=BGQ)
    fab_b = Fabric(n_hosts=4, constants=BGQ)
    blob = np.arange(1 << 12, dtype=np.uint8) % 251
    fab_a.fs.put("d/x", blob)
    fab_b.fs.put("d/x", blob)
    stripes = _stripes(1 << 12, 4)
    view, t_batch = fab_a.fs.read_striped("d/x", stripes, 0.0,
                                          coordinated=True)
    t_loop = 0.0
    for off, sz in stripes:
        _, t_done = fab_b.fs.read("d/x", off, sz, 0.0, coordinated=True)
        t_loop = max(t_loop, t_done)
    assert t_batch == pytest.approx(t_loop)
    assert np.array_equal(view, fab_a.fs.files["d/x"])
    assert np.shares_memory(view, fab_a.fs.files["d/x"])   # zero-copy
    assert fab_a.fs.bytes_read == fab_b.fs.bytes_read == 1 << 12
    assert fab_a.fs.read_requests == fab_b.fs.read_requests == 4


def test_write_many_matches_sequential_writes():
    s_bulk, s_seq = make_store(), make_store()
    replicas = {f"f{i}": np.ones(64 * (i + 1), np.uint8) for i in range(3)}
    t_bulk = s_bulk.write_many(replicas, 0.0)
    t_seq = 0.0
    for p, v in replicas.items():
        t_seq = s_seq.write(p, v, t_seq)
    assert t_bulk == pytest.approx(t_seq)
    assert s_bulk.bytes_written == s_seq.bytes_written
    assert all(np.array_equal(s_bulk.data[p], s_seq.data[p])
               for p in replicas)


def test_fs_busy_and_wait_accounting():
    """The shared-FS occupancy/wait ledger: busy_time sums the bandwidth
    occupancy of every request, wait_time the queueing behind earlier
    traffic — and neither changes any completion time."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    fs = fab.fs
    size = 1 << 20
    fs.put("a.bin", np.zeros(size, np.uint8))
    assert fs.busy_time == 0.0 and fs.wait_time == 0.0   # put is free
    _, t1 = fs.read("a.bin", 0, size, 0.0, coordinated=True)
    per_read = size / BGQ.fs_seq_bw
    assert fs.busy_time == pytest.approx(per_read)
    assert fs.wait_time == 0.0                           # idle FS: no queue
    # a second read issued at t=0 queues behind the first
    _, t2 = fs.read("a.bin", 0, size, 0.0, coordinated=True)
    assert fs.busy_time == pytest.approx(2 * per_read)
    assert fs.wait_time == pytest.approx(per_read)
    assert t2 == pytest.approx(t1 + per_read)
    # writes and metadata feed the same ledger
    fs.write("b.bin", np.zeros(size, np.uint8), fs.busy_until)
    names, _ = fs.glob("*.bin", fs.busy_until)
    assert names == ["a.bin", "b.bin"]
    assert fs.busy_time > 2 * per_read

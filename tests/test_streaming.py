"""Streaming ingestion: delivery, sliding window, backpressure, frame
futures, online HEDM equivalence (streaming follow-on to the paper)."""
import numpy as np
import pytest

from repro.core.dataflow import Dataflow
from repro.core.fabric import BGQ, Fabric
from repro.core.iohook import BroadcastEntry, StagingSpec, run_io_hook
from repro.core.manytask import ManyTaskEngine, Task
from repro.core.streaming import (DetectorSource, StreamScenario,
                                  StreamStager, stage_stream)
from repro.hedm.pipeline import (reduce_frames, reduce_frames_online,
                                 run_batch_hedm, run_online_hedm,
                                 simulate_detector_frames)

FRAME = 32
FRAME_BYTES = FRAME * FRAME * 4


def make_stream(n_frames=8, rate_hz=100.0, seed=0):
    frames, dark = simulate_detector_frames(n_frames, size=FRAME,
                                            n_spots=3, seed=seed)
    return frames, dark, DetectorSource.from_frames(frames, rate_hz=rate_hz)


def emitted_bytes(frames, i):
    return np.ascontiguousarray(frames[i]).view(np.uint8).ravel()


# ---------------------------------------------------------------------------
# delivery
# ---------------------------------------------------------------------------

def test_stream_delivery_byte_exact_zero_copy():
    """Every node-local store ends up with a read-only zero-copy view of
    each emitted frame, byte-identical to the detector output."""
    fab = Fabric(n_hosts=4, constants=BGQ)
    frames, _, src = make_stream()
    stager = StreamStager(fab, window_bytes=8 * FRAME_BYTES)
    rep, recs = stager.stage(src)
    assert rep.n_frames == 8 and rep.evictions == 0 and rep.stall_time == 0
    for host in fab.hosts:
        for i, r in enumerate(recs):
            replica = host.store.data[r.path]
            assert np.array_equal(replica, emitted_bytes(frames, i))
            assert not replica.flags.writeable
    # one shared buffer per frame across all hosts (zero-copy)
    for r in recs:
        assert np.shares_memory(fab.hosts[0].store.data[r.path],
                                fab.hosts[-1].store.data[r.path])


def test_frame_futures_monotone_and_after_emission():
    fab = Fabric(n_hosts=8, constants=BGQ)
    _, _, src = make_stream(rate_hz=50.0)
    rep, recs = StreamStager(fab, window_bytes=8 * FRAME_BYTES).stage(src)
    for a, b in zip(recs, recs[1:]):
        assert b.t_avail > a.t_avail            # delivery order preserved
    for r in recs:
        assert r.t_avail > r.t_emit             # causality
    assert rep.ingest_makespan >= rep.acquisition_span
    assert rep.mean_latency > 0


def test_stream_report_net_accounting():
    """Each frame crosses the detector link once and is broadcast to the
    other P-1 hosts: net_bytes = F * B * (1 + (P-1))."""
    P, F = 4, 8
    fab = Fabric(n_hosts=P, constants=BGQ)
    _, _, src = make_stream(F)
    rep, _ = StreamStager(fab, window_bytes=F * FRAME_BYTES).stage(src)
    assert rep.total_bytes == F * FRAME_BYTES
    assert rep.net_bytes == F * FRAME_BYTES * P


# ---------------------------------------------------------------------------
# sliding window: eviction, pinning, backpressure
# ---------------------------------------------------------------------------

def test_watermark_eviction_frees_consumed_frames():
    """Above the high watermark, released (consumed) frames are dropped
    oldest-first down to the low watermark, on every host."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    frames, _, src = make_stream(16)
    stager = StreamStager(fab, window_bytes=4 * FRAME_BYTES,
                          high_watermark=0.9, low_watermark=0.5)
    recs = []
    for fid, path, buf, t_emit in src:
        rec = stager.ingest(path, buf, t_emit)
        stager.release(path, rec.t_avail)       # consumer keeps up
        recs.append(rec)
    rep = stager.finish()
    assert rep.evictions > 0
    assert rep.stall_time == 0                  # releases prevented stalls
    assert rep.peak_resident_bytes <= 4 * FRAME_BYTES
    for host in fab.hosts:
        assert recs[0].path not in host.store.data      # oldest evicted
        assert recs[-1].path in host.store.data         # newest resident
        resident = sum(v.size for v in host.store.data.values())
        assert resident <= 4 * FRAME_BYTES


def test_pinned_frames_survive_eviction():
    fab = Fabric(n_hosts=2, constants=BGQ)
    frames, _, src = make_stream(16)
    stager = StreamStager(fab, window_bytes=4 * FRAME_BYTES)
    first = None
    for fid, path, buf, t_emit in src:
        rec = stager.ingest(path, buf, t_emit)
        if fid == 0:
            first = rec
            stager.pin(rec.path)
        stager.release(path, rec.t_avail)
    rep = stager.finish()
    assert rep.evictions > 0
    for host in fab.hosts:
        assert first.path in host.store.data            # pinned survived
        assert first.path in host.store.pinned
        assert np.array_equal(host.store.data[first.path],
                              emitted_bytes(frames, 0))


def test_backpressure_stalls_and_stays_byte_exact():
    """A slow consumer fills the window: admission stalls until releases
    free space, frames are never corrupted or dropped."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    frames, _, src = make_stream(12, rate_hz=1000.0)    # fast acquisition
    stager = StreamStager(fab, window_bytes=3 * FRAME_BYTES)
    for fid, path, buf, t_emit in src:
        rec = stager.ingest(path, buf, t_emit)
        # frame is intact on every node while the consumer holds it
        for host in fab.hosts:
            assert np.array_equal(host.store.data[path],
                                  emitted_bytes(frames, fid))
        stager.release(path, rec.t_avail + 0.5)         # slow consumer
    rep = stager.finish()
    assert rep.n_frames == 12                           # nothing dropped
    assert rep.stall_time > 0                           # backpressure hit
    assert rep.evictions > 0
    assert rep.ingest_makespan > rep.acquisition_span + rep.stall_time / 2


def test_wedged_window_raises():
    """A window that can never fit the next frame (nothing released, no
    future release pending) is a hard error, not silent loss."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    _, _, src = make_stream(4)
    stager = StreamStager(fab, window_bytes=2 * FRAME_BYTES)
    it = iter(src)
    for _ in range(2):
        fid, path, buf, t_emit = next(it)
        stager.ingest(path, buf, t_emit)                # never released
    fid, path, buf, t_emit = next(it)
    with pytest.raises(RuntimeError, match="wedged"):
        stager.ingest(path, buf, t_emit)


def test_shared_window_backpressures_on_slowest_consumer():
    """Two sessions sharing ONE stager window: a frame only becomes
    evictable when BOTH have released it, at the LATEST ack — so the
    shared run is byte- and time-exact with a single consumer acking at
    the slow session's times (the serial equivalent)."""
    def drive(shared):
        fab = Fabric(n_hosts=2, constants=BGQ)
        frames, _, src = make_stream(12, rate_hz=1000.0)
        stager = StreamStager(fab, window_bytes=3 * FRAME_BYTES)
        if shared:
            stager.register_consumer("fast")
            stager.register_consumer("slow")
        for fid, path, buf, t_emit in src:
            rec = stager.ingest(path, buf, t_emit)
            if shared:
                stager.release(path, rec.t_avail, consumer="fast")
                stager.release(path, rec.t_avail + 0.5, consumer="slow")
            else:
                stager.release(path, rec.t_avail + 0.5)   # = the max ack
        rep = stager.finish()
        stores = [{p: bytes(h.store.data[p]) for p in h.store.data}
                  for h in fab.hosts]
        return (rep.n_frames, rep.stall_time, rep.evictions,
                rep.ingest_makespan, stores)

    shared, serial = drive(True), drive(False)
    assert shared == serial
    assert shared[1] > 0                    # the slow session backpressures


def test_shared_window_waits_for_every_consumer():
    """A frame acked by only one of two registered consumers stays
    unconsumed: it cannot be evicted, and the window wedges rather than
    dropping it from under the laggard."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    _, _, src = make_stream(4)
    stager = StreamStager(fab, window_bytes=2 * FRAME_BYTES)
    stager.register_consumer("a")
    stager.register_consumer("b")
    with pytest.raises(ValueError, match="unknown consumer"):
        stager.release("nope", 0.0, consumer="c")
    it = iter(src)
    for _ in range(2):
        fid, path, buf, t_emit = next(it)
        rec = stager.ingest(path, buf, t_emit)
        stager.release(path, rec.t_avail, consumer="a")   # b never acks
    fid, path, buf, t_emit = next(it)
    with pytest.raises(RuntimeError, match="wedged"):
        stager.ingest(path, buf, t_emit)
    # once b acks too, admission proceeds at the max ack time
    for p in list(stager._resident):
        stager.release(p, 2.0, consumer="b")
    rec = stager.ingest(path, buf, t_emit)
    assert rec.t_avail > 2.0
    assert stager.evictions > 0


# ---------------------------------------------------------------------------
# iohook mode="stream"
# ---------------------------------------------------------------------------

def test_iohook_stream_mode_skips_fs_readback():
    fab = Fabric(n_hosts=4, constants=BGQ)
    for i in range(3):
        fab.fs.put(f"scans/s{i}.bin", np.full(1 << 12, i, np.uint8))
    res = run_io_hook(fab, StagingSpec([BroadcastEntry(("scans/*.bin",))]),
                      mode="stream")
    rep = res.reports[0]
    assert rep.mode == "stream"
    assert rep.fs_bytes == 0                    # the whole point
    assert fab.fs.bytes_read == 0               # FS never read back
    assert rep.n_chunks == 3                    # per-frame delivery
    for host in fab.hosts:
        for i in range(3):
            p = f"scans/s{i}.bin"
            assert np.array_equal(host.store.data[p], fab.fs.files[p])
            assert p in host.store.pinned       # hook pins as usual


def test_stage_stream_bounded_window_slides():
    """A window smaller than the dataset must not wedge: frames release on
    delivery and the cache keeps only the most recent ones."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    paths = []
    for i in range(6):
        fab.fs.put(f"s/{i}.bin", np.full(1 << 10, i, np.uint8))
        paths.append(f"s/{i}.bin")
    rep, _ = stage_stream(fab, paths, window_bytes=2 << 10)
    assert rep.mode == "stream"
    for host in fab.hosts:
        assert paths[-1] in host.store.data             # newest resident
        assert paths[0] not in host.store.data          # oldest evicted
        assert sum(v.size for v in host.store.data.values()) <= 2 << 10
        assert np.array_equal(host.store.data[paths[-1]],
                              fab.fs.files[paths[-1]])


def test_iohook_stage_kw_passthrough():
    """Engine-specific parameters reach the staging engine via stage_kw."""
    fab = Fabric(n_hosts=4, constants=BGQ)
    for i in range(2):
        fab.fs.put(f"k/{i}.bin", np.full(1 << 14, i, np.uint8))
    spec = StagingSpec([BroadcastEntry(("k/*.bin",))])
    res_p = run_io_hook(fab, spec, mode="pipelined",
                        stage_kw={"chunk_bytes": 1 << 10})
    assert res_p.reports[0].n_chunks > 2        # chunk size actually used
    fab2 = Fabric(n_hosts=4, constants=BGQ)
    for i in range(2):
        fab2.fs.put(f"k/{i}.bin", np.full(1 << 14, i, np.uint8))
    res_s = run_io_hook(fab2, spec, mode="stream",
                        stage_kw={"rate_hz": 1.0})
    assert res_s.total_time >= 2.0              # 2 frames at 1 Hz


def test_iohook_stream_pin_with_bounded_window_fails_loudly():
    """Pinning happens at ingest: a bounded window too small for the
    pinned set wedges loudly instead of silently evicting pinned files."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    for i in range(4):
        fab.fs.put(f"p/{i}.bin", np.full(1 << 10, i, np.uint8))
    spec = StagingSpec([BroadcastEntry(("p/*.bin",), pin=True)])
    with pytest.raises(RuntimeError, match="wedged"):
        run_io_hook(fab, spec, mode="stream",
                    stage_kw={"window_bytes": 2 << 10})
    # unpinned entries slide through the same bounded window fine
    fab2 = Fabric(n_hosts=2, constants=BGQ)
    for i in range(4):
        fab2.fs.put(f"p/{i}.bin", np.full(1 << 10, i, np.uint8))
    res = run_io_hook(fab2, StagingSpec([BroadcastEntry(("p/*.bin",),
                                                        pin=False)]),
                      mode="stream", stage_kw={"window_bytes": 2 << 10})
    assert res.reports[0].n_chunks == 4


def test_online_hedm_accepts_non_float32_frames():
    """The online path casts to float32 like the batch path's stream_to_fs,
    so a float64 stack neither wedges the window nor corrupts replicas."""
    frames, dark, _ = make_stream(8, seed=11)
    on = run_online_hedm(Fabric(n_hosts=2, constants=BGQ),
                         frames.astype(np.float64), dark, rate_hz=100.0,
                         window=4, use_kernel=False,
                         reduce_time_per_frame=0.01)
    batch = reduce_frames(frames, dark, use_kernel=False)
    for a, b in zip(on.reduced, batch):
        assert np.array_equal(a.peaks, b.peaks)


def test_evicted_frame_input_fails_loudly():
    """A task whose streamed-frame input was evicted (and never existed on
    the shared FS) gets a diagnosable error, not a KeyError."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    eng = ManyTaskEngine(fab, n_workers=2)
    with pytest.raises(RuntimeError, match="evicted"):
        eng.run([Task(task_id=0, duration=0.1,
                      inputs=("scan/frame_00000.bin",))])


def test_stage_stream_respects_rate():
    fab = Fabric(n_hosts=2, constants=BGQ)
    for i in range(4):
        fab.fs.put(f"s/{i}.bin", np.ones(1 << 10, np.uint8))
    rep, t_end = stage_stream(fab, [f"s/{i}.bin" for i in range(4)],
                              rate_hz=2.0)
    assert t_end >= 2.0                         # 4 frames at 2 Hz
    assert rep.total_time == pytest.approx(t_end)


# ---------------------------------------------------------------------------
# frame futures in the engine / dataflow
# ---------------------------------------------------------------------------

def test_task_not_before_delays_start():
    fab = Fabric(n_hosts=2)
    eng = ManyTaskEngine(fab, n_workers=4)
    stats = eng.run([Task(task_id=0, duration=1.0, not_before=5.0),
                     Task(task_id=1, duration=1.0)])
    ev = {e.task_id: e for e in stats.events}
    assert ev[1].start == 0.0
    assert ev[0].start >= 5.0                   # waited for its frame
    assert stats.makespan == pytest.approx(6.0)


def test_dataflow_frame_future_ordering():
    """Per-frame tasks become eligible exactly when their frame lands;
    merges ride behind without a barrier; results are correct."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    frames, _, src = make_stream(8, rate_hz=2.0)        # 0.5 s cadence
    _, recs = StreamStager(fab, window_bytes=8 * FRAME_BYTES).stage(src)

    flow = Dataflow(fab)
    futs = [flow.frame_task(lambda r: r.frame_id, rec, duration=0.01)
            for rec in recs]
    total = flow.merge_pairwise(lambda a, b: a + b, futs, duration=0.0)
    stats = flow.run(n_workers=4)

    ev = {e.task_id: e for e in stats.events}
    for rec, fut in zip(recs, futs):
        assert ev[fut.task_id].start >= rec.t_avail - 1e-12
    assert total.result() == sum(range(8))
    # early frames were processed long before the stream closed
    assert ev[futs[0].task_id].end < recs[-1].t_avail
    assert stats.makespan >= recs[-1].t_avail


def test_dataflow_foreach_not_befores():
    fab = Fabric(n_hosts=2)
    flow = Dataflow(fab)
    futs = flow.foreach(lambda x: x, [10, 20], durations=[0.1, 0.1],
                        not_befores=[3.0, 0.0])
    stats = flow.run(n_workers=2)
    ev = {e.task_id: e for e in stats.events}
    assert ev[futs[0].task_id].start >= 3.0
    assert ev[futs[1].task_id].start == 0.0


# ---------------------------------------------------------------------------
# online HEDM
# ---------------------------------------------------------------------------

def test_online_reduction_bit_identical_to_batch():
    frames, dark, _ = make_stream(10, seed=3)
    batch = reduce_frames(frames, dark, use_kernel=False)
    online = [r for chunk in reduce_frames_online(frames, dark, window=4,
                                                  use_kernel=False)
              for r in chunk]
    assert len(online) == len(batch)
    for a, b in zip(online, batch):
        assert a.frame_id == b.frame_id
        assert a.n_signal_pixels == b.n_signal_pixels
        assert a.n_spots == b.n_spots
        assert np.array_equal(a.peaks, b.peaks)


def test_online_hedm_matches_batch_through_staged_replicas():
    """End to end: streamed ingestion + per-window reduction from the
    node-local replicas == FS round trip + batch staging + one-shot
    reduction, bit-exact — even with a bounded window under backpressure."""
    frames, dark, _ = make_stream(12, seed=5)
    on = run_online_hedm(Fabric(n_hosts=4, constants=BGQ), frames, dark,
                         rate_hz=500.0, window=4, use_kernel=False,
                         cache_frames=6, reduce_time_per_frame=0.05)
    batch, _, _ = run_batch_hedm(Fabric(n_hosts=4, constants=BGQ), frames,
                                 dark, rate_hz=500.0, use_kernel=False,
                                 reduce_time_per_frame=0.05)
    assert on.stream.stall_time > 0             # window actually pressured
    for a, b in zip(on.reduced, batch):
        assert a.frame_id == b.frame_id and a.n_spots == b.n_spots
        assert np.array_equal(a.peaks, b.peaks)


def test_online_hedm_validates_cache_vs_window():
    frames, dark, _ = make_stream(8)
    with pytest.raises(ValueError, match="cache_frames"):
        run_online_hedm(Fabric(n_hosts=2, constants=BGQ), frames, dark,
                        window=4, cache_frames=2, use_kernel=False,
                        reduce_time_per_frame=0.01)


def test_batch_hedm_naive_mode():
    frames, dark, _ = make_stream(6)
    reduced, t_naive, rep = run_batch_hedm(
        Fabric(n_hosts=4, constants=BGQ), frames, dark, rate_hz=10.0,
        mode="naive", use_kernel=False, reduce_time_per_frame=0.01)
    assert rep.mode == "naive"
    assert len(reduced) == 6
    with pytest.raises(ValueError, match="unknown staging mode"):
        run_batch_hedm(Fabric(n_hosts=2, constants=BGQ), frames, dark,
                       mode="bogus")


def test_streaming_turnaround_beats_batch_when_acquisition_bound():
    """The headline: overlapping reduction with a slow acquisition beats
    stage-then-process end to end (deterministic simulated durations)."""
    frames, dark, _ = make_stream(16, seed=7)
    kw = dict(rate_hz=4.0, use_kernel=False, reduce_time_per_frame=0.05)
    on = run_online_hedm(Fabric(n_hosts=8, constants=BGQ), frames, dark,
                         window=4, **kw)
    _, t_batch, _ = run_batch_hedm(Fabric(n_hosts=8, constants=BGQ),
                                   frames, dark, **kw)
    assert on.turnaround < t_batch
    # window results arrive DURING acquisition (the interactive property)
    assert on.window_done[0] < 16 / 4.0


def test_stream_scenario_wiring():
    sc = StreamScenario(n_hosts=4, n_frames=6, frame_size=FRAME,
                        rate_hz=50.0, window_frames=3)
    assert sc.frame_bytes == FRAME_BYTES
    assert sc.window_bytes == 6 * FRAME_BYTES  # cache_frames=None -> scan
    assert StreamScenario(n_frames=6, frame_size=FRAME,
                          cache_frames=4).window_bytes == 4 * FRAME_BYTES
    fab = sc.make_fabric()
    frames, dark = sc.make_frames()
    rep, recs = StreamStager(fab, window_bytes=sc.window_bytes).stage(
        sc.make_source(frames))
    assert rep.n_frames == 6
    assert fab.n_hosts == 4

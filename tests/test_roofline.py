"""HLO cost parser: validated against XLA cost_analysis; trip-count scaling."""
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.hlo_cost import analyze_hlo_text

xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)


def test_parser_matches_xla_on_unrolled():
    def unrolled(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x
    c = jax.jit(unrolled).lower(xs, xs).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    mine = analyze_hlo_text(c.as_text(), 1)
    assert abs(mine.flops / ca["flops"] - 1.0) < 0.05


def test_parser_scales_scan_bodies_by_trip_count():
    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    def unrolled(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x
    cs = jax.jit(scanned).lower(xs, xs).compile()
    cu = jax.jit(unrolled).lower(xs, xs).compile()
    ms = analyze_hlo_text(cs.as_text(), 1)
    mu = analyze_hlo_text(cu.as_text(), 1)
    assert abs(ms.flops / mu.flops - 1.0) < 0.02
    # XLA's own analysis counts the body once — the bug we correct
    ca = cs.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ms.flops > 5 * ca["flops"]


def test_nested_scan_trip_products():
    def nested(x, w):
        def inner(c, _):
            return jnp.tanh(c @ w), None

        def outer(c, _):
            out, _ = jax.lax.scan(inner, c, None, length=4)
            return out, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out
    c = jax.jit(nested).lower(xs, xs).compile()
    m = analyze_hlo_text(c.as_text(), 1)
    one = 2 * 128 ** 3
    assert abs(m.flops / (12 * one) - 1.0) < 0.1


def test_dus_counts_slice_not_buffer():
    """Scan ys-stacking must cost the written slice, not the full stack."""
    def stacker(x):
        def body(c, _):
            return c + 1.0, c
        _, ys = jax.lax.scan(body, x, None, length=100)
        return ys
    c = jax.jit(stacker).lower(xs).compile()
    m = analyze_hlo_text(c.as_text(), 1)
    slice_bytes = 128 * 128 * 4
    # 100 iterations x ~(read+write slice + adds); full-stack accounting
    # would be 100 x 100 x slice
    assert m.bytes < 20 * 100 * slice_bytes


def test_collective_ring_model_values():
    """AG/AR wire models on a known sharded matmul."""
    import jax
    import jax.numpy as jnp
    import os, subprocess, sys, textwrap
    # run under 8 devices in a subprocess (main process stays 1-device)
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.hlo_cost import analyze_hlo_text
        from repro.core.compat import make_auto_mesh
        mesh = make_auto_mesh((2, 4), ("data", "model"))
        def f(x, w):
            return x @ w
        xs = jax.ShapeDtypeStruct((64, 512), jnp.float32)
        ws = jax.ShapeDtypeStruct((512, 64), jnp.float32)
        low = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                                       NamedSharding(mesh, P("model", None))),
                      out_shardings=NamedSharding(mesh, P())).lower(xs, ws)
        c = low.compile()
        m = analyze_hlo_text(c.as_text(), 8)
        # all-reduce of (64,64) f32 over 4-way model axis:
        # 2 * (4-1)/4 * 16384 bytes = 24576
        assert abs(m.ici_collective_bytes - 24576.0) < 1.0, m.ici_collective_bytes
        assert m.dcn_collective_bytes == 0.0
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout

"""Fault injection + self-healing residency: deterministic schedules,
degraded collective planning, replica-aware staging and repair, the
DEGRADED catalog lifecycle, elastic resize, catalog snapshot/restore
across a simulated service restart, and the client fault surface."""
import math

import numpy as np
import pytest

from repro.checkpoint.store import CheckpointError, CheckpointStore
from repro.core.api import (CollectiveConfig, FaultConfig, ReplicatedConfig,
                            ServiceConfig, StagingClient, StagingSpec,
                            BroadcastEntry, ENGINES)
from repro.core.collectives import (CollectivePlanner, LinkPartitionedError)
from repro.core.datasvc import DatasetState, StagingService
from repro.core.fabric import BGQ, Fabric
from repro.core.faults import FaultEvent, FaultKind, FaultSchedule
from repro.core.staging import (LostStripesError, ReplicaPlacement,
                                re_replicate, stage_collective,
                                stage_replicated)
from repro.core.topology import BGQ_TORUS, FLAT


from conftest import make_fabric as _make_fabric


def make_fabric(n_hosts=8, n_files=4, file_bytes=1 << 12, seed=0, **kw):
    """This module's default shape over the shared conftest builder
    (fabric only — the files are recovered via :func:`paths`)."""
    fab, _ = _make_fabric(n_hosts=n_hosts, n_files=n_files, size=file_bytes,
                          seed=seed, **kw)
    return fab


def paths(fab):
    return sorted(fab.fs.files)


def assemble(fab, ps):
    return np.concatenate([fab.fs.files[p] for p in ps])


# ---------------------------------------------------------------------------
# FaultSchedule: deterministic queryable timeline
# ---------------------------------------------------------------------------

def test_schedule_trivial_and_ordering():
    sched = FaultSchedule()
    assert sched.trivial
    sched.inject(FaultEvent(5.0, FaultKind.HOST_DEATH, host=2))
    sched.inject(FaultEvent(1.0, FaultKind.HOST_DEATH, host=1))
    assert not sched.trivial
    assert [ev.t for ev in sched.events] == [1.0, 5.0]
    assert sched.dead_hosts(0.5) == frozenset()
    assert sched.dead_hosts(1.0) == {1}
    assert sched.dead_hosts(10.0) == {1, 2}


def test_schedule_death_then_recovery():
    sched = FaultSchedule([
        FaultEvent(1.0, FaultKind.HOST_DEATH, host=3),
        FaultEvent(4.0, FaultKind.HOST_RECOVERY, host=3),
    ])
    assert sched.is_dead(3, 2.0)
    assert not sched.is_dead(3, 4.0)
    assert sched.n_dead(2.0) == 1 and sched.n_dead(5.0) == 0


def test_schedule_degradation_windows_multiply():
    sched = FaultSchedule([
        FaultEvent(1.0, FaultKind.LINK_DEGRADE, tier="link", t_end=3.0,
                   factor=0.5),
        FaultEvent(2.0, FaultKind.LINK_DEGRADE, tier="link", t_end=4.0,
                   factor=0.5),
    ])
    assert sched.tier_factor("link", 0.5) == 1.0
    assert sched.tier_factor("link", 1.5) == 0.5
    assert sched.tier_factor("link", 2.5) == 0.25     # windows overlap
    assert sched.tier_factor("link", 3.5) == 0.5
    assert sched.tier_factor("link", 4.0) == 1.0      # t_end exclusive
    assert sched.tier_factors(("link", "other"), 2.5) == {"link": 0.25}


def test_schedule_random_is_seed_deterministic():
    a = FaultSchedule.random(7, 64, 30.0, n_deaths=3, n_degradations=2)
    b = FaultSchedule.random(7, 64, 30.0, n_deaths=3, n_degradations=2)
    c = FaultSchedule.random(8, 64, 30.0, n_deaths=3, n_degradations=2)
    key = lambda s: [(e.t, e.kind, e.host, e.tier, e.t_end, e.factor)
                     for e in s.events]
    assert key(a) == key(b)
    assert key(a) != key(c)
    assert all(0.0 <= e.t < 30.0 for e in a.events)


def test_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(1.0, FaultKind.LINK_DEGRADE, tier="link", t_end=0.5,
                   factor=0.5)                         # window ends early
    with pytest.raises(ValueError):
        FaultEvent(0.0, FaultKind.LINK_DEGRADE, tier="link", t_end=1.0,
                   factor=1.5)                         # factor out of range


# ---------------------------------------------------------------------------
# degraded planning + dead-host re-routing
# ---------------------------------------------------------------------------

def test_degraded_tier_slows_collectives_proportionally():
    healthy = CollectivePlanner(FLAT, BGQ)
    degraded = CollectivePlanner(FLAT.degraded({"link": 0.25}), BGQ)
    nbytes = 64 << 20
    t_h = healthy.plan_broadcast(nbytes, 16).time
    t_d = degraded.plan_broadcast(nbytes, 16).time
    assert t_d > t_h
    # bandwidth term scales 4x; latency terms are unchanged
    assert t_d < 4 * t_h + 1e-9


def test_fully_partitioned_tier_raises():
    planner = CollectivePlanner(FLAT.degraded({"link": 0.0}), BGQ)
    with pytest.raises(LinkPartitionedError):
        planner.plan_broadcast(1 << 20, 8)


def test_dead_host_adds_detour_and_marks_plan():
    planner = CollectivePlanner(FLAT, BGQ)
    base = planner.plan_allgather(1 << 20, 7)
    detour = planner.plan_allgather(1 << 20, 7, dead=2)
    assert detour.rerouted == 2
    assert detour.time == pytest.approx(base.time + 2 * FLAT.intra.latency
                                        if FLAT.intra.latency is not None
                                        else base.time + 2 * BGQ.link_latency)


def test_interconnect_consults_schedule_at_issue_time():
    sched = FaultSchedule([FaultEvent(10.0, FaultKind.HOST_DEATH, host=1)])
    fab = make_fabric(faults=sched)
    before = fab.net.allgather(1 << 20, 8, t=5.0)
    after = fab.net.allgather(1 << 20, 8, t=15.0)
    assert after != before                       # planned over 7 live + detour


def test_zero_fault_schedule_is_bit_exact():
    fab_a = make_fabric()
    fab_b = make_fabric(faults=FaultSchedule())
    rep_a, t_a = stage_collective(fab_a, paths(fab_a))
    rep_b, t_b = stage_collective(fab_b, paths(fab_b))
    assert t_a == t_b
    assert rep_a.comm_time == rep_b.comm_time
    assert fab_a.net.bytes_moved == fab_b.net.bytes_moved


# ---------------------------------------------------------------------------
# replica-aware staging + repair
# ---------------------------------------------------------------------------

def test_stage_replicated_is_byte_exact():
    fab = make_fabric(n_hosts=6)
    ps = paths(fab)
    rep, t = stage_replicated(fab, ps, replication=3)
    pl = rep.placement
    assert pl is not None and pl.replication == 3
    blob = assemble(fab, ps)
    for i, owners in pl.owners.items():
        assert len(owners) == 3
        for o in owners:
            got = np.concatenate(
                [fab.hosts[o].store.data[ReplicaPlacement.stripe_key(p, i)]
                 for p in ps])
            # stripe i of each file, concatenated — recompute and compare
    # stronger: every stripe of every file reassembles the file exactly
    for p in ps:
        src = fab.fs.files[p]
        rebuilt = np.concatenate(
            [fab.hosts[pl.owners[i][0]].store.data[
                ReplicaPlacement.stripe_key(p, i)]
             for i in sorted(pl.owners)])
        assert np.array_equal(rebuilt, src)


def test_chained_declustering_geometry():
    pl = ReplicaPlacement.chained([0, 1, 2, 3], replication=2)
    assert pl.owners == {0: (0, 1), 1: (1, 2), 2: (2, 3), 3: (3, 0)}
    assert pl.stripes_on(1) == [0, 1]
    assert pl.lost(live={0, 1, 2, 3}) == []
    assert pl.degraded(live={0, 2, 3}) == [0, 1]       # stripes owned by 1
    assert pl.lost(live={2, 3}) == [0]                 # both owners of 0 gone


def test_re_replicate_restores_placement_byte_exactly():
    fab = make_fabric(n_hosts=6)
    ps = paths(fab)
    rep, t = stage_replicated(fab, ps, replication=2)
    pl = rep.placement
    victim = 2
    fab.kill_host(victim, t + 1.0)
    live = fab.live_ids(t + 1.0)
    fix, t_fix = re_replicate(fab, ps, pl, t0=t + 1.0, live=live)
    assert fix.net_bytes > 0 and fix.comm_time > 0
    assert all(victim not in own for own in pl.owners.values())
    for i, owners in pl.owners.items():
        assert len(owners) == 2
        for p in ps:
            key = ReplicaPlacement.stripe_key(p, i)
            for o in owners:
                assert key in fab.hosts[o].store.data
    # byte-exact reassembly from the repaired placement
    for p in ps:
        rebuilt = np.concatenate(
            [fab.hosts[pl.owners[i][0]].store.data[
                ReplicaPlacement.stripe_key(p, i)]
             for i in sorted(pl.owners)])
        assert np.array_equal(rebuilt, fab.fs.files[p])


def test_re_replicate_cheaper_than_full_restage():
    fab = make_fabric(n_hosts=8, n_files=8, file_bytes=1 << 16)
    ps = paths(fab)
    rep, t = stage_replicated(fab, ps, replication=2)
    fab.kill_host(3, t + 1.0)
    fix, _ = re_replicate(fab, ps, rep.placement, t0=t + 1.0,
                          live=fab.live_ids(t + 1.0))
    # repair moves ~ the lost stripes, not the dataset
    assert fix.net_bytes < rep.net_bytes
    assert fix.total_time < rep.total_time


def test_re_replicate_raises_when_all_owners_dead():
    fab = make_fabric(n_hosts=4)
    ps = paths(fab)
    rep, t = stage_replicated(fab, ps, replication=1)
    fab.kill_host(0, t + 1.0)
    with pytest.raises(LostStripesError):
        re_replicate(fab, ps, rep.placement, t0=t + 1.0,
                     live=fab.live_ids(t + 1.0))


# ---------------------------------------------------------------------------
# DEGRADED lifecycle: death/recovery, lease-preserving repair
# ---------------------------------------------------------------------------

def make_service(n_hosts=8, engine=None, budget=1 << 20):
    fab = make_fabric(n_hosts=n_hosts)
    svc = StagingService(fab, budget_bytes=budget, engine=engine)
    svc.register("scan", paths=paths(fab), t=0.0)
    return fab, svc


def test_host_death_degrades_resident_dataset():
    fab, svc = make_service()
    lease = svc.acquire("alice", "scan", 0.0)
    entry = svc.catalog["scan"]
    svc.fail_host(3, lease.t_ready + 1.0)
    assert entry.state is DatasetState.DEGRADED
    assert 3 not in entry.holders
    assert svc.stats.host_deaths == 1 and svc.stats.degraded_events == 1
    # the lease is untouched: surviving replicas stay pinned + readable
    assert fab.hosts[2].store.read(entry.paths[0]) is not None
    assert entry.paths[0] in fab.hosts[2].store.pinned


def test_acquire_on_degraded_repairs_not_wedges():
    fab, svc = make_service()
    l1 = svc.acquire("alice", "scan", 0.0)
    svc.fail_host(3, l1.t_ready + 1.0)
    l2 = svc.acquire("bob", "scan", l1.t_ready + 2.0)   # repair, not error
    entry = svc.catalog["scan"]
    assert entry.state is DatasetState.RESIDENT
    assert svc.stats.repairs == 1
    # repair is neither a hit nor a stage; the invariant extends by repairs
    assert entry.acquires == (svc.catalog["scan"].stage_count
                              + entry.coalesced + entry.hits + entry.repairs)


def test_recovery_repair_is_lease_preserving_and_byte_exact():
    fab, svc = make_service()
    l1 = svc.acquire("alice", "scan", 0.0)
    l2 = svc.acquire("bob", "scan", l1.t_ready + 0.5)
    entry = svc.catalog["scan"]
    t1 = l1.t_ready + 1.0
    svc.fail_host(3, t1)
    svc.recover_host(3, t1 + 1.0)
    assert entry.state is DatasetState.DEGRADED     # back blank: no replica
    rep, t_done = svc.re_replicate("scan", t1 + 2.0)
    assert entry.state is DatasetState.RESIDENT
    assert rep.net_bytes == entry.nbytes            # one full replica moved
    for p in entry.paths:
        assert np.array_equal(fab.hosts[3].store.data[p], fab.fs.files[p])
        # the repaired host carries BOTH live leases' pins
        assert fab.hosts[3].store.pinned[p] == 2
    svc.release("alice", "scan", t_done + 1.0)
    svc.release("bob", "scan", t_done + 1.0)
    assert all(not h.store.pinned for h in fab.hosts)


def test_repaired_around_when_every_live_host_still_holds():
    fab, svc = make_service()
    l1 = svc.acquire("alice", "scan", 0.0)
    svc.fail_host(3, l1.t_ready + 1.0)
    rep, t_done = svc.re_replicate("scan", l1.t_ready + 2.0)
    # no recovery happened: every live host already holds a replica
    assert rep.net_bytes == 0
    assert t_done == l1.t_ready + 2.0
    assert svc.catalog["scan"].state is DatasetState.RESIDENT


def test_striped_service_repair_moves_only_lost_stripes():
    fab, svc = make_service(engine=ReplicatedConfig(replication=2))
    l1 = svc.acquire("alice", "scan", 0.0)
    entry = svc.catalog["scan"]
    assert entry.placement is not None
    svc.fail_host(2, l1.t_ready + 1.0)
    assert entry.state is DatasetState.DEGRADED
    rep, _ = svc.re_replicate("scan", l1.t_ready + 2.0)
    assert entry.state is DatasetState.RESIDENT
    assert 0 < rep.net_bytes < entry.nbytes
    assert all(2 not in own for own in entry.placement.owners.values())


def test_no_live_copy_falls_back_to_restage():
    fab, svc = make_service(n_hosts=3)
    l1 = svc.acquire("alice", "scan", 0.0)
    entry = svc.catalog["scan"]
    t = l1.t_ready + 1.0
    for h in (0, 1, 2):
        svc.fail_host(h, t)
        svc.recover_host(h, t + 0.5)          # all blank again
    assert entry.state is DatasetState.DEGRADED
    rep, t_done = svc.re_replicate("scan", t + 1.0)
    assert entry.state is DatasetState.RESIDENT
    assert svc.stats.restages == 1            # went through the shared FS
    for p in entry.paths:
        assert np.array_equal(fab.hosts[0].store.data[p], fab.fs.files[p])
        assert fab.hosts[0].store.pinned[p] == 1     # lease re-pinned


def test_resize_grow_degrades_full_replication_until_repair():
    fab, svc = make_service(n_hosts=6)
    l1 = svc.acquire("alice", "scan", 0.0)
    entry = svc.catalog["scan"]
    grown = svc.resize(8, l1.t_ready + 1.0)
    assert grown == [6, 7]
    assert entry.state is DatasetState.DEGRADED
    svc.re_replicate("scan", l1.t_ready + 2.0)
    assert entry.state is DatasetState.RESIDENT
    for h in grown:
        assert all(p in fab.hosts[h].store.data for p in entry.paths)


def test_resize_shrink_keeps_full_replication_resident():
    fab, svc = make_service(n_hosts=8)
    l1 = svc.acquire("alice", "scan", 0.0)
    entry = svc.catalog["scan"]
    removed = svc.resize(6, l1.t_ready + 1.0)
    assert removed == [6, 7]
    assert entry.state is DatasetState.RESIDENT   # survivors all hold copies
    assert entry.holders == set(range(6))


# ---------------------------------------------------------------------------
# catalog snapshot/restore (simulated service restart)
# ---------------------------------------------------------------------------

def test_catalog_restart_restores_residency_and_leases(tmp_path):
    fab, svc = make_service()
    l1 = svc.acquire("alice", "scan", 0.0)
    store = CheckpointStore(str(tmp_path))
    store.save_catalog(svc, t=l1.t_ready + 1.0)
    svc2 = store.restore_catalog(fab)
    entry = svc2.catalog["scan"]
    assert entry.state is DatasetState.RESIDENT
    assert entry.lease_count == 1
    svc2.release("alice", "scan", l1.t_ready + 2.0)


def test_catalog_restart_detects_lost_replicas(tmp_path):
    fab, svc = make_service()
    l1 = svc.acquire("alice", "scan", 0.0)
    store = CheckpointStore(str(tmp_path))
    store.save_catalog(svc, t=l1.t_ready + 1.0)
    fab.kill_host(4, l1.t_ready + 2.0)            # dies while service is down
    svc2 = store.restore_catalog(fab)
    entry = svc2.catalog["scan"]
    assert entry.state is DatasetState.DEGRADED
    assert 4 not in entry.holders
    lease = svc2.acquire("bob", "scan", l1.t_ready + 3.0)
    assert entry.state is DatasetState.RESIDENT
    assert svc2.stats.repairs == 1


def test_catalog_restore_without_snapshot_is_loud(tmp_path):
    fab = make_fabric()
    with pytest.raises(CheckpointError, match="no catalog snapshot"):
        CheckpointStore(str(tmp_path)).restore_catalog(fab)


# ---------------------------------------------------------------------------
# client surface: FaultConfig scoping, replicated engine, inject
# ---------------------------------------------------------------------------

def test_fault_config_zero_fault_is_bit_exact():
    fab_a, fab_b = make_fabric(), make_fabric()
    r_a = StagingClient(fab_a).stage("d/*.bin", CollectiveConfig())
    r_b = StagingClient(fab_b).stage("d/*.bin",
                                     CollectiveConfig(faults=FaultConfig()))
    assert r_a.total_time == r_b.total_time
    assert fab_a.net.bytes_moved == fab_b.net.bytes_moved


def test_fault_config_scopes_to_one_stage():
    fab = make_fabric()
    cfg = CollectiveConfig(faults=FaultConfig(host_deaths=((0.0, 3),)))
    rep = StagingClient(fab).stage("d/*.bin", cfg)
    assert not fab.hosts[3].store.data           # dead host skipped
    assert not fab.hosts[3].store.pinned         # and never pinned
    assert fab.hosts[2].store.read("d/f0.bin") is not None
    assert fab.faults.trivial                    # live schedule untouched


def test_fault_config_json_round_trip():
    cfg = ReplicatedConfig(
        replication=3,
        faults=FaultConfig(host_deaths=((1.0, 2),),
                           degradations=(("link", 0.5, 2.0, 0.25),)))
    spec = StagingSpec([BroadcastEntry(files=("d/*.bin",))], config=cfg)
    spec2 = StagingSpec.from_json(spec.to_json())
    assert spec2.config == cfg
    assert spec2.config.faults.build(8).n_dead(1.5) == 1


def test_fault_config_validation():
    with pytest.raises(ValueError, match="seed and random_deaths"):
        FaultConfig(seed=3)
    with pytest.raises(ValueError, match="seed and random_deaths"):
        FaultConfig(random_deaths=2)
    sched = FaultConfig(seed=3, random_deaths=2, horizon=10.0).build(32)
    assert sched.n_dead(10.0) == 2


def test_client_inject_degrades_attached_service_catalog():
    fab = make_fabric()
    client = StagingClient(fab, service=ServiceConfig(budget_bytes=1 << 20))
    svc = client.service
    svc.register("scan", paths=paths(fab), t=0.0)
    lease = svc.acquire("alice", "scan", 0.0)
    ev = client.inject(FaultKind.HOST_DEATH, t=lease.t_ready + 1.0, host=2)
    assert ev.kind is FaultKind.HOST_DEATH
    assert svc.catalog["scan"].state is DatasetState.DEGRADED
    assert not fab.hosts[2].store.data           # store wiped (live fault)


def test_replicated_engine_registered():
    assert "replicated" in ENGINES
    assert ENGINES.entry("replicated").batch
    cfg = ENGINES.config_for("replicated", replication=2)
    assert isinstance(cfg, ReplicatedConfig)


def test_degraded_stream_ingest_counts_and_skips():
    from repro.core.streaming import DetectorSource, StreamStager
    rng = np.random.default_rng(1)
    frames = rng.integers(0, 255, (6, 16, 16), dtype=np.uint8)
    fab = Fabric(4, constants=BGQ)
    stager = StreamStager(fab, window_bytes=1 << 22)
    for fid, path, buf, t_emit in DetectorSource.from_frames(
            frames.astype(np.float32), rate_hz=10.0):
        if fid == 2:
            fab.kill_host(1, t_emit)
        stager.ingest(path, buf, t_emit)
    rep = stager.finish()
    assert rep.degraded_deliveries == 4
    assert len(fab.hosts[1].store.data) == 0      # wiped, then skipped
    assert len(fab.hosts[0].store.data) == 6

"""Topology-aware fabric + collective planner.

Covers the FLAT regression anchor (bit-for-bit the pre-topology ring
accounting), planner edge cases (n_hosts in {1, 2}, zero-byte messages,
single-rack collapse), cost monotonicity in P and nbytes, per-tier byte
accounting, engine byte-exactness under every planner algorithm, and the
TopologyConfig surface on the client API."""
import json

import numpy as np
import pytest

from repro.core.api import (CollectiveConfig, PipelinedConfig, StagingClient,
                            StagingSpec, BroadcastEntry, StreamConfig,
                            TopologyConfig)
from repro.core.collectives import CollectivePlanner
from repro.core.fabric import BGQ, Fabric, Interconnect
from repro.core.topology import (BGQ_TORUS, FLAT, TOPOLOGIES,
                                 TPU_POD_ICI_DCN, LinkTier, Topology,
                                 resolve_topology)
from tests.hypothesis_compat import given, settings, st


def legacy_broadcast(nbytes, P, c=BGQ):
    """The pre-topology pipelined-ring broadcast closed form."""
    if P <= 1:
        return 0.0
    seg = min(nbytes, 1 << 20)
    return (nbytes / c.link_bw + (P - 2) * (seg / c.link_bw + c.link_latency)
            + c.link_latency)


def legacy_allgather(shard, P, c=BGQ):
    """The pre-topology ring all-gather closed form."""
    if P <= 1:
        return 0.0
    return (P - 1) * (shard / c.link_bw + c.link_latency)


from tests.conftest import make_fabric as _make_fabric


def make_fabric(n_hosts=4, n_files=3, size=1 << 14, topology=None, seed=0):
    """This module's default shape over the shared conftest builder."""
    return _make_fabric(n_hosts=n_hosts, n_files=n_files, size=size,
                        seed=seed, topology=topology)


# ---------------------------------------------------------------------------
# FLAT: the numeric regression anchor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [1, 2, 3, 17, 64, 4096])
@pytest.mark.parametrize("nbytes", [0, 1, 12345, 32 << 20])
def test_flat_matches_legacy_closed_forms(P, nbytes):
    net = Interconnect(BGQ)                       # default topology: FLAT
    assert net.topology is FLAT
    assert net.broadcast(nbytes, P) == legacy_broadcast(nbytes, P)
    assert net.allgather(nbytes, P) == legacy_allgather(nbytes, P)
    assert (net.point_to_point_time(nbytes)
            == nbytes / BGQ.link_bw + BGQ.link_latency)


def test_flat_bytes_moved_matches_legacy_accounting():
    net = Interconnect(BGQ)
    net.broadcast(100, 8)
    assert net.bytes_moved == 100 * 7
    net.allgather(10, 8)
    assert net.bytes_moved == 100 * 7 + 10 * 8 * 7
    net.point_to_point_time(5)
    assert net.bytes_moved == 100 * 7 + 10 * 8 * 7 + 5
    # FLAT has one tier ("link"); it carries everything
    assert net.tier_bytes == {"link": net.bytes_moved}


def test_flat_single_host_moves_nothing():
    net = Interconnect(BGQ)
    assert net.broadcast(1 << 20, 1) == 0.0
    assert net.allgather(1 << 20, 1) == 0.0
    assert net.bytes_moved == 0 and net.tier_bytes == {}


def test_deprecated_aliases_route_through_planner():
    a, b = Interconnect(BGQ), Interconnect(BGQ)
    with pytest.warns(DeprecationWarning, match="Interconnect.broadcast"):
        t_bcast = a.broadcast_time(1 << 16, 8)
    assert t_bcast == b.broadcast(1 << 16, 8)
    with pytest.warns(DeprecationWarning, match="Interconnect.allgather"):
        t_ag = a.ring_allgather_time(1 << 10, 8)
    assert t_ag == b.allgather(1 << 10, 8)
    assert a.bytes_moved == b.bytes_moved


# ---------------------------------------------------------------------------
# planner edge cases
# ---------------------------------------------------------------------------

ALL_OPS = [("broadcast", "plan_broadcast"), ("allgather", "plan_allgather"),
           ("scatter", "plan_scatter")]


@pytest.mark.parametrize("topology", [FLAT, BGQ_TORUS, TPU_POD_ICI_DCN])
@pytest.mark.parametrize("op,planfn", ALL_OPS)
def test_single_host_plans_are_empty(topology, op, planfn):
    planner = CollectivePlanner(topology, BGQ)
    for P in (0, 1):
        plan = getattr(planner, planfn)(1 << 20, P)
        assert plan.time == 0.0 and plan.total_bytes == 0


@pytest.mark.parametrize("topology", [FLAT, BGQ_TORUS, TPU_POD_ICI_DCN])
@pytest.mark.parametrize("op,planfn", ALL_OPS)
def test_two_hosts_every_algorithm_is_finite_and_positive(topology, op,
                                                          planfn):
    planner = CollectivePlanner(topology, BGQ)
    for alg in planner.algorithms(op):
        plan = getattr(planner, planfn)(1 << 16, 2, algorithm=alg)
        assert plan.time > 0.0
        assert plan.total_bytes > 0


@pytest.mark.parametrize("topology", [FLAT, BGQ_TORUS, TPU_POD_ICI_DCN])
@pytest.mark.parametrize("op,planfn", ALL_OPS)
def test_zero_byte_messages_cost_latency_only(topology, op, planfn):
    planner = CollectivePlanner(topology, BGQ)
    for alg in planner.algorithms(op):
        plan = getattr(planner, planfn)(0, 64, algorithm=alg)
        assert plan.time >= 0.0
        assert plan.total_bytes == 0
        # latency-only: well under a bandwidth-bearing message's time
        ref = getattr(planner, planfn)(1 << 25, 64, algorithm=alg)
        assert plan.time < ref.time


def test_unknown_algorithm_and_negative_bytes_raise():
    planner = CollectivePlanner(BGQ_TORUS, BGQ)
    with pytest.raises(ValueError, match="unknown broadcast algorithm"):
        planner.plan_broadcast(1 << 20, 64, algorithm="bogus")
    with pytest.raises(ValueError, match="must be >= 0"):
        planner.plan_broadcast(-1, 64)


def test_single_rack_topologies_collapse_to_the_flat_plan():
    """hosts_per_rack >= P: the hierarchical algorithms degrade to
    exactly the flat (single-tier) plans."""
    single = Topology("single", hosts_per_rack=4096,
                      intra=LinkTier("torus", 2e9, 2.5e-6),
                      inter=LinkTier("optical", 2e9, 6e-6))
    planner = CollectivePlanner(single, BGQ)
    for P in (2, 17, 256):
        h = planner.plan_broadcast(1 << 20, P, algorithm="hierarchical")
        r = planner.plan_broadcast(1 << 20, P, algorithm="pipelined_ring")
        assert h.time == r.time and h.tier_bytes == r.tier_bytes
        h = planner.plan_allgather(1 << 12, P, algorithm="hierarchical")
        r = planner.plan_allgather(1 << 12, P, algorithm="ring")
        assert h.time == r.time and h.tier_bytes == r.tier_bytes
        h = planner.plan_scatter(1 << 20, P, algorithm="hierarchical")
        r = planner.plan_scatter(1 << 20, P, algorithm="binomial")
        assert h.time == r.time and h.tier_bytes == r.tier_bytes


# ---------------------------------------------------------------------------
# cost monotonicity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", [FLAT, BGQ_TORUS, TPU_POD_ICI_DCN])
@pytest.mark.parametrize("op,planfn", ALL_OPS)
def test_cost_monotone_in_nbytes(topology, op, planfn):
    planner = CollectivePlanner(topology, BGQ)
    for P in (2, 64, 4096):
        prev = -1.0
        for n in (0, 1, 1 << 10, 1 << 16, 1 << 20, 1 << 25):
            t = getattr(planner, planfn)(n, P).time
            assert t >= prev, (op, P, n)
            prev = t


@pytest.mark.parametrize("topology", [FLAT, BGQ_TORUS, TPU_POD_ICI_DCN])
@pytest.mark.parametrize("op,planfn", ALL_OPS)
def test_cost_monotone_in_hosts(topology, op, planfn):
    planner = CollectivePlanner(topology, BGQ)
    prev = -1.0
    for P in (1, 2, 4, 16, 64, 256, 1024, 4096, 8192):
        t = getattr(planner, planfn)(1 << 20, P).time
        assert t >= prev, (op, P)
        prev = t


@settings(max_examples=50, deadline=None)
@given(n=st.integers(min_value=0, max_value=1 << 26),
       delta=st.integers(min_value=0, max_value=1 << 20),
       P=st.integers(min_value=1, max_value=8192))
def test_broadcast_cost_monotone_in_nbytes_property(n, delta, P):
    planner = CollectivePlanner(BGQ_TORUS, BGQ)
    assert (planner.plan_broadcast(n + delta, P).time
            >= planner.plan_broadcast(n, P).time)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(min_value=0, max_value=1 << 26),
       P=st.integers(min_value=1, max_value=4096))
def test_auto_selection_never_beats_itself_property(n, P):
    """The auto-selected plan is the argmin over explicit algorithms."""
    planner = CollectivePlanner(TPU_POD_ICI_DCN, BGQ)
    auto = planner.plan_broadcast(n, P)
    for alg in planner.algorithms("broadcast"):
        assert auto.time <= planner.plan_broadcast(n, P,
                                                   algorithm=alg).time


# ---------------------------------------------------------------------------
# per-tier accounting + the hierarchical win
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op,planfn", ALL_OPS)
def test_tier_bytes_sum_to_total_and_name_real_tiers(op, planfn):
    planner = CollectivePlanner(BGQ_TORUS, BGQ)
    for alg in planner.algorithms(op):
        plan = getattr(planner, planfn)(1 << 22, 2048, algorithm=alg)
        assert sum(plan.tier_bytes.values()) == plan.total_bytes
        assert set(plan.tier_bytes) <= set(BGQ_TORUS.tier_names())


def test_broadcast_ring_and_hierarchical_move_identical_total_bytes():
    """Both deliver n bytes to P-1 hosts: (P-1) * n on the wire, split
    across tiers differently."""
    planner = CollectivePlanner(BGQ_TORUS, BGQ)
    n, P = 1 << 22, 2048
    ring = planner.plan_broadcast(n, P, algorithm="pipelined_ring")
    hier = planner.plan_broadcast(n, P, algorithm="hierarchical")
    assert ring.total_bytes == hier.total_bytes == (P - 1) * n
    assert hier.tier_bytes["optical"] < hier.tier_bytes["torus"]


@pytest.mark.parametrize("P", [4096, 8192])
def test_hierarchical_broadcast_beats_flat_ring_at_scale(P):
    """The tentpole claim: at P >= 4096 the hierarchical plan (and the
    auto selection) demonstrably beat the flat pipelined ring."""
    planner = CollectivePlanner(BGQ_TORUS, BGQ)
    flat = planner.plan_broadcast(32 << 20, P, algorithm="pipelined_ring")
    hier = planner.plan_broadcast(32 << 20, P, algorithm="hierarchical")
    auto = planner.plan_broadcast(32 << 20, P)
    assert hier.time < flat.time
    assert auto.time <= hier.time


def test_interconnect_tier_counters_accumulate_plans():
    net = Interconnect(BGQ, topology=BGQ_TORUS)
    net.broadcast(1 << 20, 2048)
    net.allgather(1 << 10, 2048)
    assert sum(net.tier_bytes.values()) == net.bytes_moved
    snap = net.tier_snapshot()
    net.broadcast(1 << 16, 2048)
    delta = net.tier_delta(snap)
    assert sum(delta.values()) == (1 << 16) * 2047


# ---------------------------------------------------------------------------
# engines under topologies: byte-exact, FLAT parity, per-tier reports
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["collective", "pipelined", "stream"])
@pytest.mark.parametrize("topology", ["bgq_torus", "tpu_pod_ici_dcn"])
def test_engines_byte_exact_under_hierarchical_topologies(mode, topology):
    from repro.core.api import ENGINES
    fab, paths = make_fabric(n_hosts=6)
    rep, t = ENGINES.stage_fn(mode)(fab, paths, 0.0, topology=topology)
    assert t > 0.0
    assert sum(rep.tier_bytes.values()) == rep.net_bytes
    for host in fab.hosts:
        for p in paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])


@pytest.mark.parametrize("alg", ["pipelined_ring", "binomial_tree",
                                 "scatter_allgather", "hierarchical"])
def test_stream_delivery_byte_exact_under_every_broadcast_algorithm(alg):
    """Replica data is independent of the planned algorithm — pin each
    broadcast algorithm via a custom topology and check delivery."""
    topo = Topology(f"pin_{alg}", hosts_per_rack=2,
                    intra=LinkTier("torus", 2e9, 2.5e-6),
                    inter=LinkTier("optical", 2e9, 6e-6),
                    pinned_algorithms={"broadcast": alg})
    fab, paths = make_fabric(n_hosts=6)
    from repro.core.streaming import stage_stream
    rep, _ = stage_stream(fab, paths, topology=topo)
    assert rep.fs_bytes == 0
    for host in fab.hosts:
        for p in paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])


def test_engine_flat_topology_reproduces_default_accounting():
    """topology=FLAT (explicit, via name, or via config) is the regression
    anchor: identical simulated accounting to a default run."""
    results = []
    for topo in (None, "flat", TopologyConfig("flat")):
        fab, paths = make_fabric(n_hosts=8)
        rep, t = __import__("repro.core.staging", fromlist=["x"]) \
            .stage_collective(fab, paths, 0.0, topology=topo)
        results.append((rep.stage_time, rep.comm_time, rep.write_time,
                        rep.fs_bytes, rep.net_bytes, t))
    assert results[0] == results[1] == results[2]


def test_direct_topology_assignment_rebinds_the_planner():
    """`net.topology` is a public field: assigning it directly must take
    effect on the next plan (no stale cached planner)."""
    net = Interconnect(BGQ)
    flat_t = net.broadcast(32 << 20, 8192)
    net.topology = BGQ_TORUS
    assert net.planner.topology is BGQ_TORUS
    assert net.broadcast(32 << 20, 8192) < flat_t     # hierarchical plan
    assert set(net.tier_bytes) >= {"link", "torus"}   # both bindings used


def test_scoped_topology_restores_binding_and_none_is_noop():
    fab, _ = make_fabric(n_hosts=4)
    assert fab.net.topology is FLAT
    with fab.net.scoped_topology("bgq_torus"):
        assert fab.net.topology.name == "bgq_torus"
        with fab.net.scoped_topology(None):       # no-op nesting
            assert fab.net.topology.name == "bgq_torus"
    assert fab.net.topology is FLAT


def test_predict_stage_time_tracks_fabric_topology():
    """The eviction cost model plans through the fabric topology: FLAT
    reproduces the legacy closed form; a hierarchical machine differs."""
    from repro.core.datasvc import predict_stage_time
    from repro.core.staging import _coll_overhead
    fab = Fabric(n_hosts=64, constants=BGQ)
    nbytes, n_files = 1 << 24, 4
    c = BGQ
    stripe = max(1, (nbytes + 63) // 64)
    expect = (nbytes / c.fs_seq_bw + n_files * _coll_overhead(fab)
              + c.fs_op_latency
              + legacy_allgather(stripe, 64)
              + nbytes / c.local_bw)
    assert predict_stage_time(fab, nbytes, n_files) == pytest.approx(expect)
    fab_t = Fabric(n_hosts=64, constants=BGQ, topology=BGQ_TORUS)
    assert predict_stage_time(fab_t, nbytes, n_files) > 0.0


# ---------------------------------------------------------------------------
# TopologyConfig on the client API
# ---------------------------------------------------------------------------

def test_topology_config_validation():
    with pytest.raises(ValueError, match="unknown topology"):
        TopologyConfig("not_a_machine")
    with pytest.raises(ValueError, match="hosts_per_rack"):
        TopologyConfig("bgq_torus", hosts_per_rack=0)
    cfg = TopologyConfig("bgq_torus", hosts_per_rack=128)
    assert cfg.resolve().hosts_per_rack == 128
    assert cfg.resolve().intra.name == "torus"
    assert resolve_topology(None) is FLAT
    assert resolve_topology("tpu_pod_ici_dcn") is TPU_POD_ICI_DCN
    assert set(TOPOLOGIES) >= {"flat", "bgq_torus", "tpu_pod_ici_dcn"}


def test_engine_config_coerces_loose_topology_spellings():
    a = CollectiveConfig(topology="bgq_torus")
    b = CollectiveConfig(topology=TopologyConfig("bgq_torus"))
    c = CollectiveConfig(topology={"name": "bgq_torus"})
    d = CollectiveConfig(topology=BGQ_TORUS)
    assert a == b == c == d
    assert isinstance(a.topology, TopologyConfig)


def test_coerce_keeps_canned_instance_overrides_or_refuses():
    """A customized canned Topology must not silently coerce back to the
    stock instance: config-representable overrides are kept, anything
    else (tier edits, unregistered machines) refuses loudly."""
    from dataclasses import replace
    custom = replace(BGQ_TORUS, hosts_per_rack=128)
    cfg = TopologyConfig.coerce(custom)
    assert cfg.hosts_per_rack == 128
    assert cfg.resolve() == custom
    with pytest.raises(ValueError, match="cannot carry"):
        TopologyConfig.coerce(replace(
            BGQ_TORUS, intra=LinkTier("torus", 1e9, 1e-6)))
    with pytest.raises(ValueError, match="not the registered"):
        TopologyConfig.coerce(Topology("homegrown"))


def test_stream_stager_honors_config_topology():
    """The incremental driver plans delivery under the config's topology,
    exactly like the one-shot stream engine."""
    fab, paths = make_fabric(n_hosts=4, size=1 << 12)
    fab2, _ = make_fabric(n_hosts=4, size=1 << 12)
    client = StagingClient(fab)
    cfg = StreamConfig(window_bytes=1 << 20, topology="tpu_pod_ici_dcn")
    stager = client.stream_stager(cfg)
    for p in paths:
        stager.ingest(p, fab.fs.files[p], 0.0)
    rep = stager.finish()
    assert set(rep.tier_bytes) <= {"ici", "dcn"}
    assert sum(rep.tier_bytes.values()) == rep.net_bytes
    # FLAT control: same frames, default binding -> "link" tier
    flat = StagingClient(fab2).stream_stager(
        StreamConfig(window_bytes=1 << 20))
    for p in paths:
        flat.ingest(p, fab2.fs.files[p], 0.0)
    assert set(flat.finish().tier_bytes) == {"link"}


def test_spec_json_round_trips_topology_config():
    spec = StagingSpec(
        [BroadcastEntry(("d/*.bin",))],
        config=PipelinedConfig(chunk_bytes=1 << 12,
                               topology=TopologyConfig("tpu_pod_ici_dcn",
                                                       hosts_per_rack=32)))
    text = spec.to_json()
    json.loads(text)                              # valid JSON all the way
    assert StagingSpec.from_json(text) == spec


def test_client_stage_with_topology_config_byte_exact_and_tiered():
    fab, paths = make_fabric(n_hosts=6)
    rep = StagingClient(fab).stage(
        "d/*.bin", CollectiveConfig(topology=TopologyConfig(
            "bgq_torus", hosts_per_rack=2)))
    assert rep.resolved_files == paths
    r = rep.reports[0]
    assert sum(r.tier_bytes.values()) == r.net_bytes
    assert set(r.tier_bytes) <= {"torus", "optical"}
    for host in fab.hosts:
        for p in paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])
    assert fab.net.topology is FLAT               # binding restored


def test_client_planner_property_is_pure():
    fab, _ = make_fabric(n_hosts=4)
    client = StagingClient(fab)
    plan = client.planner.plan_broadcast(1 << 20, 4)
    assert plan.time > 0.0
    assert fab.net.bytes_moved == 0               # planning accounts nothing


def test_stream_config_carries_topology_to_the_stager():
    fab, paths = make_fabric(n_hosts=4, size=1 << 12)
    client = StagingClient(fab)
    rep = client.stage("d/*.bin", StreamConfig(topology="bgq_torus"))
    r = rep.reports[0]
    assert r.fs_bytes == 0 and sum(r.tier_bytes.values()) == r.net_bytes
    for host in fab.hosts:
        for p in paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])


# ---------------------------------------------------------------------------
# satellite: degenerate-stripe no-ops on the shared FS
# ---------------------------------------------------------------------------

def test_read_striped_empty_stripe_list_is_a_true_noop():
    fab, paths = make_fabric(n_hosts=2)
    fab.fs.busy_until = 1.0
    view, t = fab.fs.read_striped(paths[0], [], t=5.0)
    assert t == 5.0                               # no latency charged
    assert view.size == 0
    assert fab.fs.busy_until == 1.0               # busy stream untouched
    assert fab.fs.bytes_read == 0 and fab.fs.read_requests == 0


def test_write_gather_empty_stripe_list_is_a_true_noop():
    fab, _ = make_fabric(n_hosts=2)
    fab.fs.busy_until = 1.0
    t = fab.fs.write_gather("out/x.bin", np.ones(16, np.uint8), [], t=5.0)
    assert t == 5.0
    assert "out/x.bin" not in fab.fs.files        # nothing installed
    assert fab.fs.busy_until == 1.0
    assert fab.fs.bytes_written == 0 and fab.fs.write_requests == 0

"""Training: convergence, microbatch equivalence, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.train import compression as comp
from repro.train.optimizer import OptConfig, lr_schedule
from repro.train.train_step import (grads_and_loss, init_train_state,
                                    make_train_step)

key = jax.random.PRNGKey(0)


def test_loss_decreases_on_repeated_batch():
    cfg = get_smoke_config("qwen3_32b")
    opt = OptConfig(total_steps=50, warmup_steps=5, peak_lr=3e-3)
    params, opt_state = init_train_state(key, cfg, opt)
    shape = ShapeConfig("s", "train", 32, 4, num_microbatches=2, remat=True)
    step = jax.jit(make_train_step(cfg, shape, opt))
    batch = {"tokens": jnp.ones((4, 32), jnp.int32),
             "labels": jnp.ones((4, 32), jnp.int32)}
    losses = []
    for _ in range(6):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8


@pytest.mark.slow
def test_microbatch_grads_match_full_batch():
    cfg = get_smoke_config("internlm2_20b")
    from repro.models import model as M
    params = M.init_model(key, cfg)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
    g1, l1, _ = grads_and_loss(params, cfg, batch,
                               ShapeConfig("a", "train", 32, 4, 1, True),
                               None)
    g2, l2, _ = grads_and_loss(params, cfg, batch,
                               ShapeConfig("a", "train", 32, 4, 2, True),
                               None)
    assert abs(float(l1) - float(l2)) < 1e-3
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-3, rtol=3e-2)


def test_lr_schedule_shape():
    opt = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    assert float(lr_schedule(opt, jnp.asarray(0))) < 0.11
    assert abs(float(lr_schedule(opt, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr_schedule(opt, jnp.asarray(100))) <= 0.11


def test_int8_quantization_error_bound():
    x = jax.random.normal(key, (256, 256)) * 3.0
    q, scale = comp.quantize_int8(x)
    err = jnp.abs(comp.dequantize_int8(q, scale) - x)
    assert float(jnp.max(err)) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    """Sum of compressed updates converges to sum of true grads (EF-SGD)."""
    g = jax.random.normal(key, (64,)) * 0.01
    err = jnp.zeros((64,))
    sent = jnp.zeros((64,))
    for _ in range(30):
        q, scale, err = comp.compress_residual(g, err)
        sent = sent + comp.dequantize_int8(q, scale)
    total_true = g * 30
    assert float(jnp.max(jnp.abs(sent + err - total_true))) < 1e-4


def test_optimizer_state_dtypes():
    cfg = get_smoke_config("rwkv6_3b")
    opt = OptConfig()
    params, opt_state = init_train_state(key, cfg, opt)
    for leaf in jax.tree.leaves(opt_state["m"]):
        assert leaf.dtype == jnp.float32
    for leaf in jax.tree.leaves(opt_state["master"]):
        assert leaf.dtype == jnp.float32

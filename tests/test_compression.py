"""Compression-aware tiered staging (`repro.core.compression` +
planner election): codec model, per-tier crossover correctness,
identity-codec regression anchors for every engine, and the
wire-vs-payload accounting split."""
from dataclasses import fields, replace

import numpy as np
import pytest

from conftest import make_fabric
from hypothesis_compat import given, settings, st

from repro.core.api import (CollectiveConfig, NaiveConfig, PipelinedConfig,
                            ReplicatedConfig, StagingClient, StagingSpec,
                            StreamConfig, WanStreamConfig)
from repro.core.collectives import CollectivePlanner
from repro.core.compression import (CODECS, Codec, CompressionConfig,
                                    CompressionStats, resolve_codec)
from repro.core.fabric import BGQ, Fabric
from repro.core.faults import FaultEvent, FaultKind, FaultSchedule
from repro.core.staging import (stage_collective, stage_naive,
                                stage_out, stage_pipelined,
                                stage_replicated)
from repro.core.streaming import stage_stream
from repro.core.telemetry import Tracer, flight_recorder
from repro.core.topology import resolve_topology
from repro.core.wan import stage_wan

MB = 1 << 20
FRAME_LOSSLESS = CODECS["frame-lossless"]
FRAME_FAST = CODECS["frame-fast"]
FRAME_DEEP = CODECS["frame-deep"]


def planner(topology="wan_beamline", constants=BGQ):
    return CollectivePlanner(resolve_topology(topology), constants)


def closed_form_wins(codec, nbytes, bw):
    """The decision inequality, computed independently of the planner."""
    w = codec.compressed_size(nbytes)
    if codec.is_identity or nbytes <= 0 or w >= nbytes:
        return False
    return (nbytes / codec.compress_bw + nbytes / codec.decompress_bw
            + w / bw < nbytes / bw)


# ---------------------------------------------------------------------------
# codec model
# ---------------------------------------------------------------------------

def test_codec_validation():
    with pytest.raises(ValueError, match="ratio"):
        Codec(name="bad", ratio=0.5)
    with pytest.raises(ValueError, match="positive"):
        Codec(name="bad", compress_bw=0.0)
    with pytest.raises(ValueError, match="non-empty"):
        Codec(name="")


def test_compressed_size_deterministic_and_bounded():
    c = FRAME_LOSSLESS
    assert c.compressed_size(0) == 0
    assert c.compressed_size(-5) == 0
    assert c.compressed_size(1) == 1          # headers never vanish
    n = 10 * MB
    w = c.compressed_size(n)
    assert w == c.compressed_size(n)          # pure function
    assert 0 < w < n
    assert w == -(-n // 3.2) or w == int(np.ceil(n / 3.2))


def test_identity_codec_is_free_and_size_preserving():
    ident = CODECS["none"]
    assert ident.is_identity
    assert ident.compressed_size(MB) == MB
    assert ident.compress_time(MB) == 0.0
    assert ident.decompress_time(MB) == 0.0
    assert resolve_codec("none") is None
    assert resolve_codec(None) is None
    assert resolve_codec(ident) is None


def test_config_coercion_and_round_trip():
    cfg = CompressionConfig.coerce("frame-lossless")
    assert cfg.build() == FRAME_LOSSLESS
    assert CompressionConfig.coerce(cfg) is cfg
    assert CompressionConfig.coerce(None).build() is None
    over = CompressionConfig(codec="frame-lossless", ratio=2.0)
    assert over.build().ratio == 2.0
    rebuilt = CompressionConfig(**over.to_dict())
    assert rebuilt == over
    with pytest.raises(ValueError, match="unknown codec"):
        CompressionConfig(codec="zstd-99")
    with pytest.raises(ValueError, match="not registered"):
        CompressionConfig.coerce(Codec(name="adhoc", ratio=2.0))


def test_compression_stats_accounting():
    s = CompressionStats(plans=2, payload_bytes=10, wire_bytes=4,
                         compress_time=1.0, decompress_time=0.5)
    assert s.saved_bytes == 6 and s.wire_ratio == 2.5 and s.codec_time == 1.5
    snap = s.copy()
    s.add(s.copy())
    d = s.delta(snap)
    assert d.plans == 2 and d.payload_bytes == 10
    assert CompressionStats().wire_ratio == 1.0


# ---------------------------------------------------------------------------
# the per-tier election (satellite: crossover correctness)
# ---------------------------------------------------------------------------

def test_election_matches_closed_form_on_every_canned_topology():
    n = MB
    for topo_name in ("flat", "bgq_torus", "tpu_pod_ici_dcn",
                      "wan_beamline"):
        pl = planner(topo_name)
        topo = pl.topology
        tiers = [topo.intra] + ([topo.inter] if topo.inter else [])
        for codec in (FRAME_LOSSLESS, FRAME_FAST, FRAME_DEEP):
            elected = pl.compression_election(codec, n)
            for tier in tiers:
                assert pl.compression_wins(tier, codec, n) \
                    == closed_form_wins(codec, n, pl._bw(tier, 1)) \
                    == (tier.name in elected), (topo_name, codec.name,
                                                tier.name)


def test_default_codec_elects_wan_but_not_cluster_tiers():
    # frame-lossless sits between the 2 GB/s cluster links and the
    # 1.25 GB/s WAN pipe: per-tier election, visible on one topology
    pl = planner("wan_beamline")
    assert pl.compression_election(FRAME_LOSSLESS, MB) == {"wan"}
    assert pl.compression_election(FRAME_FAST, MB) == {"cluster", "wan"}
    assert pl.compression_election(FRAME_DEEP, MB) == frozenset()
    # 50 GB/s ICI: no registered codec can keep up
    fast = planner("tpu_pod_ici_dcn")
    for codec in (FRAME_LOSSLESS, FRAME_FAST, FRAME_DEEP):
        assert fast.compression_election(codec, MB) == frozenset()


def test_election_monotone_in_codec_throughput():
    pl = planner("wan_beamline")
    tier = pl.topology.inter
    prev = False
    for bw in (0.5e9, 1e9, 2e9, 4e9, 8e9, 16e9, 64e9):
        codec = replace(FRAME_LOSSLESS, compress_bw=bw, decompress_bw=2 * bw)
        wins = pl.compression_wins(tier, codec, MB)
        assert wins >= prev      # once it wins, faster codecs keep winning
        prev = wins
    assert prev                  # the fast end does win


def test_election_monotone_in_tier_bandwidth():
    # slower tiers make compression MORE attractive, never less
    prev = True
    for link_bw in (0.5e9, 1.25e9, 2e9, 4e9, 16e9, 50e9):
        topo = resolve_topology("flat").degraded({})
        pl = CollectivePlanner(replace(topo, intra=replace(topo.intra,
                                                           bw=link_bw)), BGQ)
        wins = pl.compression_wins(pl.topology.intra, FRAME_LOSSLESS, MB)
        assert wins <= prev      # once raw wins, faster tiers keep raw
        prev = wins


def test_degraded_tier_flips_election():
    # healthy 2 GB/s cluster tier: frame-lossless ships raw; a brownout
    # to 1 GB/s flips the same tier to compressed
    pl = planner("wan_beamline")
    assert not pl.compression_wins(pl.topology.intra, FRAME_LOSSLESS, MB)
    degraded = CollectivePlanner(
        pl.topology.degraded({"cluster": 0.5}), BGQ)
    assert degraded.compression_wins(degraded.topology.intra,
                                     FRAME_LOSSLESS, MB)
    assert "cluster" in degraded.compression_election(FRAME_LOSSLESS, MB)


def test_partitioned_tier_never_elected():
    pl = planner("wan_beamline")
    dead = CollectivePlanner(pl.topology.degraded({"wan": 0.0}), BGQ)
    assert not dead.compression_wins(dead.topology.inter, FRAME_LOSSLESS, MB)
    assert dead.compression_election(FRAME_LOSSLESS, MB) == frozenset()


def test_fault_schedule_degradation_flips_election_through_fabric():
    # the SAME fabric decision flips when a scheduled brownout halves
    # the cluster tier at plan-issue time
    sched = FaultSchedule([
        FaultEvent(t=10.0, kind=FaultKind.LINK_DEGRADE, tier="cluster",
                   factor=0.5, t_end=20.0)])
    fab = Fabric(n_hosts=8, constants=BGQ, topology="wan_beamline",
                 faults=sched)
    pl_healthy, _ = fab.net._fault_state(0.0, 8)
    pl_brown, _ = fab.net._fault_state(15.0, 8)
    assert not pl_healthy.compression_wins(pl_healthy.topology.intra,
                                           FRAME_LOSSLESS, MB)
    assert pl_brown.compression_wins(pl_brown.topology.intra,
                                     FRAME_LOSSLESS, MB)


@settings(max_examples=60, deadline=None)
@given(nbytes=st.integers(min_value=1, max_value=1 << 28),
       cbw=st.floats(min_value=1e8, max_value=1e11),
       dbw=st.floats(min_value=1e8, max_value=1e11),
       ratio=st.floats(min_value=1.0, max_value=20.0),
       tier_bw=st.floats(min_value=1e8, max_value=1e11))
def test_property_election_iff_inequality(nbytes, cbw, dbw, ratio, tier_bw):
    codec = Codec(name="frame-lossless", compress_bw=cbw,
                  decompress_bw=dbw, ratio=ratio)
    topo = resolve_topology("flat")
    pl = CollectivePlanner(replace(topo, intra=replace(topo.intra,
                                                       bw=tier_bw)), BGQ)
    assert pl.compression_wins(pl.topology.intra, codec, nbytes) \
        == closed_form_wins(codec, nbytes, tier_bw)


# ---------------------------------------------------------------------------
# plans: wire vs payload bytes, codec charges
# ---------------------------------------------------------------------------

def test_plan_reports_wire_and_payload_separately():
    pl = planner("wan_beamline")
    raw = pl.plan_point_to_point(MB, attempts=3)
    cmp_ = pl.plan_point_to_point(MB, attempts=3, codec=FRAME_LOSSLESS)
    w = FRAME_LOSSLESS.compressed_size(MB)
    assert raw.tier_bytes == {"wan": 3 * MB}
    assert cmp_.tier_bytes == {"wan": 3 * w}
    assert cmp_.payload_tier_bytes == {"wan": 3 * MB}
    assert cmp_.payload_bytes == 3 * MB
    assert cmp_.bytes_saved == 3 * (MB - w)
    assert cmp_.compressed_tiers == ("wan",)
    assert cmp_.codec == "frame-lossless"
    # raw plans: payload IS wire
    assert raw.payload_tier_bytes is None
    assert raw.payload_bytes == raw.total_bytes and raw.bytes_saved == 0


def test_p2p_retransmits_resend_compressed_and_charge_codec_once():
    # the sender keeps the compressed buffer: compress is charged once,
    # every attempt re-sends the compressed wire size
    pl = planner("wan_beamline")
    one = pl.plan_point_to_point(MB, attempts=1, codec=FRAME_LOSSLESS)
    three = pl.plan_point_to_point(MB, attempts=3, codec=FRAME_LOSSLESS)
    assert three.compress_time == one.compress_time \
        == FRAME_LOSSLESS.compress_time(MB)
    assert three.decompress_time == one.decompress_time
    wire_step = one.time - one.codec_time
    assert three.time == pytest.approx(3 * wire_step + one.codec_time)
    assert three.total_bytes == 3 * one.total_bytes


def test_compressed_plan_beats_raw_iff_elected():
    pl = planner("wan_beamline")
    # elected on wan: compressed p2p strictly faster
    assert pl.plan_point_to_point(MB, codec=FRAME_LOSSLESS).time \
        < pl.plan_point_to_point(MB).time
    # not elected anywhere: identical to raw, stamped with the codec name
    deep = pl.plan_broadcast(MB, 64, codec=FRAME_DEEP)
    raw = pl.plan_broadcast(MB, 64)
    assert (deep.time, deep.tier_bytes) == (raw.time, raw.tier_bytes)
    assert deep.codec == "frame-deep" and deep.compressed_tiers == ()


def test_elected_but_idle_tier_charges_nothing():
    # frame-lossless elects the wan tier, but a single-rack broadcast
    # never crosses it: the plan must stay EXACTLY the raw plan
    pl = planner("wan_beamline")
    cmp_ = pl.plan_broadcast(MB, 64, codec=FRAME_LOSSLESS)
    raw = pl.plan_broadcast(MB, 64)
    assert (cmp_.time, cmp_.tier_bytes, cmp_.algorithm) \
        == (raw.time, raw.tier_bytes, raw.algorithm)
    assert cmp_.compressed_tiers == ()
    assert cmp_.compress_time == 0.0 and cmp_.decompress_time == 0.0


def test_hierarchical_plans_compound_on_multi_tier_election():
    # frame-fast elects torus AND optical on bgq_torus: hierarchical
    # broadcast wins on both tiers at P=8192
    pl = planner("bgq_torus")
    for P in (1024, 4096, 8192):
        raw = pl.plan_broadcast(8 * MB, P)
        cmp_ = pl.plan_broadcast(8 * MB, P, codec=FRAME_FAST)
        assert cmp_.time < raw.time
        assert set(cmp_.compressed_tiers) == set(cmp_.tier_bytes)
        for tier, wire in cmp_.tier_bytes.items():
            assert wire < cmp_.payload_tier_bytes[tier]


@pytest.mark.parametrize("op,kw", [
    ("plan_broadcast", dict(nbytes=MB, n_hosts=64)),
    ("plan_allgather", dict(shard_bytes=MB // 64, n_hosts=64)),
    ("plan_scatter", dict(total_bytes=MB, n_hosts=64)),
    ("plan_replichain", dict(stripe_bytes=MB // 64, n_hosts=64,
                             replication=3)),
    ("plan_point_to_point", dict(nbytes=MB)),
])
def test_identity_codec_plans_bit_exact(op, kw):
    for topo in ("flat", "bgq_torus", "wan_beamline"):
        pl = planner(topo)
        a = getattr(pl, op)(**kw)
        b = getattr(pl, op)(**kw, codec=resolve_codec("none"))
        assert (a.time, a.tier_bytes, a.algorithm) \
            == (b.time, b.tier_bytes, b.algorithm)
        assert b.compressed_tiers == () and b.payload_tier_bytes is None


# ---------------------------------------------------------------------------
# identity-codec regression anchor: every engine, traced and untraced
# ---------------------------------------------------------------------------

ENGINE_CONFIGS = [
    CollectiveConfig(topology="wan_beamline"),
    PipelinedConfig(topology="wan_beamline", chunk_bytes=1 << 14),
    NaiveConfig(topology="wan_beamline"),
    ReplicatedConfig(topology="wan_beamline", replication=2),
    StreamConfig(topology="wan_beamline", rate_hz=50.0),
    WanStreamConfig(topology="wan_beamline", rate_hz=50.0, loss_rate=0.2,
                    loss_seed=5),
]


def assert_reports_equal(a, b):
    for f in fields(a):
        assert getattr(a, f.name) == getattr(b, f.name), \
            f"{f.name}: {getattr(a, f.name)!r} != {getattr(b, f.name)!r}"


@pytest.mark.parametrize("trace", [False, True], ids=["untraced", "traced"])
@pytest.mark.parametrize("config", ENGINE_CONFIGS,
                         ids=lambda c: type(c).__name__)
def test_identity_codec_engine_anchor(config, trace):
    f1, _ = make_fabric(n_hosts=8, topology="wan_beamline")
    f2, _ = make_fabric(n_hosts=8, topology="wan_beamline")
    r1 = StagingClient(f1, trace=trace).stage("d/*.bin", config)
    cfg_none = replace(config, compression="none")
    assert cfg_none.compression == CompressionConfig()
    r2 = StagingClient(f2, trace=trace).stage("d/*.bin", cfg_none)
    assert r1.total_time == r2.total_time
    assert (r1.net_bytes, r1.fs_bytes) == (r2.net_bytes, r2.fs_bytes)
    assert_reports_equal(r1.reports[0], r2.reports[0])
    assert r2.bytes_saved == 0 and r2.comp.plans == 0
    assert r1.accounting_closes() and r2.accounting_closes()
    for h1, h2 in zip(f1.hosts, f2.hosts):
        assert set(h1.store.data) == set(h2.store.data)
        for p in h1.store.data:
            assert np.array_equal(h1.store.data[p], h2.store.data[p])


@pytest.mark.parametrize("trace", [False, True], ids=["untraced", "traced"])
def test_identity_codec_stage_out_anchor(trace):
    f1, _ = make_fabric(n_hosts=8)
    f2, _ = make_fabric(n_hosts=8)
    if trace:
        f1.attach_tracer(Tracer())
        f2.attach_tracer(Tracer())
    out = {"results/r.bin": np.arange(1 << 12, dtype=np.uint8)}
    ra, ta = stage_out(f1, out)
    rb, tb = stage_out(f2, out, compression="none")
    assert ta == tb
    assert_reports_equal(ra, rb)


def test_traced_compressed_run_matches_untraced_arithmetic():
    cfg = WanStreamConfig(topology="wan_beamline", rate_hz=50.0,
                          loss_rate=0.2, loss_seed=5,
                          compression="frame-lossless")
    f1, _ = make_fabric(n_hosts=8, topology="wan_beamline")
    f2, _ = make_fabric(n_hosts=8, topology="wan_beamline")
    r1 = StagingClient(f1, trace=False).stage("d/*.bin", cfg)
    client2 = StagingClient(f2, trace=True)
    r2 = client2.stage("d/*.bin", cfg)
    assert r1.total_time == r2.total_time
    assert_reports_equal(r1.reports[0], r2.reports[0])
    names = {s.name for s in f2.tracer.spans}
    assert "comp.compress" in names and "comp.decompress" in names
    assert "compression:" in flight_recorder(f2.tracer)


# ---------------------------------------------------------------------------
# wire vs payload through the engines (satellite: accounting split)
# ---------------------------------------------------------------------------

def test_wan_engine_wire_bytes_shrink_but_payload_stays():
    def run(compression):
        fab, paths = make_fabric(n_hosts=8, n_files=6,
                                 topology="wan_beamline")
        rep = StagingClient(fab).stage(
            "d/*.bin", WanStreamConfig(topology="wan_beamline",
                                       loss_rate=0.2, loss_seed=5,
                                       compression=compression))
        return rep

    raw, cmp_ = run(None), run("frame-lossless")
    # logical delivery is untouched
    assert cmp_.total_bytes == raw.total_bytes
    assert cmp_.delivered_bytes == raw.delivered_bytes
    # the wan tier shrinks by the codec ratio; cluster tiers stay raw
    rw, cw = raw.reports[0], cmp_.reports[0]
    assert cw.tier_bytes["wan"] < rw.tier_bytes["wan"]
    assert rw.tier_bytes["wan"] == 3.2 * cw.tier_bytes["wan"] \
        or rw.tier_bytes["wan"] <= 3.2 * cw.tier_bytes["wan"] + 8
    assert cw.tier_bytes["cluster"] == rw.tier_bytes["cluster"]
    # reconciliation: wire + saved == the raw wire
    assert cmp_.payload_net_bytes == raw.net_bytes
    assert cmp_.bytes_saved == cmp_.comp.saved_bytes > 0
    assert cmp_.accounting_closes() and raw.accounting_closes()
    # the WAN-side counter is wire too
    assert cw.wan.wan_bytes == cw.comp.wire_bytes
    assert cw.comp.wire_ratio == pytest.approx(3.2, rel=1e-3)


def test_collective_engine_compresses_on_degraded_cluster():
    # healthy 2 GB/s links ship raw; a scheduled brownout makes the
    # SAME staged dataset ship compressed (and still land byte-exact)
    def run(faults):
        sched = FaultSchedule([
            FaultEvent(t=0.0, kind=FaultKind.LINK_DEGRADE, tier="cluster",
                       factor=0.5, t_end=1e9)]) if faults else None
        fab, paths = make_fabric(n_hosts=8, topology="wan_beamline",
                                 faults=sched)
        rep, _ = stage_collective(fab, paths, t0=0.0,
                                  compression="frame-lossless")
        return rep, fab

    healthy, _ = run(False)
    brown, fab = run(True)
    assert healthy.comp.plans == 0 and healthy.comp.saved_bytes == 0
    assert brown.comp.plans > 0 and brown.comp.saved_bytes > 0
    assert brown.total_bytes == healthy.total_bytes


def test_stream_stager_compression_threads_through_client():
    fab, _ = make_fabric(n_hosts=8, topology="wan_beamline")
    stager = StagingClient(fab).stream_stager(
        StreamConfig(window_bytes=1 << 30, topology="wan_beamline",
                     compression="frame-lossless"))
    rng = np.random.default_rng(0)
    for i in range(4):
        stager.ingest(f"s/f{i}", rng.integers(0, 255, 1 << 12,
                                              dtype=np.uint8), float(i))
    rep = stager.finish()
    # each frame's detector->leader ingest hop crosses the wan tier and
    # ships compressed; the single-rack delivery broadcasts stay raw
    assert rep.comp.plans == 4
    assert rep.comp.wire_ratio == pytest.approx(3.2, rel=1e-3)
    assert rep.comp.saved_bytes == rep.comp.payload_bytes \
        - rep.comp.wire_bytes > 0


def test_replicated_engine_identity_and_compressed_paths():
    f1, p1 = make_fabric(n_hosts=8, topology="bgq_torus")
    f2, _ = make_fabric(n_hosts=8, topology="bgq_torus")
    ra, _ = stage_replicated(f1, p1, replication=3)
    rb, _ = stage_replicated(f2, p1, replication=3,
                             compression="frame-fast")
    assert rb.total_bytes == ra.total_bytes
    assert rb.net_bytes < ra.net_bytes           # torus tier elected
    assert rb.comp.saved_bytes == ra.net_bytes - rb.net_bytes

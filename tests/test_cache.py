"""Node-local store + application-memory cache (paper §VI-B)."""
import numpy as np

from repro.core.cache import TaskInputCache
from repro.core.fabric import BGQ, Fabric, NodeLocalStore


def test_store_pin_survives_eviction():
    store = NodeLocalStore(0, BGQ)
    store.write("a", np.ones(1000, np.uint8), 0.0)
    store.write("b", np.ones(1000, np.uint8), 0.0)
    store.pin("a")
    store.evict_lru(budget_bytes=1200)
    assert "a" in store.data and "b" not in store.data


def test_task_input_cache_second_read_free():
    """'HEDM tasks after the first do not need to perform Read operations'."""
    store = NodeLocalStore(0, BGQ)
    store.write("x", np.ones(1 << 20, np.uint8), 0.0)
    cache = TaskInputCache(store)
    cache.get("x")
    t1 = cache.read_time_charged
    cache.get("x")
    assert cache.read_time_charged == t1        # no extra cost
    assert cache.hits == 1 and cache.misses == 1


def test_task_input_cache_capacity_eviction():
    store = NodeLocalStore(0, BGQ)
    for name in "abc":
        store.write(name, np.ones(600, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=1000)
    cache.get("a"); cache.get("b"); cache.get("c")
    assert cache.resident_bytes <= 1000

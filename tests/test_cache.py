"""Node-local store + application-memory cache (paper §VI-B)."""
import numpy as np

from repro.core.cache import TaskInputCache
from repro.core.fabric import BGQ, Fabric, NodeLocalStore


def test_store_pin_survives_eviction():
    store = NodeLocalStore(0, BGQ)
    store.write("a", np.ones(1000, np.uint8), 0.0)
    store.write("b", np.ones(1000, np.uint8), 0.0)
    store.pin("a")
    store.evict_lru(budget_bytes=1200)
    assert "a" in store.data and "b" not in store.data


def test_task_input_cache_second_read_free():
    """'HEDM tasks after the first do not need to perform Read operations'."""
    store = NodeLocalStore(0, BGQ)
    store.write("x", np.ones(1 << 20, np.uint8), 0.0)
    cache = TaskInputCache(store)
    cache.get("x")
    t1 = cache.read_time_charged
    cache.get("x")
    assert cache.read_time_charged == t1        # no extra cost
    assert cache.hits == 1 and cache.misses == 1


def test_task_input_cache_capacity_eviction():
    store = NodeLocalStore(0, BGQ)
    for name in "abc":
        store.write(name, np.ones(600, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=1000)
    cache.get("a"); cache.get("b"); cache.get("c")
    assert cache.resident_bytes <= 1000


def test_task_input_cache_fifo_eviction_order():
    """Capacity-bounded FIFO: the OLDEST entries evict first, and an
    evicted entry faults back in as a fresh miss."""
    store = NodeLocalStore(0, BGQ)
    for name in "abcd":
        store.write(name, np.ones(400, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=1000)
    cache.get("a"); cache.get("b")
    cache.get("c")                        # evicts a (oldest), keeps b, c
    assert set(cache._mem) == {"b", "c"}
    cache.get("d")                        # evicts b
    assert set(cache._mem) == {"c", "d"}
    assert cache.misses == 4 and cache.hits == 0
    cache.get("a")                        # re-fault: a miss again
    assert cache.misses == 5


def test_task_input_cache_deserialize_called_once_per_miss():
    store = NodeLocalStore(0, BGQ)
    store.write("x", np.arange(256, dtype=np.uint8), 0.0)
    calls = []

    def parse(raw):
        calls.append(raw.size)
        return raw.astype(np.float64)

    cache = TaskInputCache(store)
    v1 = cache.get("x", parse)
    v2 = cache.get("x", parse)
    v3 = cache.get("x", parse)
    assert len(calls) == 1                # parsed once, on the faulting miss
    assert v1 is v2 is v3                 # the deserialized object is shared
    assert v1.dtype == np.float64
    # a miss for an absent path deserializes nothing
    assert cache.get("nope", parse) is None
    assert len(calls) == 1


def test_task_input_cache_read_time_charged_accounting():
    """Misses charge size / local_read_bw simulated seconds; hits and
    absent paths charge nothing."""
    store = NodeLocalStore(0, BGQ)
    store.write("x", np.ones(1 << 20, np.uint8), 0.0)
    store.write("y", np.ones(1 << 19, np.uint8), 0.0)
    cache = TaskInputCache(store)
    assert cache.get("nope") is None
    assert cache.read_time_charged == 0.0
    cache.get("x")
    expect_x = (1 << 20) / BGQ.local_read_bw
    assert cache.read_time_charged == expect_x
    cache.get("x")                        # hit: free
    assert cache.read_time_charged == expect_x
    cache.get("y")
    assert cache.read_time_charged == \
        expect_x + (1 << 19) / BGQ.local_read_bw
    assert cache.misses == 2 and cache.hits == 1


def test_task_input_cache_pin_survives_capacity_eviction():
    """Lease-aware pinning: pinned entries are exempt from FIFO eviction
    until the last holder unpins."""
    store = NodeLocalStore(0, BGQ)
    for name in "abc":
        store.write(name, np.ones(400, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=900)
    cache.get("a")
    cache.pin("a")
    cache.pin("a")
    cache.get("b")
    cache.get("c")                        # would evict a; must take b
    assert "a" in cache._mem and "b" not in cache._mem
    cache.unpin("a")
    cache.get("b")                        # still pinned by one holder
    assert "a" in cache._mem
    cache.unpin("a")
    store.write("d", np.ones(400, np.uint8), 0.0)
    cache.get("d")                        # now a is the FIFO victim
    assert "a" not in cache._mem


def test_task_input_cache_eviction_sweep_is_linear():
    """The capacity sweep walks the FIFO ONCE per put (the seed restarted
    the victim scan per eviction — O(n^2) on a cold cache of small
    entries): evicting k victims must not re-visit survivors."""
    store = NodeLocalStore(0, BGQ)
    n = 2000
    for i in range(n):
        store.write(f"f{i}", np.ones(10, np.uint8), 0.0)
    store.write("big", np.ones(10 * n, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=10 * n + 5)
    for i in range(n):
        cache.get(f"f{i}")

    sweeps = {"n": 0}

    class CountingPins(dict):
        def __contains__(self, key):
            sweeps["n"] += 1
            return super().__contains__(key)

    cache._pins = CountingPins()
    cache.get("big")                      # must evict all n small entries
    assert "big" in cache._mem
    assert cache.resident_bytes <= 10 * n + 5
    # one ordered sweep: ~n membership probes, not O(n^2)
    assert sweeps["n"] <= n + 1


def test_task_input_cache_drop_mirrors_store_drop_semantics():
    """drop() takes the entry AND its pin refs with it, exactly like
    NodeLocalStore.drop — a re-faulted copy starts unpinned."""
    store = NodeLocalStore(0, BGQ)
    store.write("a", np.ones(400, np.uint8), 0.0)
    store.write("b", np.ones(400, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=900)
    cache.get("a")
    cache.pin("a")
    cache.pin("a")
    cache.drop("a")
    assert "a" not in cache._mem and "a" not in cache._pins
    # re-faulted copy is unpinned: it evicts like any FIFO entry
    cache.get("a")
    cache.get("b")
    store.write("c", np.ones(400, np.uint8), 0.0)
    cache.get("c")
    assert "a" not in cache._mem


def test_task_input_cache_clears_stale_pin_after_forced_store_drop():
    """A previously resident, pinned path force-dropped via the backing
    store must not keep a stale pin: the next (missing-everywhere) lookup
    clears it, so a later re-staged copy is NOT shielded from eviction by
    the dead lease."""
    store = NodeLocalStore(0, BGQ)
    store.write("a", np.ones(400, np.uint8), 0.0)
    store.write("x", np.ones(600, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=900)
    cache.get("a")                        # faulted in (resident here once)
    cache.get("x")                        # capacity-evicts unpinned a
    assert "a" not in cache._mem
    cache.pin("a")                        # holder pins for reuse...
    store.drop("a")                       # ...but the store force-drops it
    assert cache.get("a") is None         # resident nowhere
    assert "a" not in cache._pins         # stale pin cleared
    # the re-staged copy behaves as unpinned
    store.write("a", np.ones(400, np.uint8), 0.0)
    store.write("b", np.ones(400, np.uint8), 0.0)
    cache2 = TaskInputCache(store, capacity_bytes=900)
    cache2.get("a"); cache2.get("x")
    assert "a" not in cache2._mem         # FIFO victim, not shielded


def test_task_input_cache_pin_ahead_of_first_fault_survives():
    """Pinning a path BEFORE it is ever staged is live intent, not a
    stale pin: probing get()s while the path is absent must not destroy
    the refcount, and the eventual fault-in lands pinned."""
    store = NodeLocalStore(0, BGQ)
    cache = TaskInputCache(store, capacity_bytes=900)
    cache.pin("a")
    assert cache.get("a") is None         # not staged yet — probe
    assert cache.get("a") is None
    assert cache._pins.get("a") == 1      # refcount intact
    store.write("a", np.ones(400, np.uint8), 0.0)
    store.write("b", np.ones(400, np.uint8), 0.0)
    store.write("c", np.ones(400, np.uint8), 0.0)
    cache.get("a"); cache.get("b"); cache.get("c")
    assert "a" in cache._mem              # pinned: b was the FIFO victim
    assert "b" not in cache._mem

"""Node-local store + application-memory cache (paper §VI-B)."""
import numpy as np

from repro.core.cache import TaskInputCache
from repro.core.fabric import BGQ, Fabric, NodeLocalStore


def test_store_pin_survives_eviction():
    store = NodeLocalStore(0, BGQ)
    store.write("a", np.ones(1000, np.uint8), 0.0)
    store.write("b", np.ones(1000, np.uint8), 0.0)
    store.pin("a")
    store.evict_lru(budget_bytes=1200)
    assert "a" in store.data and "b" not in store.data


def test_task_input_cache_second_read_free():
    """'HEDM tasks after the first do not need to perform Read operations'."""
    store = NodeLocalStore(0, BGQ)
    store.write("x", np.ones(1 << 20, np.uint8), 0.0)
    cache = TaskInputCache(store)
    cache.get("x")
    t1 = cache.read_time_charged
    cache.get("x")
    assert cache.read_time_charged == t1        # no extra cost
    assert cache.hits == 1 and cache.misses == 1


def test_task_input_cache_capacity_eviction():
    store = NodeLocalStore(0, BGQ)
    for name in "abc":
        store.write(name, np.ones(600, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=1000)
    cache.get("a"); cache.get("b"); cache.get("c")
    assert cache.resident_bytes <= 1000


def test_task_input_cache_fifo_eviction_order():
    """Capacity-bounded FIFO: the OLDEST entries evict first, and an
    evicted entry faults back in as a fresh miss."""
    store = NodeLocalStore(0, BGQ)
    for name in "abcd":
        store.write(name, np.ones(400, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=1000)
    cache.get("a"); cache.get("b")
    cache.get("c")                        # evicts a (oldest), keeps b, c
    assert set(cache._mem) == {"b", "c"}
    cache.get("d")                        # evicts b
    assert set(cache._mem) == {"c", "d"}
    assert cache.misses == 4 and cache.hits == 0
    cache.get("a")                        # re-fault: a miss again
    assert cache.misses == 5


def test_task_input_cache_deserialize_called_once_per_miss():
    store = NodeLocalStore(0, BGQ)
    store.write("x", np.arange(256, dtype=np.uint8), 0.0)
    calls = []

    def parse(raw):
        calls.append(raw.size)
        return raw.astype(np.float64)

    cache = TaskInputCache(store)
    v1 = cache.get("x", parse)
    v2 = cache.get("x", parse)
    v3 = cache.get("x", parse)
    assert len(calls) == 1                # parsed once, on the faulting miss
    assert v1 is v2 is v3                 # the deserialized object is shared
    assert v1.dtype == np.float64
    # a miss for an absent path deserializes nothing
    assert cache.get("nope", parse) is None
    assert len(calls) == 1


def test_task_input_cache_read_time_charged_accounting():
    """Misses charge size / local_read_bw simulated seconds; hits and
    absent paths charge nothing."""
    store = NodeLocalStore(0, BGQ)
    store.write("x", np.ones(1 << 20, np.uint8), 0.0)
    store.write("y", np.ones(1 << 19, np.uint8), 0.0)
    cache = TaskInputCache(store)
    assert cache.get("nope") is None
    assert cache.read_time_charged == 0.0
    cache.get("x")
    expect_x = (1 << 20) / BGQ.local_read_bw
    assert cache.read_time_charged == expect_x
    cache.get("x")                        # hit: free
    assert cache.read_time_charged == expect_x
    cache.get("y")
    assert cache.read_time_charged == \
        expect_x + (1 << 19) / BGQ.local_read_bw
    assert cache.misses == 2 and cache.hits == 1


def test_task_input_cache_pin_survives_capacity_eviction():
    """Lease-aware pinning: pinned entries are exempt from FIFO eviction
    until the last holder unpins."""
    store = NodeLocalStore(0, BGQ)
    for name in "abc":
        store.write(name, np.ones(400, np.uint8), 0.0)
    cache = TaskInputCache(store, capacity_bytes=900)
    cache.get("a")
    cache.pin("a")
    cache.pin("a")
    cache.get("b")
    cache.get("c")                        # would evict a; must take b
    assert "a" in cache._mem and "b" not in cache._mem
    cache.unpin("a")
    cache.get("b")                        # still pinned by one holder
    assert "a" in cache._mem
    cache.unpin("a")
    store.write("d", np.ones(400, np.uint8), 0.0)
    cache.get("d")                        # now a is the FIFO victim
    assert "a" not in cache._mem

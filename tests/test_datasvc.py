"""Dataset catalog + staging service: lifecycle, leases, coalescing,
cost-aware eviction, queued admission, and collective write-back."""
import numpy as np
import pytest
from conftest import make_service

from repro.core.datasvc import (AnalysisSession, DataCatalog, DatasetEntry,
                                DatasetState, StagingService,
                                predict_stage_time)
from repro.core.fabric import BGQ, Fabric
from repro.core.iohook import BroadcastEntry, StagingSpec, run_io_hook
from repro.core.staging import stage_out, stage_out_naive


# ---------------------------------------------------------------------------
# lifecycle + catalog
# ---------------------------------------------------------------------------

def test_lifecycle_states_and_history():
    fab, svc = make_service()
    entry = svc.catalog["d0"]
    assert entry.state is DatasetState.REGISTERED
    lease = svc.acquire("alice", "d0", 0.0)
    assert entry.state is DatasetState.RESIDENT
    assert lease.t_ready > 0.0 and entry.t_ready == lease.t_ready
    # during the stage window the observable state is STAGING
    assert entry.state_at(lease.t_ready / 2) is DatasetState.STAGING
    assert entry.state_at(lease.t_ready) is DatasetState.RESIDENT
    svc.release("alice", "d0", 1.0)
    # force eviction: fill the budget past d0
    svc.acquire("alice", "d1", 2.0)
    svc.acquire("alice", "d2", 3.0)
    assert entry.state is DatasetState.GONE
    states = [s for _, s in entry.history]
    assert states == [DatasetState.REGISTERED, DatasetState.STAGING,
                      DatasetState.RESIDENT, DatasetState.EVICTING,
                      DatasetState.GONE]


def test_illegal_transition_raises():
    entry = DatasetEntry(name="x", paths=["p"], nbytes=1)
    with pytest.raises(RuntimeError, match="illegal dataset transition"):
        entry.to_state(DatasetState.RESIDENT, 0.0)   # REGISTERED -> RESIDENT


def test_catalog_unknown_dataset_loud():
    fab, svc = make_service()
    with pytest.raises(KeyError, match="unknown dataset"):
        svc.acquire("alice", "nope", 0.0)


def test_register_idempotent_and_validating():
    fab, svc = make_service()
    entry, _ = svc.register("d0", paths=["d0/f0.bin"])     # re-register
    assert entry is svc.catalog["d0"] and len(entry.paths) == 4
    with pytest.raises(ValueError, match="exactly one of"):
        svc.register("x", patterns=["*"], paths=["p"])
    with pytest.raises(ValueError, match="no files"):
        svc.register("x", patterns=["nomatch/*"])
    big = np.zeros(svc.budget_bytes + 1, np.uint8)
    fab.fs.put("big.bin", big)
    with pytest.raises(ValueError, match="exceeds the service budget"):
        svc.register("big", paths=["big.bin"])


def test_register_patterns_charges_metadata_and_broadcast():
    fab, svc = make_service()
    del svc  # fresh service so stats start at zero
    svc2 = StagingService(fab, budget_bytes=1 << 20)
    _, t_done = svc2.register("g", patterns=["d0/f*.bin"], t=0.0)
    assert t_done > 0.0
    assert svc2.stats.metadata_time > 0.0
    assert svc2.stats.broadcast_time > 0.0
    assert svc2.stats.metadata_time + svc2.stats.broadcast_time == \
        pytest.approx(t_done)


# ---------------------------------------------------------------------------
# coalescing + residency
# ---------------------------------------------------------------------------

def test_concurrent_acquires_coalesce_into_one_stage():
    fab, svc = make_service()
    l1 = svc.acquire("alice", "d0", 0.0)
    fs_bytes = fab.fs.bytes_read
    l2 = svc.acquire("bob", "d0", l1.t_ready / 2)    # inside stage window
    assert fab.fs.bytes_read == fs_bytes             # no second stage
    assert l2.t_ready == l1.t_ready                  # shares completion
    assert svc.stats.stages == 1 and svc.stats.coalesced == 1
    entry = svc.catalog["d0"]
    assert entry.stage_count == 1 and entry.coalesced == 1


def test_resident_acquire_is_a_hit():
    fab, svc = make_service()
    l1 = svc.acquire("alice", "d0", 0.0)
    l2 = svc.acquire("bob", "d0", l1.t_ready + 5.0)
    assert l2.t_ready == l1.t_ready + 5.0            # immediate
    assert svc.stats.hits == 1 and svc.stats.stages == 1


def test_staged_replicas_byte_exact_on_every_host():
    fab, svc = make_service(n_hosts=5)
    svc.acquire("alice", "d0", 0.0)
    for host in fab.hosts:
        for p in svc.catalog["d0"].paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])


# ---------------------------------------------------------------------------
# eviction + admission queue
# ---------------------------------------------------------------------------

def test_eviction_prefers_cheapest_restage():
    # d0 = 2 files, d1 = 6 files (more bytes -> costlier to re-stage);
    # budget fits both plus nothing else
    fab, svc = make_service(sizes=(2, 6, 4), budget_files=8)
    svc.acquire("alice", "d0", 0.0)
    svc.acquire("alice", "d1", 0.0)
    svc.release("alice", "d0", 1.0)
    svc.release("alice", "d1", 1.0)
    assert predict_stage_time(fab, svc.catalog["d0"].nbytes, 2) < \
        predict_stage_time(fab, svc.catalog["d1"].nbytes, 6)
    svc.acquire("bob", "d2", 2.0)        # needs 4 files of room
    assert svc.catalog["d0"].state is DatasetState.GONE   # cheapest went
    assert svc.catalog["d1"].state is DatasetState.GONE   # still short: next
    assert svc.catalog["d2"].state is DatasetState.RESIDENT
    assert svc.stats.evictions == 2


def test_eviction_spares_larger_dataset_when_small_frees_enough():
    # budget 9, d0=2, d1=6; acquiring d2 (2 files) only needs the small one
    fab, svc = make_service(sizes=(2, 6, 2), budget_files=9)
    svc.acquire("alice", "d0", 0.0)
    svc.acquire("alice", "d1", 0.0)
    svc.release("alice", "d0", 1.0)
    svc.release("alice", "d1", 1.0)
    svc.acquire("bob", "d2", 2.0)
    assert svc.catalog["d0"].state is DatasetState.GONE
    assert svc.catalog["d1"].state is DatasetState.RESIDENT   # spared
    assert svc.stats.evictions == 1


def test_leased_datasets_never_evict():
    fab, svc = make_service(sizes=(4, 4, 4), budget_files=8)
    svc.acquire("alice", "d0", 0.0)          # leased, never released
    svc.acquire("alice", "d1", 0.0)
    svc.release("alice", "d1", 1.0)
    svc.acquire("bob", "d2", 2.0)            # must evict d1, not d0
    assert svc.catalog["d0"].state is DatasetState.RESIDENT
    assert svc.catalog["d1"].state is DatasetState.GONE


def test_admission_queues_on_future_release():
    fab, svc = make_service(sizes=(4, 4, 4), budget_files=8)
    svc.acquire("alice", "d0", 0.0)
    svc.acquire("alice", "d1", 0.0)
    svc.release("alice", "d0", 10.0)         # frees in the future
    svc.release("alice", "d1", 20.0)
    lease = svc.acquire("bob", "d2", 2.0)    # queued until t=10
    assert lease.t_ready >= 10.0
    assert svc.stats.queue_waits == 1
    assert svc.stats.queue_wait_time == pytest.approx(8.0)
    # the EARLIEST release is taken, not the cheapest dataset
    assert svc.catalog["d0"].state is DatasetState.GONE
    assert svc.catalog["d1"].state is DatasetState.RESIDENT


def test_admission_wedges_loudly_when_all_leased():
    fab, svc = make_service(sizes=(4, 4, 4), budget_files=8)
    svc.acquire("alice", "d0", 0.0)
    svc.acquire("bob", "d1", 0.0)
    with pytest.raises(RuntimeError, match="wedged"):
        svc.acquire("carol", "d2", 1.0)


def test_transparent_restage_on_miss_is_byte_exact():
    fab, svc = make_service(sizes=(4, 4, 4), budget_files=8)
    svc.acquire("alice", "d0", 0.0)
    svc.release("alice", "d0", 1.0)
    svc.acquire("alice", "d1", 2.0)
    svc.acquire("alice", "d2", 3.0)          # evicts d0
    assert svc.catalog["d0"].state is DatasetState.GONE
    svc.release("alice", "d1", 4.0)
    lease = svc.acquire("bob", "d0", 5.0)    # transparent re-stage
    assert svc.stats.restages == 1
    assert svc.catalog["d0"].stage_count == 2
    assert lease.t_ready > 5.0               # paid a real stage
    for host in fab.hosts:
        for p in svc.catalog["d0"].paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])


def test_release_without_lease_raises():
    fab, svc = make_service()
    with pytest.raises(RuntimeError, match="holds no lease"):
        svc.release("alice", "d0", 0.0)


def _degraded_ranking_service():
    """Two unleased residents whose restage-cost ranking FLIPS inside a
    link-degradation window: dA is one big file (comm-heavy — cheapest on
    a healthy fabric, costliest at 5% link bandwidth), dB is two tiny
    files (overhead-heavy — its cost barely moves). Budget forces exactly
    one eviction when dC arrives."""
    from repro.core.fabric import BGQ, Fabric
    from repro.core.datasvc import StagingService
    fab = Fabric(n_hosts=8, constants=BGQ)
    rng = np.random.default_rng(0)
    a_bytes, b_bytes, c_bytes = 4 << 20, 1024, 1 << 16
    fab.fs.put("dA/f0.bin", rng.integers(0, 255, a_bytes, dtype=np.uint8))
    for i in range(2):
        fab.fs.put(f"dB/f{i}.bin",
                   rng.integers(0, 255, b_bytes, dtype=np.uint8))
    fab.fs.put("dC/f0.bin", rng.integers(0, 255, c_bytes, dtype=np.uint8))
    # fits dA+dB, and fits dC after evicting EITHER of them — so the
    # victim choice is purely the cost ranking's
    svc = StagingService(fab, budget_bytes=a_bytes + 2 * b_bytes + c_bytes - 1)
    svc.register("dA", paths=["dA/f0.bin"])
    svc.register("dB", paths=["dB/f0.bin", "dB/f1.bin"])
    svc.register("dC", paths=["dC/f0.bin"])
    return fab, svc


def test_predict_stage_time_tracks_degraded_timeline():
    """`predict_stage_time(..., t=)` must price the candidate under the
    fault-schedule state AT `t` (degraded tiers), not the healthy
    registration-time fabric; the trivial schedule ignores `t` exactly."""
    fab, svc = _degraded_ranking_service()
    a, b = svc.catalog["dA"], svc.catalog["dB"]
    # trivial schedule: t is inert — bit-exact with the no-t prediction
    assert predict_stage_time(fab, a.nbytes, 1, t=10.0) == \
        predict_stage_time(fab, a.nbytes, 1)
    healthy_a = predict_stage_time(fab, a.nbytes, 1)
    healthy_b = predict_stage_time(fab, b.nbytes, 2)
    assert healthy_a < healthy_b                 # big file is cheap when fast
    fab.degrade_tier("link", 5.0, 50.0, 0.05)
    in_window_a = predict_stage_time(fab, a.nbytes, 1, t=10.0)
    in_window_b = predict_stage_time(fab, b.nbytes, 2, t=10.0)
    assert in_window_a > in_window_b             # ranking flips at 5% links
    # outside the window the healthy ranking is restored
    assert predict_stage_time(fab, a.nbytes, 1, t=60.0) == healthy_a


def test_eviction_ranking_uses_current_timeline_state():
    """Regression (latent serial-clock assumption): the eviction victim
    must be the dataset cheapest to re-stage under the CURRENT timeline
    state at admission time. Inside a 5%-bandwidth link-degradation
    window the comm-heavy big dataset dA is the expensive one, so the
    service must evict dB — the healthy registration-time ranking would
    wrongly evict dA."""
    fab, svc = _degraded_ranking_service()
    svc.acquire("alice", "dA", 0.0)
    svc.acquire("alice", "dB", 0.0)
    svc.release("alice", "dA", 1.0)
    svc.release("alice", "dB", 1.0)
    fab.degrade_tier("link", 5.0, 50.0, 0.05)
    svc.acquire("bob", "dC", 10.0)               # one eviction, in-window
    assert svc.stats.evictions == 1
    assert svc.catalog["dB"].state is DatasetState.GONE
    assert svc.catalog["dA"].state is DatasetState.RESIDENT


# ---------------------------------------------------------------------------
# session context manager (lease auto-release)
# ---------------------------------------------------------------------------

def test_session_context_manager_releases_held_leases():
    fab, svc = make_service()
    with svc.session("alice") as sess:
        l0 = sess.acquire("d0", 0.0)
        sess.acquire("d0", l0.t_ready + 1.0)         # two holds, same dataset
        sess.acquire("d1", l0.t_ready + 2.0)
        assert sess.held() == {"d0": 2, "d1": 1}
    assert svc.catalog["d0"].lease_count == 0
    assert svc.catalog["d1"].lease_count == 0
    # released at the last-observed simulated time, not before
    assert svc.catalog["d1"].t_unleased >= l0.t_ready + 2.0


def test_session_exit_under_exception_still_releases():
    fab, svc = make_service()
    with pytest.raises(RuntimeError, match="boom"):
        with svc.session("alice") as sess:
            sess.acquire("d0", 0.0)
            raise RuntimeError("boom")
    entry = svc.catalog["d0"]
    assert entry.lease_count == 0
    # the store pins went with the lease: the dataset is evictable again
    svc.acquire("bob", "d1", 100.0)
    svc.acquire("bob", "d2", 101.0)                  # forces d0 out
    assert entry.state is DatasetState.GONE


def test_session_close_caller_supplied_time_and_idempotence():
    fab, svc = make_service()
    sess = svc.session("alice")
    sess.acquire("d0", 0.0)
    sess.close(t=42.0)
    assert svc.catalog["d0"].lease_count == 0
    assert svc.catalog["d0"].t_unleased == 42.0
    sess.close()                                     # idempotent: no raise
    # explicit releases inside the scope leave nothing for __exit__
    with svc.session("bob") as bob:
        lease = bob.acquire("d0", 50.0)
        bob.release("d0", lease.t_ready)
    assert svc.catalog["d0"].lease_count == 0


# ---------------------------------------------------------------------------
# lease-aware pinning
# ---------------------------------------------------------------------------

def test_leases_pin_replicas_in_node_stores():
    fab, svc = make_service()
    svc.acquire("alice", "d0", 0.0)
    svc.acquire("bob", "d0", 1.0)
    store = fab.hosts[0].store
    p = svc.catalog["d0"].paths[0]
    assert p in store.pinned
    store.evict_lru(0)                       # leased data survives any budget
    assert p in store.data
    svc.release("alice", "d0", 2.0)
    assert p in store.pinned                 # bob still holds it
    svc.release("bob", "d0", 3.0)
    assert p not in store.pinned             # last lease unpins


def test_store_pin_refcounts():
    from repro.core.fabric import NodeLocalStore
    store = NodeLocalStore(0, BGQ)
    store.write("a", np.ones(100, np.uint8), 0.0)
    store.pin("a")
    store.pin("a")
    store.unpin("a")
    store.evict_lru(0)
    assert "a" in store.data                 # one holder left
    store.unpin("a")
    store.evict_lru(0)
    assert "a" not in store.data
    store.unpin("a")                         # no-op, never raises


def test_stream_stager_pin_refcounts():
    from repro.core.streaming import StreamStager
    fab = Fabric(n_hosts=2, constants=BGQ)
    stager = StreamStager(fab, window_bytes=300)
    rec = stager.ingest("f0", np.ones(100, np.uint8), 0.0)
    stager.pin("f0")
    stager.pin("f0")
    stager.release("f0", rec.t_avail)
    stager.unpin("f0")
    for i, t in (("f1", 1.0), ("f2", 2.0)):
        r = stager.ingest(i, np.ones(100, np.uint8), t)
        stager.release(i, r.t_avail)
    # still one pin holder: f0 must survive the window squeeze
    r3 = stager.ingest("f3", np.ones(100, np.uint8), 3.0)
    stager.release("f3", r3.t_avail)
    assert "f0" in stager._resident
    stager.unpin("f0")
    stager.ingest("f4", np.ones(100, np.uint8), 4.0)
    assert "f0" not in stager._resident      # evictable once fully unpinned


def test_stream_window_eviction_respects_foreign_store_pins():
    """A frame pinned in the node-local stores by ANOTHER holder (e.g. a
    dataset-service lease on the same paths) must survive window
    eviction even though the stager itself never pinned it."""
    from repro.core.streaming import StreamStager
    fab = Fabric(n_hosts=2, constants=BGQ)
    stager = StreamStager(fab, window_bytes=300)
    r0 = stager.ingest("f0", np.ones(100, np.uint8), 0.0)
    stager.release("f0", r0.t_avail)
    for host in fab.hosts:                   # foreign holder pins f0
        host.store.pin("f0")
    for i, t in (("f1", 1.0), ("f2", 2.0)):
        r = stager.ingest(i, np.ones(100, np.uint8), t)
        stager.release(i, r.t_avail)
    stager.ingest("f3", np.ones(100, np.uint8), 3.0)   # squeeze
    assert "f0" in stager._resident          # spared: f1 evicted instead
    assert "f1" not in stager._resident
    assert "f0" in fab.hosts[0].store.data


def test_stream_stager_unpin_spares_foreign_store_pins():
    """unpin on a path the stager never pinned must not strip another
    holder's node-local store pin (e.g. a dataset-service lease)."""
    from repro.core.streaming import StreamStager
    fab = Fabric(n_hosts=2, constants=BGQ)
    stager = StreamStager(fab, window_bytes=1000)
    stager.ingest("f0", np.ones(100, np.uint8), 0.0)
    fab.hosts[0].store.pin("f0")             # foreign holder
    stager.unpin("f0")                       # stager holds no pin: no-op
    assert "f0" in fab.hosts[0].store.pinned
    fab.hosts[0].store.evict_lru(0)
    assert "f0" in fab.hosts[0].store.data


# ---------------------------------------------------------------------------
# write-back
# ---------------------------------------------------------------------------

def test_put_result_and_flush_land_bytes_on_fs():
    fab, svc = make_service()
    sess = svc.session("alice")
    sess.acquire("d0", 0.0)
    out = np.arange(777, dtype=np.float32)
    path, t_put = sess.put_result("d0", out, 1.0)
    assert t_put > 1.0                       # local write charged
    assert path not in fab.fs.files          # dirty: not flushed yet
    assert svc.dirty_bytes == out.nbytes
    rep, t_done = sess.flush(2.0)
    assert t_done > 2.0
    assert np.array_equal(fab.fs.files[path], out.view(np.uint8).ravel())
    assert rep.mode == "stage_out"
    assert rep.fs_write_bytes == out.nbytes  # 1x the result, not P x
    assert svc.dirty_bytes == 0
    # flushed replicas freed from the nodes
    assert path not in fab.hosts[0].store.data
    # empty flush is a no-op report
    rep2, t2 = sess.flush(3.0)
    assert t2 == 3.0 and rep2.total_bytes == 0


def test_stage_out_collective_vs_naive_accounting():
    out = {"r.bin": np.arange(1 << 16, dtype=np.uint8)}
    fab_c = Fabric(n_hosts=64, constants=BGQ)
    fab_n = Fabric(n_hosts=64, constants=BGQ)
    rep_c, _ = stage_out(fab_c, out)
    rep_n, _ = stage_out_naive(fab_n, out)
    assert rep_c.fs_write_bytes == 1 << 16             # 1x dataset
    assert rep_n.fs_write_bytes == 64 * (1 << 16)      # P x dataset
    assert np.array_equal(fab_c.fs.files["r.bin"], fab_n.fs.files["r.bin"])
    assert fab_c.fs.write_requests == 64               # stripes
    assert fab_n.fs.write_requests == 64               # full files


def test_stage_out_beats_naive_at_scale():
    out = {"r.bin": np.zeros(16 << 20, np.uint8)}
    rep_c, _ = stage_out(Fabric(n_hosts=1024, constants=BGQ), dict(out))
    rep_n, _ = stage_out_naive(Fabric(n_hosts=1024, constants=BGQ),
                               dict(out))
    assert rep_n.total_time > 5 * rep_c.total_time


def test_fs_write_gather_matches_per_stripe_writes():
    from repro.core.staging import _stripes
    fab_a = Fabric(n_hosts=4, constants=BGQ)
    fab_b = Fabric(n_hosts=4, constants=BGQ)
    blob = (np.arange(1 << 12, dtype=np.int64) % 251).astype(np.uint8)
    stripes = _stripes(1 << 12, 4)
    t_batch = fab_a.fs.write_gather("d/x", blob, stripes, 0.0,
                                    coordinated=True)
    t_loop = 0.0
    for off, sz in stripes:
        t_done = fab_b.fs.write("d/x", blob[off:off + sz], 0.0,
                                coordinated=True)
        t_loop = max(t_loop, t_done)
    assert t_batch == pytest.approx(t_loop)
    assert fab_a.fs.bytes_written == fab_b.fs.bytes_written == 1 << 12
    assert fab_a.fs.write_requests == fab_b.fs.write_requests == 4
    assert np.array_equal(fab_a.fs.files["d/x"], blob)


# ---------------------------------------------------------------------------
# catalog-backed I/O hook + session-tagged tasks
# ---------------------------------------------------------------------------

def test_iohook_catalog_mode_coalesces_across_hooks():
    fab = Fabric(n_hosts=4, constants=BGQ)
    for i in range(3):
        fab.fs.put(f"scans/s{i}.bin", np.full(1 << 12, i, np.uint8))
    svc = StagingService(fab, budget_bytes=1 << 20)
    spec = StagingSpec([BroadcastEntry(("scans/*.bin",))])
    res1 = run_io_hook(fab, spec, service=svc, session="alice")
    fs_bytes = fab.fs.bytes_read
    res2 = run_io_hook(fab, spec, t0=res1.total_time / 2,
                       service=svc, session="bob")
    assert fab.fs.bytes_read == fs_bytes          # second hook coalesced
    assert svc.stats.stages == 1 and svc.stats.coalesced == 1
    assert res1.resolved_files == res2.resolved_files
    for host in fab.hosts:
        for i in range(3):
            assert np.array_equal(host.store.data[f"scans/s{i}.bin"],
                                  fab.fs.files[f"scans/s{i}.bin"])
    # the hook hands back its leases; the caller releases them
    assert len(res1.leases) == 1 and len(res2.leases) == 1
    entry = svc.catalog[res1.leases[0].dataset]
    assert entry.lease_count == 2
    for res in (res1, res2):
        lease = res.leases[0]
        svc.release(lease.session_id, lease.dataset, lease.t_ready + 1.0)
    assert entry.lease_count == 0            # evictable again
    # metadata_time stays glob-only (broadcast is accounted separately)
    assert res1.metadata_time > 0.0
    assert svc.stats.broadcast_time > 0.0
    assert res1.metadata_time == pytest.approx(svc.stats.metadata_time)


def test_manytask_session_accounting():
    from repro.core.manytask import ManyTaskEngine, Task
    fab, svc = make_service(n_hosts=2)
    svc.acquire("alice", "d0", 0.0)
    sess = AnalysisSession(svc, "alice")
    p = svc.catalog["d0"].paths[0]
    tasks = [sess.tag(Task(0, duration=1.0, inputs=(p,))),
             sess.tag(Task(1, duration=2.0, inputs=(p,))),
             Task(2, duration=4.0)]                  # untagged
    engine = ManyTaskEngine(fab, n_workers=2, backup_threshold=0.0)
    stats = engine.run(tasks)
    assert set(stats.sessions) == {"alice"}
    s = stats.sessions["alice"]
    assert s.tasks == 2
    assert s.input_read_time > 0.0
    assert s.busy_time >= 3.0
    assert s.makespan <= stats.makespan


# ---------------------------------------------------------------------------
# end to end: interactive HEDM over the service
# ---------------------------------------------------------------------------

def test_run_interactive_hedm_byte_exact_under_eviction():
    from repro.hedm.pipeline import (SessionScript, pack_reduced,
                                     reduce_frames, run_interactive_hedm,
                                     simulate_detector_frames)
    n_frames, size = 6, 32
    scans, dark = {}, None
    for i, name in enumerate(["sA", "sB", "sC"]):
        frames, dark = simulate_detector_frames(n_frames, size=size,
                                                n_spots=3, seed=i)
        scans[name] = frames
    budget = 2 * n_frames * size * size * 4 + 64     # 2 of 3 fit
    fab = Fabric(n_hosts=8, constants=BGQ)
    sessions = [SessionScript("s1", ["sA", "sB", "sC"]),
                SessionScript("s2", ["sA", "sC", "sB"]),
                SessionScript("s3", ["sB", "sA", "sC"], t_start=0.2),
                SessionScript("s4", ["sC", "sB", "sA"], t_start=0.4)]
    res = run_interactive_hedm(fab, scans, dark, sessions, budget)
    svc = res.service
    assert svc.stats.coalesced > 0
    assert svc.stats.evictions > 0 and svc.stats.restages > 0
    # one stage per residency, per dataset
    for entry in svc.catalog:
        residencies = sum(1 for _, s in entry.history
                          if s is DatasetState.RESIDENT)
        assert entry.stage_count == residencies
        assert entry.acquires == \
            entry.stage_count + entry.coalesced + entry.hits
    # observable form: FS read traffic is exactly one dataset per residency
    assert fab.fs.bytes_read == \
        sum(e.stage_count * e.nbytes for e in svc.catalog)
    # outputs and write-back are byte-exact despite eviction/re-staging
    for name, frames in scans.items():
        ref = pack_reduced(reduce_frames(np.float32(frames), dark,
                                         use_kernel=False))
        for outs in res.outputs.values():
            assert np.array_equal(outs[name], ref)
    for paths in res.result_paths.values():
        for ds, p in paths.items():
            ref = pack_reduced(reduce_frames(np.float32(scans[ds]), dark,
                                             use_kernel=False))
            assert np.array_equal(fab.fs.files[p], ref.view(np.uint8).ravel())
    assert res.turnaround >= max(res.session_done.values())


# ---------------------------------------------------------------------------
# forced drop / stale pins (regression)
# ---------------------------------------------------------------------------

def test_forced_drop_restage_leaves_no_stale_pins():
    """Regression: the forced-drop path (``_restage_degraded``) drops the
    stale replicas WITH their lease pins and re-pins the fresh copies
    exactly ``lease_count`` times — a surviving stale pin would shield the
    re-staged replica from window eviction forever and make the final
    release underflow."""
    fab, svc = make_service(n_hosts=4)
    l1 = svc.acquire("alice", "d0", 0.0)
    svc.acquire("bob", "d0", l1.t_ready + 0.1)
    entry = svc.catalog["d0"]
    t = l1.t_ready + 1.0
    for h in range(4):                       # every copy lost, hosts blank
        svc.fail_host(h, t)
        svc.recover_host(h, t + 0.5)
    assert entry.state is DatasetState.DEGRADED
    # acquire repairs via forced drop + shared-FS re-stage (no live copy)
    l3 = svc.acquire("carol", "d0", t + 1.0)
    assert entry.state is DatasetState.RESIDENT
    assert svc.stats.restages == 1
    # exactly the three live leases pin the fresh replicas — no stale pins
    for host in fab.hosts:
        for p in entry.paths:
            assert host.store.pinned[p] == 3
    for sess in ("alice", "bob", "carol"):
        svc.release(sess, "d0", l3.t_ready + 1.0)
    assert all(not h.store.pinned for h in fab.hosts)
    # unpinned, the re-staged copy is evictable under budget pressure
    svc.acquire("dana", "d1", l3.t_ready + 2.0)
    svc.acquire("dana", "d2", l3.t_ready + 3.0)
    assert entry.state is DatasetState.GONE


# ---------------------------------------------------------------------------
# service invariants under random schedules (satellite: property test)
# ---------------------------------------------------------------------------
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402


def _drive_schedule(ops):
    """Drive a service through an arbitrary (acquire/release/put/inject)
    schedule, checking the budget bound after every op and the
    lease/counter invariants at the end. ``ops`` is a list of (kind,
    session#, dataset#) triples; impossible ops (release without a lease,
    acquire that would wedge with nothing releasable, death below quorum)
    are skipped, wedge-avoiding releases are applied first — the schedule
    is deterministic given ``ops``. ``inject`` kills a live host, so the
    ledger invariant is exercised in its full
    ``acquires == stages + coalesced + hits + repairs`` form."""
    fab, svc = make_service(sizes=(4, 4, 4), budget_files=8)
    file_bytes = 1 << 12
    t, held, injected = 0.0, [], False
    for kind, s, d in ops:
        t += 0.5
        sess, name = f"s{s % 3}", f"d{d % 3}"
        if kind == "inject":
            live = fab.live_ids(t)
            if len(live) > len(fab.hosts) // 2:
                svc.fail_host(live[(s * 3 + d) % len(live)], t)
                injected = True
            continue
        if kind == "release":
            if not held:
                continue
            sess, name = held.pop((s * 3 + d) % len(held))
            svc.release(sess, name, t)
        elif kind == "put":
            _, t = svc.put_result(sess, name,
                                  np.arange(8, dtype=np.float32), t)
            svc.flush(sess, t)
        else:
            entry = svc.catalog[name]
            resident = (DatasetState.RESIDENT, DatasetState.STAGING,
                        DatasetState.DEGRADED)
            wedged = False
            while entry.state not in resident:
                # admission needed: evictable = unleased residents
                leased = {n for _, n in held}
                freeable = sum(e.nbytes for e in svc.catalog
                               if e.state in (DatasetState.RESIDENT,
                                              DatasetState.DEGRADED)
                               and e.name not in leased)
                if (svc.catalog.resident_bytes - freeable + entry.nbytes
                        <= svc.budget_bytes):
                    break
                # would wedge: release a lease on a resident dataset first
                idx = next((i for i, (_, n) in enumerate(held)
                            if svc.catalog[n].state in resident), None)
                if idx is None:
                    wedged = True
                    break
                rs, rn = held.pop(idx)
                svc.release(rs, rn, t)
                t += 0.5
            if wedged:
                continue
            lease = svc.acquire(sess, name, t)
            t = max(t, lease.t_ready)
            held.append((sess, name))
        assert svc.catalog.resident_bytes <= svc.budget_bytes
    for sess, name in held:
        t += 0.5
        svc.release(sess, name, t)
    # the ledger invariant, per entry and in aggregate (repairs only
    # enter it when a death was injected)
    for e in svc.catalog:
        assert e.acquires == e.stage_count + e.coalesced + e.hits + e.repairs
        if not injected:
            assert e.repairs == 0
    assert sum(e.acquires for e in svc.catalog) == (
        svc.stats.stages + svc.stats.coalesced + svc.stats.hits
        + svc.stats.repairs)
    assert all(not h.store.pinned for h in fab.live_hosts(t))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["acquire", "release", "put",
                                           "inject"]),
                          st.integers(min_value=0, max_value=2),
                          st.integers(min_value=0, max_value=2)),
                max_size=50))
def test_service_invariants_random_schedules(ops):
    _drive_schedule(ops)


@pytest.mark.parametrize("seed", [0, 1, 7, 42])
def test_service_invariants_seeded_schedules(seed):
    """Deterministic stand-in for the property test above (runs even when
    hypothesis is absent): the same driver over seeded random schedules."""
    rng = np.random.default_rng(seed)
    kinds = ["acquire", "acquire", "acquire", "release", "put"]
    ops = [(kinds[rng.integers(0, len(kinds))],
            int(rng.integers(0, 3)), int(rng.integers(0, 3)))
           for _ in range(60)]
    _drive_schedule(ops)


@pytest.mark.parametrize("seed", [3, 11, 29])
def test_service_invariants_seeded_schedules_with_faults(seed):
    """Seeded schedules with host deaths mixed in: the ledger invariant
    holds in its full form (+ repairs) and no pin survives on a live
    host."""
    rng = np.random.default_rng(seed)
    kinds = ["acquire", "acquire", "acquire", "release", "put", "inject"]
    ops = [(kinds[rng.integers(0, len(kinds))],
            int(rng.integers(0, 3)), int(rng.integers(0, 3)))
           for _ in range(60)]
    _drive_schedule(ops)

"""HEDM application: stage-1 reduction and stage-2 orientation fitting."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.hedm.pipeline import (fit_grid, label_components, make_gvectors,
                                 reduce_frames, simulate_detector_frames,
                                 stream_to_fs, synth_grid_observations,
                                 _union_find_label)
from repro.core.fabric import Fabric


def test_stage1_detects_spots():
    frames, dark = simulate_detector_frames(3, size=96, n_spots=4, seed=1)
    red = reduce_frames(frames, dark, threshold=200.0, use_kernel=True)
    assert all(r.n_spots >= 1 for r in red)
    for r in red:
        assert r.peaks.shape == (r.n_spots, 3)
        assert r.n_signal_pixels > 0


def test_stage1_reduction_is_sparse():
    """Paper: 8 MB frames reduce to ~1 MB of signal — mask must be sparse."""
    frames, dark = simulate_detector_frames(2, size=128, n_spots=6, seed=2)
    red = reduce_frames(frames, dark, threshold=200.0)
    for r in red:
        assert r.n_signal_pixels < 0.1 * 128 * 128


def test_union_find_labeling():
    mask = np.zeros((8, 8), bool)
    mask[1:3, 1:3] = True
    mask[5:7, 5:7] = True
    labels, n = _union_find_label(mask)
    assert n == 2
    assert labels[1, 1] != labels[5, 5]


def test_vectorized_labeler_matches_union_find():
    """The run-based two-pass labeler is a drop-in for the pixel-loop
    reference: identical labels AND identical numbering on random masks."""
    rng = np.random.default_rng(7)
    for _ in range(40):
        H = int(rng.integers(1, 48))
        W = int(rng.integers(1, 48))
        mask = rng.random((H, W)) < rng.uniform(0.05, 0.8)
        l_ref, n_ref = _union_find_label(mask)
        l_vec, n_vec = label_components(mask)
        assert n_ref == n_vec
        assert np.array_equal(l_ref, l_vec)


def test_labeler_edge_cases():
    empty = np.zeros((6, 6), bool)
    labels, n = label_components(empty)
    assert n == 0 and not labels.any()
    full = np.ones((5, 9), bool)
    labels, n = label_components(full)
    assert n == 1 and (labels == 1).all()
    one_px = np.zeros((1, 1), bool)
    one_px[0, 0] = True
    labels, n = label_components(one_px)
    assert n == 1 and labels[0, 0] == 1
    # snake: single 8-shaped component that forces cross-row merging
    snake = np.zeros((5, 5), bool)
    snake[0, :] = snake[2, :] = snake[4, :] = True
    snake[1, 0] = snake[3, 4] = True
    labels, n = label_components(snake)
    assert n == 1
    assert np.array_equal(*[x[0] for x in [label_components(snake),
                                           _union_find_label(snake)]])


def test_bincount_centroids_match_per_label_scan():
    """reduce_frames' one-pass weighted centroids equal the per-label
    nonzero-scan they replaced."""
    frames, dark = simulate_detector_frames(2, size=96, n_spots=5, seed=4)
    red = reduce_frames(frames, dark, threshold=200.0, use_kernel=False)
    from repro.kernels.hedm_reduce_ref import reference
    import jax.numpy as jnp
    masks, _ = reference(jnp.asarray(frames), jnp.asarray(dark),
                         threshold=200.0)
    for r, frame, mask in zip(red, frames, np.asarray(masks)):
        labels, n = label_components(mask > 0)
        assert n == r.n_spots
        for lbl in range(1, n + 1):
            ys, xs = np.nonzero(labels == lbl)
            inten = frame[ys, xs]
            w = inten / max(inten.sum(), 1e-9)
            np.testing.assert_allclose(
                r.peaks[lbl - 1],
                [(ys * w).sum(), (xs * w).sum(), inten.sum()], rtol=1e-4)


def test_detector_sim_spots_are_gaussian_and_bright():
    """Vectorized rendering still produces detectable bright spots well
    above the Poisson background."""
    frames, dark = simulate_detector_frames(3, size=64, n_spots=3, seed=9)
    assert frames.shape == (3, 64, 64) and frames.dtype == np.float32
    for f in frames:
        assert f.max() > 500            # amp >= 800 minus overlap losses
    no_spots, _ = simulate_detector_frames(2, size=64, n_spots=0, seed=9)
    assert no_spots.max() < 40          # pure Poisson(8) background


def test_stage2_recovers_orientations():
    gvec = make_gvectors()
    truth, obs = synth_grid_observations(128, gvec, noise=0.005)
    fit = fit_grid(jnp.asarray(obs), jnp.asarray(gvec),
                   jnp.zeros((128, 3)))
    err = np.abs(np.asarray(fit) - truth).max(axis=1)
    assert (err < 0.05).mean() > 0.7      # local minima are physical


def test_detector_stream_to_fs():
    fab = Fabric(n_hosts=2)
    frames, _ = simulate_detector_frames(3, size=32, n_spots=1)
    paths = stream_to_fs(fab, frames)
    assert len(paths) == 3
    assert fab.fs.size(paths[0]) == 32 * 32 * 4

"""HEDM application: stage-1 reduction and stage-2 orientation fitting."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.hedm.pipeline import (fit_grid, make_gvectors, reduce_frames,
                                 simulate_detector_frames, stream_to_fs,
                                 synth_grid_observations, _union_find_label)
from repro.core.fabric import Fabric


def test_stage1_detects_spots():
    frames, dark = simulate_detector_frames(3, size=96, n_spots=4, seed=1)
    red = reduce_frames(frames, dark, threshold=200.0, use_kernel=True)
    assert all(r.n_spots >= 1 for r in red)
    for r in red:
        assert r.peaks.shape == (r.n_spots, 3)
        assert r.n_signal_pixels > 0


def test_stage1_reduction_is_sparse():
    """Paper: 8 MB frames reduce to ~1 MB of signal — mask must be sparse."""
    frames, dark = simulate_detector_frames(2, size=128, n_spots=6, seed=2)
    red = reduce_frames(frames, dark, threshold=200.0)
    for r in red:
        assert r.n_signal_pixels < 0.1 * 128 * 128


def test_union_find_labeling():
    mask = np.zeros((8, 8), bool)
    mask[1:3, 1:3] = True
    mask[5:7, 5:7] = True
    labels, n = _union_find_label(mask)
    assert n == 2
    assert labels[1, 1] != labels[5, 5]


def test_stage2_recovers_orientations():
    gvec = make_gvectors()
    truth, obs = synth_grid_observations(128, gvec, noise=0.005)
    fit = fit_grid(jnp.asarray(obs), jnp.asarray(gvec),
                   jnp.zeros((128, 3)))
    err = np.abs(np.asarray(fit) - truth).max(axis=1)
    assert (err < 0.05).mean() > 0.7      # local minima are physical


def test_detector_stream_to_fs():
    fab = Fabric(n_hosts=2)
    frames, _ = simulate_detector_frames(3, size=32, n_spots=1)
    paths = stream_to_fs(fab, frames)
    assert len(paths) == 3
    assert fab.fs.size(paths[0]) == 32 * 32 * 4

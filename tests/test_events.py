"""Event timeline + QoS scheduler: unit, serial-equivalence, edge-case
and property-based invariant tests.

The load-bearing claims pinned down here:

  * the `repro.core.events.EventLoop` is deterministic — events fire in
    ``(t, priority, seq)`` order, identical schedules replay identically,
    and time never runs backwards;
  * a single zero-contention session through the
    `repro.core.qos.QoSScheduler` is BIT-EXACT with driving the
    `repro.core.datasvc.StagingService` serially (the acceptance bar for
    the event-driven rework);
  * concurrent sessions on the timeline match the serial service driven
    with the same operations in timestamp order (operations are atomic
    at issue, so event-driven == serial-in-time-order);
  * the QoS policy's properties: head-of-line blocking under fifo,
    backfill + aging + fair-share + priority-protective preemption under
    qos, loud failure when parked requests can never be admitted;
  * invariants under random concurrent schedules (hypothesis when
    available, seeded always): per-key timestamp monotonicity, the
    budget bound after EVERY event, ``acquires == stages + coalesced +
    hits + repairs``, and no request starved forever.
"""
import math

import numpy as np
import pytest

from conftest import make_service
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.datasvc import DatasetState
from repro.core.events import CausalityError, EventLoop
from repro.core.qos import FIFO, QOS, QoSPolicy, QoSScheduler


# ---------------------------------------------------------------------------
# EventLoop unit behavior
# ---------------------------------------------------------------------------

def test_events_fire_in_time_order():
    loop, fired = EventLoop(), []
    for t in (3.0, 1.0, 2.0, 0.5):
        loop.schedule(t, lambda t=t: fired.append(t))
    loop.run()
    assert fired == [0.5, 1.0, 2.0, 3.0]
    assert loop.now == 3.0
    assert loop.fired == 4


def test_equal_time_ties_break_by_priority_then_seq():
    loop, fired = EventLoop(), []
    loop.schedule(1.0, lambda: fired.append("a"))            # seq 0
    loop.schedule(1.0, lambda: fired.append("urgent"), priority=-1)
    loop.schedule(1.0, lambda: fired.append("b"))            # seq 2
    loop.schedule(1.0, lambda: fired.append("late"), priority=5)
    loop.run()
    assert fired == ["urgent", "a", "b", "late"]


def test_scheduling_into_the_past_raises():
    loop = EventLoop()
    loop.schedule(2.0, lambda: None)
    loop.run()
    with pytest.raises(CausalityError):
        loop.schedule(1.0, lambda: None)
    # scheduling exactly AT now is legal (zero-delay follow-up work)
    loop.schedule(2.0, lambda: None)


def test_callback_may_schedule_at_now_and_later():
    loop, fired = EventLoop(), []

    def first():
        fired.append("first")
        loop.schedule(loop.now, lambda: fired.append("same-instant"))
        loop.schedule(5.0, lambda: fired.append("later"))

    loop.schedule(1.0, first)
    loop.schedule(2.0, lambda: fired.append("second"))
    loop.run()
    # the same-instant follow-up fires before the t=2 event
    assert fired == ["first", "same-instant", "second", "later"]


def test_cancel_skips_event():
    loop, fired = EventLoop(), []
    keep = loop.schedule(1.0, lambda: fired.append("keep"))
    drop = loop.schedule(2.0, lambda: fired.append("drop"))
    loop.cancel(drop)
    loop.run()
    assert fired == ["keep"]
    assert loop.fired == 1
    assert not keep.canceled


def test_run_until_partial_drain_advances_now():
    loop, fired = EventLoop(), []
    for t in (1.0, 2.0, 3.0):
        loop.schedule(t, lambda t=t: fired.append(t))
    assert loop.run(until=2.5) == 2.5
    assert fired == [1.0, 2.0]
    assert loop.pending == 1
    assert loop.advance(10.0) == 10.0     # finite until moves now past last t
    assert fired == [1.0, 2.0, 3.0]


def test_step_fires_exactly_one_event():
    loop, fired = EventLoop(), []
    loop.schedule(1.0, lambda: fired.append(1))
    loop.schedule(2.0, lambda: fired.append(2))
    ev = loop.step()
    assert fired == [1] and ev.t == 1.0
    assert loop.step().t == 2.0
    assert loop.step() is None


def test_peek_and_pending_skip_canceled():
    loop = EventLoop()
    first = loop.schedule(1.0, lambda: None)
    loop.schedule(2.0, lambda: None)
    loop.cancel(first)
    assert loop.peek() == 2.0
    assert loop.pending == 1


def test_identical_schedules_replay_identically():
    def build():
        loop, fired = EventLoop(), []
        rng = np.random.default_rng(7)
        for i in range(50):
            t = float(rng.integers(0, 10))    # heavy tie collisions
            loop.schedule(t, lambda i=i: fired.append(i),
                          priority=int(rng.integers(-2, 3)), key=f"k{i % 5}")
        loop.run()
        return fired, [(e.t, e.priority, e.seq) for e in loop.history]

    assert build() == build()


def test_history_is_globally_time_ordered_with_keys():
    loop = EventLoop()
    rng = np.random.default_rng(3)
    for i in range(40):
        loop.schedule(float(rng.uniform(0, 5)), lambda: None,
                      key=f"h{i % 4}")
    loop.run()
    ts = [e.t for e in loop.history]
    assert ts == sorted(ts)
    for key in {e.key for e in loop.history}:
        kts = [e.t for e in loop.history if e.key == key]
        assert kts == sorted(kts)         # per-key monotonicity


def test_loop_starts_at_t0():
    loop = EventLoop(t0=5.0)
    with pytest.raises(CausalityError):
        loop.schedule(4.0, lambda: None)
    loop.schedule(5.0, lambda: None)
    assert loop.run() == 5.0


def test_policy_validation():
    with pytest.raises(ValueError):
        QoSPolicy(name="edf")
    with pytest.raises(ValueError):
        QoSPolicy(aging_rate=-1.0)
    assert FIFO.name == "fifo" and QOS.name == "qos"


# ---------------------------------------------------------------------------
# scheduler vs serial service: zero-contention bit-exactness and
# serial-equivalence under concurrency
# ---------------------------------------------------------------------------

def _scheduler(policy=None, **kw):
    fab, svc = make_service(**kw)
    return fab, svc, QoSScheduler(svc, policy=policy)


def test_single_session_bit_exact_vs_serial():
    """The acceptance bar: one session, no contention — the event-driven
    path must reproduce the serial service exactly (times, counters, and
    the delivered bytes)."""
    fab_s, svc_s = make_service()
    l0 = svc_s.acquire("s0", "d0", 0.0)
    svc_s.release("s0", "d0", l0.t_ready + 1.0)
    l1 = svc_s.acquire("s0", "d1", l0.t_ready + 2.0)
    svc_s.release("s0", "d1", l1.t_ready)

    fab_e, svc_e, sched = _scheduler()
    r0 = sched.submit("s0", "d0", 0.0, hold=1.0)
    r1 = sched.submit("s0", "d1", l0.t_ready + 2.0, hold=0.0)
    sched.run()

    assert (r0.t_ready, r1.t_ready) == (l0.t_ready, l1.t_ready)
    assert r0.t_admit == 0.0 and r1.t_admit == l0.t_ready + 2.0
    for name in ("stages", "hits", "coalesced", "evictions", "queue_waits"):
        assert getattr(svc_e.stats, name) == getattr(svc_s.stats, name)
    assert fab_e.fs.bytes_read == fab_s.fs.bytes_read
    assert fab_e.net.bytes_moved == fab_s.net.bytes_moved
    for he, hs in zip(fab_e.hosts, fab_s.hosts):
        assert set(he.store.data) == set(hs.store.data)
        for p in he.store.data:
            np.testing.assert_array_equal(he.store.data[p],
                                          hs.store.data[p])


def test_concurrent_coalesce_on_timeline():
    """Two sessions asking for one dataset inside its stage window share
    ONE collective stage, exactly as the serial coalescing path."""
    fab, svc, sched = _scheduler()
    a = sched.submit("s0", "d0", 0.0)
    b = sched.submit("s1", "d0", 1e-4)      # lands mid-stage
    sched.run()
    assert svc.stats.stages == 1 and svc.stats.coalesced == 1
    assert a.t_ready == b.t_ready
    assert svc.catalog["d0"].acquires == 2


def test_event_driven_matches_serial_in_timestamp_order():
    """Operations are atomic at issue, so the event-driven timeline must
    equal the serial service driven with the SAME ops sorted by time —
    including FS contention between overlapping sessions' stages."""
    schedule = [("s0", "d0", 0.0, 0.5), ("s1", "d1", 1e-4, 0.2),
                ("s2", "d0", 2e-4, 0.1), ("s0", "d2", 0.9, 0.0)]
    fab_e, svc_e, sched = _scheduler(sizes=(4, 4, 4), budget_files=12)
    reqs = [sched.submit(s, d, t, hold=h) for s, d, t, h in schedule]
    sched.run()

    fab_s, svc_s = make_service(sizes=(4, 4, 4), budget_files=12)
    ops = []                      # (t, kind, session, dataset) in time order
    for s, d, t, h in schedule:
        ops.append((t, "acquire", s, d, h))
    done = {}
    serial_ready = {}
    pending = sorted(ops)
    while pending:
        t, kind, s, d, h = pending.pop(0)
        lease = svc_s.acquire(s, d, t)
        serial_ready[(s, d)] = lease.t_ready
        pending.append((lease.t_ready + h, "release", s, d, 0.0))
        pending = [op for op in pending if op[1] == "release"] and pending
        pending.sort()
        # interleave releases due before the next acquire
        while (pending and pending[0][1] == "release"):
            rt, _, rs, rd, _ = pending.pop(0)
            svc_s.release(rs, rd, rt)
    for r in reqs:
        assert r.t_ready == serial_ready[(r.session_id, r.dataset)]
    assert svc_e.stats.stages == svc_s.stats.stages
    assert fab_e.fs.bytes_read == fab_s.fs.bytes_read
    assert fab_e.fs.busy_time == fab_s.fs.busy_time


def test_contention_parks_then_wakes_on_release():
    """Budget holds two of three datasets: the third session parks and is
    admitted by the release EVENT, not a pre-recorded future time."""
    fab, svc, sched = _scheduler()
    sched.submit("s0", "d0", 0.0, hold=5.0)
    sched.submit("s1", "d1", 0.0, hold=5.0)
    c = sched.submit("s2", "d2", 0.001)
    sched.run()
    assert c.done and c.parked_time > 0
    assert c.t_admit >= 5.0                  # woken by a release at hold end
    assert svc.stats.evictions == 1
    assert svc.catalog.resident_bytes <= svc.budget_bytes


def test_fifo_head_of_line_blocks_admissible_followers():
    """Under fifo, a parked head blocks a request that WOULD be
    admissible (even a residency hit) — the baseline's failure mode."""
    fab, svc, sched = _scheduler(policy=FIFO)
    sched.submit("s0", "d0", 0.0, hold=4.0)
    sched.submit("s1", "d1", 0.001, hold=4.0)
    blocked = sched.submit("s2", "d2", 0.002, hold=0.0)   # parks: no memory
    hit = sched.submit("s3", "d0", 0.003)                 # would coalesce/hit
    sched.run()
    assert hit.t_admit >= blocked.t_admit            # no overtaking
    assert hit.parked_time > 3.0


def test_qos_backfill_overtakes_blocked_head():
    """Same schedule under qos: the admissible hit backfills immediately
    while the memory-blocked request keeps waiting."""
    fab, svc, sched = _scheduler(policy=QOS)
    sched.submit("s0", "d0", 0.0, hold=4.0)
    sched.submit("s1", "d1", 0.001, hold=4.0)
    blocked = sched.submit("s2", "d2", 0.002, hold=0.0)
    hit = sched.submit("s3", "d0", 0.003)
    sched.run()
    assert hit.t_admit < blocked.t_admit
    assert hit.parked_time == 0.0                    # started on arrival
    assert blocked.done


def test_preemption_protects_high_priority_residents():
    """qos eviction is lowest-residency-priority-first: staging a new
    dataset under pressure evicts the low-priority tenant's unleased
    dataset, keeping the high-priority one warm."""
    fab, svc, sched = _scheduler(policy=QOS)
    lo = sched.submit("lo", "d0", 0.0, priority=0, hold=0.0)
    hi = sched.submit("hi", "d1", 0.001, priority=5, hold=0.0)
    sched.submit("s2", "d2", 1.0, priority=1)        # needs one eviction
    sched.run()
    assert svc.catalog["d0"].state is DatasetState.GONE      # low-pri evicted
    assert svc.catalog["d1"].state is DatasetState.RESIDENT  # high-pri warm
    assert sched.preemptions == 1
    assert lo.done and hi.done


def test_fifo_keeps_cost_ranked_eviction():
    """The fifo baseline keeps the serial cheapest-to-restage eviction
    rule (no priority protection)."""
    fab, svc, sched = _scheduler(policy=FIFO)
    sched.submit("lo", "d0", 0.0, priority=0, hold=0.0)
    sched.submit("hi", "d1", 0.001, priority=5, hold=0.0)
    sched.submit("s2", "d2", 1.0, priority=1)
    sched.run()
    # equal-size datasets: cheapest-first degenerates to name order
    assert svc.catalog["d0"].state is DatasetState.GONE
    assert sched.preemptions == 0                    # _admit evicted, not qos
    assert svc.stats.evictions == 1


def test_aging_bounds_starvation_of_low_priority():
    """A low-priority request parked behind a stream of high-priority
    work is eventually served: aging lifts its effective rank above any
    fixed priority."""
    fab, svc, sched = _scheduler(policy=QoSPolicy(aging_rate=10.0))
    low = sched.submit("low", "d2", 0.0, priority=0)
    # continuous high-priority contention for the other two datasets
    for i in range(12):
        sched.submit(f"hi{i % 2}", f"d{i % 2}", 0.001 + i * 0.4,
                     priority=100, hold=0.4)
    sched.run()
    assert low.done
    assert math.isfinite(low.latency)


def test_fair_share_tie_break_favors_least_served():
    """At equal effective rank, the session served least goes first."""
    fab, svc, sched = _scheduler(policy=QoSPolicy(aging_rate=0.0))
    # greedy session completes two requests first
    sched.submit("greedy", "d0", 0.0, hold=1.0)
    sched.submit("greedy", "d1", 0.0, hold=1.0)
    # both park (budget full), same priority, same submit time
    a = sched.submit("greedy", "d2", 0.5, hold=0.5)
    b = sched.submit("newcomer", "d2", 0.5, hold=0.5)
    sched.run()
    assert b.t_admit <= a.t_admit                    # newcomer not last
    served = {}
    for r in sched.completed:
        served.setdefault(r.session_id, []).append(r.t_admit)
    assert min(served["newcomer"]) <= min(served["greedy"][2:] or [math.inf])


def test_run_raises_when_requests_starve():
    """A drained timeline with parked requests = nothing will ever admit
    them; the scheduler fails as loudly as the serial 'wedged' error."""
    fab, svc, sched = _scheduler()
    # leases held OFF the timeline: no release event will ever fire
    svc.acquire("pin0", "d0", 0.0)
    svc.acquire("pin1", "d1", 0.0)
    sched.submit("s2", "d2", 0.1)
    with pytest.raises(RuntimeError, match="parked"):
        sched.run()


def test_summary_reports_latency_percentiles_and_goodput():
    fab, svc, sched = _scheduler()
    for i in range(6):
        sched.submit(f"s{i % 2}", f"d{i % 3}", i * 0.01, hold=0.2)
    sched.run()
    s = sched.summary()
    assert s["completed"] == 6 and s["parked"] == 0
    assert 0 < s["p50_latency"] <= s["p99_latency"]
    assert s["goodput_bytes_per_s"] > 0
    assert s["makespan"] > 0
    empty = QoSScheduler(svc).summary()
    assert empty["completed"] == 0 and math.isnan(empty["p50_latency"])


def test_qos_beats_fifo_p99_under_overload():
    """The bench assertion in miniature: heavy-tailed holds + overload —
    qos backfill avoids fifo's head-of-line P99 penalty."""
    def drive(policy):
        fab, svc, sched = _scheduler(policy=policy, sizes=(4, 4, 4),
                                     budget_files=8)
        rng = np.random.default_rng(42)
        t = 0.0
        for i in range(40):
            t += float(rng.exponential(0.02))
            hold = float((rng.pareto(1.5) + 1) * 0.05)
            sched.submit(f"s{i % 6}", f"d{int(rng.integers(0, 3))}", t,
                         priority=int(rng.integers(0, 3)),
                         hold=min(hold, 5.0))
        sched.run()
        return sched.summary()

    fifo, qos = drive(FIFO), drive(QOS)
    assert fifo["completed"] == qos["completed"] == 40
    assert qos["p99_latency"] < fifo["p99_latency"]


# ---------------------------------------------------------------------------
# concurrency edge cases: faults and elasticity mid-flight on the timeline
# ---------------------------------------------------------------------------

def test_fail_host_mid_stage_on_timeline():
    """A host death injected INSIDE another session's stage window fires
    between the acquire and its readiness: the dataset degrades while
    observers still see STAGING, and the next acquire repairs it —
    byte-exact with the serial equivalent."""
    def drive(event_driven):
        fab, svc = make_service()
        if event_driven:
            sched = QoSScheduler(svc)
            r = sched.submit("s0", "d0", 0.0, hold=1.0)
            sched.fail_host_at(3, 0.01)       # mid-stage (stage takes ~0.06)
            late = sched.submit("s1", "d0", 2.0)
            sched.run()
            t_ready, t_late = r.t_ready, late.t_ready
        else:
            lease = svc.acquire("s0", "d0", 0.0)
            svc.fail_host(3, 0.01)
            svc.release("s0", "d0", lease.t_ready + 1.0)
            l2 = svc.acquire("s1", "d0", 2.0)
            svc.release("s1", "d0", l2.t_ready)
            t_ready, t_late = lease.t_ready, l2.t_ready
        entry = svc.catalog["d0"]
        return (t_ready, t_late, entry.repairs, svc.stats.host_deaths,
                svc.stats.degraded_events,
                {p: bytes(fab.hosts[0].store.data[p])
                 for p in fab.hosts[0].store.data})

    assert drive(True) == drive(False)
    # and the invariant holds with repairs in the ledger
    fab, svc = make_service()
    sched = QoSScheduler(svc)
    sched.submit("s0", "d0", 0.0, hold=1.0)
    sched.fail_host_at(3, 0.01)
    sched.submit("s1", "d0", 2.0)
    sched.run()
    e = svc.catalog["d0"]
    assert e.acquires == e.stage_count + e.coalesced + e.hits + e.repairs
    assert e.repairs == 1


def test_resize_mid_flight_on_timeline():
    """An elastic grow fired between a session's stage and its readiness:
    fully replicated residents degrade (blank new hosts) and the next
    acquire repairs coverage — matching the serial call order."""
    def drive(event_driven):
        fab, svc = make_service()
        if event_driven:
            sched = QoSScheduler(svc)
            sched.submit("s0", "d0", 0.0, hold=0.5)
            sched.resize_at(12, 1.0)
            late = sched.submit("s1", "d0", 2.0)
            sched.run()
            t_late = late.t_ready
        else:
            lease = svc.acquire("s0", "d0", 0.0)
            svc.release("s0", "d0", lease.t_ready + 0.5)
            svc.resize(12, 1.0)
            l2 = svc.acquire("s1", "d0", 2.0)
            svc.release("s1", "d0", l2.t_ready)
            t_late = l2.t_ready
        entry = svc.catalog["d0"]
        return (fab.n_hosts, t_late, entry.repairs, svc.stats.resizes,
                sorted(entry.holders),
                {p: bytes(fab.hosts[-1].store.data[p])
                 for p in fab.hosts[-1].store.data})

    assert drive(True) == drive(False)


def test_shrink_mid_flight_keeps_replicated_resident():
    fab, svc = make_service()
    sched = QoSScheduler(svc)
    r = sched.submit("s0", "d0", 0.0, hold=0.5)
    sched.resize_at(6, 1.0)
    sched.run()
    assert fab.n_hosts == 6
    # full replication: every surviving host still holds a copy
    assert svc.catalog["d0"].state is DatasetState.RESIDENT
    assert r.done


def test_budget_bound_after_every_event_under_churn():
    """Stepping the loop by hand: the memory budget holds at EVERY event
    boundary, not just at the end."""
    fab, svc = make_service()
    sched = QoSScheduler(svc)
    rng = np.random.default_rng(5)
    t = 0.0
    for i in range(25):
        t += float(rng.exponential(0.05))
        sched.submit(f"s{i % 4}", f"d{int(rng.integers(0, 3))}", t,
                     priority=int(rng.integers(0, 3)),
                     hold=float(rng.uniform(0, 0.3)))
    while sched.loop.peek() is not None:
        sched.loop.step()
        assert svc.catalog.resident_bytes <= svc.budget_bytes
    assert not sched.pending


# ---------------------------------------------------------------------------
# property-based invariants over random concurrent schedules
# ---------------------------------------------------------------------------

def _drive_timeline(ops, policy=None):
    """Drive a random concurrent schedule — (kind, session#, dataset#)
    triples become submits, host deaths and recoveries on one shared
    timeline — then check every invariant the suite promises:

      * event timestamps globally and per-key monotone;
      * memory budget never exceeded at any event boundary;
      * ``acquires == stages + coalesced + hits + repairs`` per entry;
      * no request starved (every submit completes, pins all returned).
    """
    fab, svc = make_service()
    sched = QoSScheduler(svc, policy=policy)
    reqs, t = [], 0.0
    for kind, s, d in ops:
        t += 0.3
        if kind == "inject":
            host = 1 + (s * 3 + d) % (fab.n_hosts - 1)

            def fire(host=host, t=t):
                # guards evaluated at FIRE time: keep a quorum, only
                # kill live hosts / recover dead ones
                if (host in fab.live_ids(t)
                        and len(fab.live_ids(t)) > fab.n_hosts // 2):
                    svc.fail_host(host, t)
                elif host in fab.dead_ids(t):
                    svc.recover_host(host, t)

            sched.at(t, fire, key="fault", priority=-2)
        else:
            reqs.append(sched.submit(
                f"s{s % 3}", f"d{d % 3}", t, priority=s % 3,
                hold=0.2 + 0.3 * (d % 3)))
    while sched.loop.peek() is not None:
        sched.loop.step()
        assert svc.catalog.resident_bytes <= svc.budget_bytes
    assert not sched.pending                      # nobody starved
    assert all(r.done for r in reqs)
    ts = [e.t for e in sched.loop.history]
    assert ts == sorted(ts)
    for key in {e.key for e in sched.loop.history}:
        kts = [e.t for e in sched.loop.history if e.key == key]
        assert kts == sorted(kts)
    for e in svc.catalog:
        assert e.acquires == e.stage_count + e.coalesced + e.hits + e.repairs
        assert not e.leases
    assert sum(e.acquires for e in svc.catalog) == (
        svc.stats.stages + svc.stats.coalesced + svc.stats.hits
        + svc.stats.repairs)
    for host in fab.live_hosts(sched.loop.now):
        assert not host.store.pinned


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(["submit", "submit", "submit", "inject"]),
    st.integers(min_value=0, max_value=2),
    st.integers(min_value=0, max_value=2)), max_size=40))
def test_timeline_invariants_random_schedules(ops):
    _drive_timeline(ops)


@pytest.mark.parametrize("policy", [None, FIFO])
@pytest.mark.parametrize("seed", [0, 1, 7, 42])
def test_timeline_invariants_seeded_schedules(seed, policy):
    """Deterministic stand-in for the property test (runs without
    hypothesis), over both policies."""
    rng = np.random.default_rng(seed)
    kinds = ["submit", "submit", "submit", "submit", "inject"]
    ops = [(kinds[rng.integers(0, len(kinds))],
            int(rng.integers(0, 3)), int(rng.integers(0, 3)))
           for _ in range(50)]
    _drive_timeline(ops, policy=policy)


def test_hypothesis_compat_flag_is_consistent():
    """The suite must be meaningful both with and without hypothesis:
    when absent, @given tests skip (not silently pass)."""
    if HAVE_HYPOTHESIS:
        import hypothesis  # noqa: F401
    else:
        marked = getattr(test_timeline_invariants_random_schedules,
                         "pytestmark", [])
        assert any(m.name == "skip" for m in marked)

"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

key = jax.random.PRNGKey(0)


def rand(k, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(key, k), shape, dtype)


# --------------------------- flash attention ------------------------------

SHAPES = [
    (2, 256, 8, 4, 64, True, 0),
    (1, 256, 4, 4, 128, True, 64),
    (2, 128, 8, 2, 32, False, 0),
    (1, 512, 8, 8, 64, True, 0),
    (1, 256, 16, 4, 64, True, 128),
]


@pytest.mark.parametrize("B,S,H,KV,hd,causal,win", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(B, S, H, KV, hd, causal, win,
                                           dtype):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention_ref import reference
    q = rand(1, (B, S, H, hd), dtype)
    k = rand(2, (B, S, KV, hd), dtype)
    v = rand(3, (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=win)
    ref = reference(q, k, v, causal=causal, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@given(bq=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64, 128]))
@settings(max_examples=9, deadline=None)
def test_flash_attention_block_shape_invariance(bq, bk):
    """Output must not depend on the VMEM tiling."""
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention_ref import reference
    q, k, v = (rand(i, (1, 256, 4, 2, 64))[..., 0, :, :].transpose(0, 2, 1, 3)
               if False else rand(i, (1, 256, 4, 64)) for i in (4, 5, 6))
    kk = rand(7, (1, 256, 2, 64))
    vv = rand(8, (1, 256, 2, 64))
    out = flash_attention(q, kk, vv, causal=True, block_q=bq, block_k=bk)
    ref = reference(q, kk, vv, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# --------------------------- mamba2 ssd -----------------------------------

@pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
    (2, 128, 4, 16, 2, 8, 32),
    (1, 64, 2, 32, 1, 16, 16),
    (1, 256, 8, 16, 8, 8, 64),
])
def test_mamba2_scan_matches_reference(B, L, H, P, G, N, chunk):
    from repro.kernels.mamba2_scan import mamba2_scan
    from repro.kernels.mamba2_scan_ref import reference
    x = rand(10, (B, L, H, P))
    dt = jax.nn.softplus(rand(11, (B, L, H)))
    A = -jnp.exp(rand(12, (H,)))
    Bm = rand(13, (B, L, G, N))
    Cm = rand(14, (B, L, G, N))
    y, h = mamba2_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, h_ref = reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-4)


# --------------------------- rwkv6 wkv ------------------------------------

@pytest.mark.parametrize("B,L,H,N,chunk", [
    (2, 96, 3, 8, 32),
    (1, 64, 2, 16, 16),
    (1, 128, 4, 32, 32),
])
def test_rwkv6_wkv_matches_reference(B, L, H, N, chunk):
    from repro.kernels.rwkv6_wkv import rwkv6_wkv
    from repro.kernels.rwkv6_wkv_ref import reference
    r = rand(20, (B, L, H, N))
    k = rand(21, (B, L, H, N))
    v = rand(22, (B, L, H, N))
    w = jax.nn.sigmoid(rand(23, (B, L, H, N))) * 0.5 + 0.45
    u = rand(24, (H, N))
    o, s = rwkv6_wkv(r, k, v, w, u, chunk=chunk)
    o_ref, s_ref = reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=2e-4)


# --------------------------- hedm reduce ----------------------------------

def test_hedm_reduce_matches_reference():
    from repro.kernels.hedm_reduce import hedm_reduce
    from repro.kernels.hedm_reduce_ref import reference
    rng = np.random.default_rng(0)
    frames = rng.integers(0, 40, (4, 64, 64)).astype(np.float32)
    frames[1, 10:13, 40:43] += 3000
    dark = np.full((64, 64), 8.0, np.float32)
    m1, c1 = hedm_reduce(jnp.asarray(frames), jnp.asarray(dark), threshold=150.0)
    m2, c2 = reference(jnp.asarray(frames), jnp.asarray(dark), threshold=150.0)
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    assert np.array_equal(np.asarray(c1), np.asarray(c2))
    assert int(np.asarray(c1)[1]) > 0          # the spot was detected


def test_hedm_reduce_row_tiled_matches_untiled():
    """Row tiling with 2-row halo must be invisible: tiled == untiled ==
    reference, including when H is not a multiple of the tile."""
    from repro.kernels.hedm_reduce import hedm_reduce
    from repro.kernels.hedm_reduce_ref import reference
    rng = np.random.default_rng(3)
    for H, W, tile in [(64, 64, 16), (72, 48, 32), (40, 56, 8)]:
        frames = rng.integers(0, 40, (2, H, W)).astype(np.float32)
        frames[0, H // 2:H // 2 + 3, W // 2:W // 2 + 3] += 3000
        frames[1, 0:3, 0:3] += 3000            # spot crossing the edge
        dark = np.full((H, W), 8.0, np.float32)
        m_ref, c_ref = reference(jnp.asarray(frames), jnp.asarray(dark),
                                 threshold=150.0)
        m_t, c_t = hedm_reduce(jnp.asarray(frames), jnp.asarray(dark),
                               threshold=150.0, tile_rows=tile)
        assert np.array_equal(np.asarray(m_t), np.asarray(m_ref)), (H, W, tile)
        assert np.array_equal(np.asarray(c_t), np.asarray(c_ref)), (H, W, tile)


@pytest.mark.slow
def test_hedm_reduce_exact_on_noisy_borders():
    """High-amplitude noise makes frame-border pixels threshold-sensitive:
    the fused kernel must still match the oracle bit-for-bit there (the
    naive fusion of input-replicated halos does not)."""
    from repro.kernels.hedm_reduce import hedm_reduce
    from repro.kernels.hedm_reduce_ref import reference
    for seed in range(5):
        for H, W, tiles in [(24, 24, (None, 8)),     # divisible
                            (20, 16, (8,)),          # H % tile != 0
                            (21, 24, (16, 4))]:      # partial last tile
            rng = np.random.default_rng(seed)
            frames = rng.integers(0, 400, (2, H, W)).astype(np.float32)
            dark = np.zeros((H, W), np.float32)
            m_ref, c_ref = reference(jnp.asarray(frames), jnp.asarray(dark),
                                     threshold=150.0)
            for tile in tiles:
                m, c = hedm_reduce(jnp.asarray(frames), jnp.asarray(dark),
                                   threshold=150.0, tile_rows=tile)
                assert np.array_equal(np.asarray(m), np.asarray(m_ref)), \
                    (seed, H, W, tile)
                assert np.array_equal(np.asarray(c), np.asarray(c_ref)), \
                    (seed, H, W, tile)


def test_hedm_reduce_vmem_budget_forces_tiling():
    """A small VMEM budget must row-tile large frames without changing the
    result (and the picked tile must actually be smaller than the frame)."""
    from repro.kernels.hedm_reduce import _pick_tile, hedm_reduce
    from repro.kernels.hedm_reduce_ref import reference
    assert _pick_tile(256, 256, 8 << 20) >= 256       # fits: one tile
    small = _pick_tile(256, 256, 1 << 18)             # 256 KB budget: tiles
    assert small < 256
    rng = np.random.default_rng(4)
    frames = rng.integers(0, 40, (1, 128, 64)).astype(np.float32)
    frames[0, 60:64, 30:34] += 2500
    dark = np.full((128, 64), 8.0, np.float32)
    m_ref, c_ref = reference(jnp.asarray(frames), jnp.asarray(dark),
                             threshold=150.0)
    m, c = hedm_reduce(jnp.asarray(frames), jnp.asarray(dark),
                       threshold=150.0, vmem_budget_bytes=1 << 17)
    assert np.array_equal(np.asarray(m), np.asarray(m_ref))
    assert np.array_equal(np.asarray(c), np.asarray(c_ref))


def test_hedm_reduce_auto_interpret_default():
    """interpret=None resolves by backend (interpreter off-TPU, compiled
    Mosaic on TPU) — the default path must run on whatever backend this is."""
    from repro.kernels.hedm_reduce import hedm_reduce
    frames = jnp.zeros((1, 16, 16), jnp.float32)
    dark = jnp.zeros((16, 16), jnp.float32)
    mask, counts = hedm_reduce(frames, dark)          # must not raise
    assert int(np.asarray(counts)[0]) == 0


def test_hedm_reduce_finds_only_real_spots():
    from repro.kernels.hedm_reduce import hedm_reduce
    rng = np.random.default_rng(1)
    frames = rng.integers(0, 30, (2, 96, 96)).astype(np.float32)
    dark = np.full((96, 96), 10.0, np.float32)
    _, counts = hedm_reduce(jnp.asarray(frames), jnp.asarray(dark),
                            threshold=500.0)
    assert int(np.asarray(counts).sum()) == 0   # pure noise -> no signal


# --------------------- model-level chunked vs naive -----------------------

def test_ssd_chunked_equals_naive_model_path():
    from repro.models.mamba2 import ssd_chunked, ssd_naive
    x = rand(30, (2, 64, 2, 4, 8))
    dt = jax.nn.softplus(rand(31, (2, 64, 2, 4)))
    A = -jnp.exp(rand(32, (2, 4)))
    Bm = rand(33, (2, 64, 2, 16))
    Cm = rand(34, (2, 64, 2, 16))
    y1, h1 = ssd_naive(x, dt, A, Bm, Cm)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-3)


def test_wkv_chunked_equals_naive_model_path():
    from repro.models.rwkv6 import wkv_chunked, wkv_naive
    r = rand(40, (2, 64, 3, 8))
    k = rand(41, (2, 64, 3, 8))
    v = rand(42, (2, 64, 3, 8))
    w = jax.nn.sigmoid(rand(43, (2, 64, 3, 8))) * 0.5 + 0.45
    u = rand(44, (3, 8))
    o1, s1 = wkv_naive(r, k, v, w, u)
    o2, s2 = wkv_chunked(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_blocked_attention_equals_dense():
    from repro.models.attention import (attention_bias, blocked_grouped_sdpa,
                                        grouped_sdpa)
    q = rand(50, (2, 256, 8, 32))
    k = rand(51, (2, 256, 4, 32))
    v = rand(52, (2, 256, 4, 32))
    for causal, win in [(True, 0), (True, 64), (False, 0)]:
        ref = grouped_sdpa(q, k, v,
                           attention_bias(256, 256, causal=causal, window=win),
                           32 ** -0.5)
        blk = blocked_grouped_sdpa(q, k, v, causal=causal, window=win,
                                   scale=32 ** -0.5, q_chunk=64)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(blk), atol=2e-5)

"""Serving: prefill+decode == full forward; continuous batching session."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServeSession, prefill_step

# per-arch prefill/decode compiles (seconds each) — slow lane; see pytest.ini
pytestmark = pytest.mark.slow

key = jax.random.PRNGKey(0)

ARCHS = ["qwen2_72b", "h2o_danube3_4b", "deepseek_v2_lite_16b", "zamba2_7b",
         "rwkv6_3b", "qwen3_moe_30b_a3b", "internlm2_20b", "qwen3_32b",
         "internvl2_2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_equals_forward(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(key, cfg)
    B, S = 2, 16
    if cfg.frontend.kind == "vision_patches":
        P = cfg.frontend.num_prefix_tokens
        toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S + 1 - P),
                                  0, cfg.vocab)
        img = jnp.ones((B, P, cfg.frontend.feature_dim), jnp.float32)
        full_in = {"tokens": toks, "image_embeds": img}
        pre_in = {"tokens": toks[:, :-1], "image_embeds": img}
    else:
        toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S + 1),
                                  0, cfg.vocab)
        full_in = {"tokens": toks}
        pre_in = {"tokens": toks[:, :S]}
    x, _ = M.forward(params, cfg, full_in, remat=False, inference=True)
    table = M.head_table(params, cfg)
    ref = jnp.einsum("bd,vd->bv", x[:, -1].astype(jnp.float32),
                     table.astype(jnp.float32))
    _, caches = prefill_step(params, cfg, pre_in, capacity=S + 8)
    dec, _ = M.decode_step(params, cfg, toks[:, -1:], caches)
    rel = float(jnp.max(jnp.abs(dec[:, :cfg.vocab] - ref[:, :cfg.vocab]))) / \
        (float(jnp.max(jnp.abs(ref[:, :cfg.vocab]))) + 1e-9)
    assert rel < 5e-3, f"{arch}: rel err {rel}"


def test_continuous_batching_session():
    cfg = get_smoke_config("qwen3_32b")
    params = M.init_model(key, cfg)
    sess = ServeSession(params, cfg, batch_slots=2, capacity=64)
    rng = np.random.default_rng(0)
    for rid in range(5):
        sess.submit(Request(request_id=rid,
                            prompt=rng.integers(0, cfg.vocab, 8,
                                                dtype=np.int32),
                            max_new_tokens=4))
    finished = sess.run_to_completion(max_steps=200)
    assert len(finished) == 5
    for req in finished:
        assert len(req.generated) == 4
        assert all(0 <= t < cfg.vocab for t in req.generated)


def test_continuous_batching_matches_single_stream():
    """A request decoded in a shared batch must equal the same request
    decoded alone (slot isolation)."""
    cfg = get_smoke_config("h2o_danube3_4b")
    params = M.init_model(key, cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 8, dtype=np.int32)
    outs = []
    for slots in (1, 3):
        sess = ServeSession(params, cfg, batch_slots=slots, capacity=64)
        sess.submit(Request(request_id=0, prompt=prompt.copy(),
                            max_new_tokens=5))
        if slots > 1:   # co-resident traffic in other slots
            sess.submit(Request(request_id=1,
                                prompt=rng.integers(0, cfg.vocab, 6,
                                                    dtype=np.int32),
                                max_new_tokens=5))
        done = sess.run_to_completion(max_steps=100)
        outs.append(next(r for r in done if r.request_id == 0).generated)
    assert outs[0] == outs[1]


def test_decode_chain_matches_batched_forward_rwkv():
    """Five decode steps from empty state == forward over the 5 tokens
    (state-based archs: exact recurrence equivalence)."""
    cfg = get_smoke_config("rwkv6_3b")
    params = M.init_model(key, cfg)
    toks = jax.random.randint(jax.random.fold_in(key, 9), (2, 5), 0,
                              cfg.vocab)
    caches = M.init_decode_state(cfg, 2, 16)
    logits = None
    for t in range(5):
        logits, caches = M.decode_step(params, cfg, toks[:, t:t + 1], caches)
    x, _ = M.forward(params, cfg, {"tokens": toks}, remat=False,
                     inference=True)
    table = M.head_table(params, cfg)
    ref = jnp.einsum("bd,vd->bv", x[:, -1].astype(jnp.float32),
                     table.astype(jnp.float32))
    rel = float(jnp.max(jnp.abs(logits - ref))) / \
        (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 5e-3, rel

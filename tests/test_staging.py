"""Staging framework: byte-exactness, traffic accounting, paper calibration."""
import numpy as np
import pytest
from conftest import make_fabric
from hypothesis_compat import given, settings, st

from repro.core.fabric import BGQ, Fabric, TPU_POD
from repro.core.iohook import (BroadcastEntry, StagingSpec, naive_per_rank_globs,
                               resolve_manifest, run_io_hook)
from repro.core.staging import (_stripes, stage_collective, stage_naive,
                                stage_pipelined)


def test_collective_staging_byte_exact():
    fab, paths = make_fabric()
    stage_collective(fab, paths)
    for host in fab.hosts:
        for p in paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])


def test_naive_staging_byte_exact():
    fab, paths = make_fabric(n_hosts=4)
    stage_naive(fab, paths)
    for host in fab.hosts:
        for p in paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])


def test_fs_traffic_collective_reads_dataset_once():
    fab, paths = make_fabric(n_hosts=16, n_files=2, size=1 << 14)
    rep, _ = stage_collective(fab, paths)
    assert rep.fs_bytes == 2 * (1 << 14)          # 1x dataset, not P x


def test_fs_traffic_naive_reads_dataset_p_times():
    fab, paths = make_fabric(n_hosts=16, n_files=2, size=1 << 14)
    rep, _ = stage_naive(fab, paths)
    assert rep.fs_bytes == 16 * 2 * (1 << 14)


def test_collective_wins_at_scale():
    """The paper's regime: thousands of nodes -> staged >> naive."""
    per_file = 577 * 2**20 // 736
    blob = np.zeros(per_file, np.uint8)
    t = {}
    for mode in ("collective", "naive"):
        fab = Fabric(n_hosts=4096, constants=BGQ)
        fab.fs.files["d/x.bin"] = blob
        paths = ["d/x.bin"] * 1                  # single file per step
        if mode == "collective":
            rep, _ = stage_collective(fab, ["d/x.bin"])
        else:
            rep, _ = stage_naive(fab, ["d/x.bin"])
        t[mode] = rep.total_time
    assert t["naive"] > t["collective"]


def test_paper_anchor_numbers():
    """8192 nodes / 577 MB / 736 files: staging ~35 s, end-to-end ~47 s,
    naive ~210-220 s (Fig. 10/11 + §VI-B)."""
    per_file = 577 * 2**20 // 736
    blob = np.zeros(per_file, np.uint8)
    fab = Fabric(n_hosts=8192, constants=BGQ)
    paths = []
    for i in range(736):
        fab.fs.files[f"d/{i}.bin"] = blob
        paths.append(f"d/{i}.bin")
    rep, _ = stage_collective(fab, paths)
    assert 25 < rep.total_time < 50
    read_phase = 577 * 2**20 / BGQ.local_read_bw
    assert 40 < rep.total_time + read_phase < 60        # paper: 46.75 s
    naive_time = 8192 * 577 * 2**20 / BGQ.fs_rand_bw
    assert 180 < naive_time < 260                       # paper: 210 s
    ratio = (naive_time) / (rep.total_time + read_phase)
    assert 3.5 < ratio < 6.0                            # paper: 4.7x


@given(total=st.integers(1, 10_000), parts=st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_stripes_cover_and_disjoint(total, parts):
    stripes = _stripes(total, parts)
    assert len(stripes) == parts
    covered = 0
    for off, sz in stripes:
        assert off == covered
        covered += sz
    assert covered == total


@given(n_hosts=st.integers(1, 32), size=st.integers(1, 4096),
       n_files=st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_staging_equivalence_property(n_hosts, size, n_files):
    """Collective and naive staging produce identical node-local contents."""
    fab_c, paths = make_fabric(n_hosts, n_files, size, seed=size)
    fab_n, _ = make_fabric(n_hosts, n_files, size, seed=size)
    stage_collective(fab_c, paths)
    stage_naive(fab_n, paths)
    for hc, hn in zip(fab_c.hosts, fab_n.hosts):
        for p in paths:
            assert np.array_equal(hc.store.data[p], hn.store.data[p])


def test_zero_copy_replicas_share_source_memory():
    """Replica delivery hands out read-only VIEWS of the FS buffer — no
    per-host copies — while staying byte-exact."""
    fab, paths = make_fabric(n_hosts=8)
    stage_collective(fab, paths)
    for host in fab.hosts:
        for p in paths:
            replica = host.store.data[p]
            assert np.shares_memory(replica, fab.fs.files[p])
            assert not replica.flags.writeable
            assert np.array_equal(replica, fab.fs.files[p])


def test_zero_copy_byte_accounting_unchanged():
    """fs_bytes/net_bytes under the zero-copy path: FS traffic is 1x the
    dataset; the ring all-gather moves stripe * P * (P-1) bytes."""
    n_hosts, n_files, size = 16, 3, 1 << 14
    fab, paths = make_fabric(n_hosts=n_hosts, n_files=n_files, size=size)
    rep, _ = stage_collective(fab, paths)
    total = n_files * size
    assert rep.fs_bytes == total
    stripe = (total + n_hosts - 1) // n_hosts
    assert rep.net_bytes == stripe * n_hosts * (n_hosts - 1)
    # node-local write accounting still sees the full replicated volume
    assert all(h.store.bytes_written == total for h in fab.hosts)


def test_write_time_accumulates_across_files():
    """Seed bug: multi-file write phase took a max; files on one host
    serialize on local-store bandwidth, so times must accumulate."""
    n_files, size = 4, 1 << 16
    fab, paths = make_fabric(n_hosts=4, n_files=n_files, size=size)
    rep, _ = stage_collective(fab, paths)
    assert rep.write_time == pytest.approx(n_files * size / BGQ.local_bw)


def test_pipelined_staging_byte_exact_and_accounted():
    fab, paths = make_fabric(n_hosts=8, n_files=3, size=1 << 16)
    rep, _ = stage_pipelined(fab, paths, chunk_bytes=1 << 12)
    for host in fab.hosts:
        for p in paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])
    assert rep.mode == "pipelined"
    assert rep.fs_bytes == 3 * (1 << 16)          # still 1x dataset
    assert rep.n_chunks > 3                        # actually chunked


def test_pipelined_overlap_beats_serial_phases():
    """Chunked read/all-gather overlap hides phase time: pipelined total is
    below collective's, by (close to) the modeled overlap_saved."""
    size = 8 << 20
    fab_c, paths = make_fabric(n_hosts=64, n_files=2, size=size)
    fab_p, _ = make_fabric(n_hosts=64, n_files=2, size=size)
    rep_c, _ = stage_collective(fab_c, paths)
    rep_p, _ = stage_pipelined(fab_p, paths, chunk_bytes=1 << 15)
    assert rep_p.overlap_saved > 0
    assert rep_p.total_time < rep_c.total_time
    assert rep_p.total_time + rep_p.overlap_saved >= 0.9 * (
        rep_c.stage_time + rep_c.comm_time)


def test_pipelined_stage_time_matches_collective():
    """Per-file sync overheads must accumulate OUTSIDE the FS busy stream:
    pipelined stage_time equals collective's, and pipelined never models
    slower than serial two-phase — even for many small files where the
    overheads dominate."""
    def mk():
        fab = Fabric(n_hosts=64, constants=BGQ)
        blob = np.zeros(1 << 20, np.uint8)
        paths = []
        for i in range(50):
            fab.fs.files[f"d/{i}"] = blob
            paths.append(f"d/{i}")
        return fab, paths

    fab_c, paths = mk()
    fab_p, _ = mk()
    rep_c, _ = stage_collective(fab_c, paths)
    rep_p, _ = stage_pipelined(fab_p, paths)
    assert rep_p.stage_time == pytest.approx(rep_c.stage_time, abs=1e-12)
    assert rep_p.total_time <= rep_c.total_time + 1e-12


def test_iohook_pipelined_mode():
    fab = Fabric(n_hosts=4, constants=BGQ)
    for i in range(3):
        fab.fs.put(f"scans/s{i}.bin", np.full(1 << 12, i, np.uint8))
    res = run_io_hook(fab, StagingSpec([BroadcastEntry(("scans/*.bin",))]),
                      mode="pipelined")
    assert res.reports[0].mode == "pipelined"
    for host in fab.hosts:
        for i in range(3):
            assert np.array_equal(host.store.data[f"scans/s{i}.bin"],
                                  fab.fs.files[f"scans/s{i}.bin"])


def test_iohook_declarative_spec_roundtrip():
    spec = StagingSpec([BroadcastEntry(files=("scripts/*.py",), dest="/tmp")])
    spec2 = StagingSpec.from_json(spec.to_json())
    assert spec2.broadcasts[0].files == ("scripts/*.py",)


def test_iohook_stages_glob_matches_and_pins():
    fab = Fabric(n_hosts=4, constants=BGQ)
    for i in range(3):
        fab.fs.put(f"scripts/s{i}.py", np.ones(64, np.uint8))
    fab.fs.put("other/data.bin", np.ones(64, np.uint8))
    res = run_io_hook(fab, StagingSpec([BroadcastEntry(("scripts/*.py",))]))
    assert len(res.resolved_files) == 3
    for host in fab.hosts:
        assert "scripts/s0.py" in host.store.pinned
        assert "other/data.bin" not in host.store.data


def test_hook_charges_leader_broadcast_into_report():
    """The on_root metadata broadcast is real wire time: it lands in
    StagingReport.broadcast_time (counted by total_time), while
    HookResult.metadata_time keeps only the glob phase — the two sum to
    the hook's end-to-end time."""
    from repro.core.leader import LeaderGroup, manifest_bytes
    fab = Fabric(n_hosts=64, constants=BGQ)
    files = []
    for i in range(5):
        fab.fs.put(f"scans/s{i}.bin", np.ones(1 << 10, np.uint8))
        files.append(f"scans/s{i}.bin")
    res = run_io_hook(fab, StagingSpec([BroadcastEntry(("scans/*.bin",))]))
    rep = res.reports[0]
    expect = fab.net.broadcast_time(manifest_bytes(files), fab.n_hosts)
    assert rep.broadcast_time == pytest.approx(expect)
    assert rep.broadcast_time > 0.0
    assert rep.total_time == pytest.approx(
        rep.stage_time + rep.comm_time + rep.write_time + rep.broadcast_time)
    # accounting closes: glob metadata + per-entry report times = total
    assert res.metadata_time + rep.total_time == pytest.approx(res.total_time)
    # the engine alone (no hook) never charges a broadcast
    fab2, paths = make_fabric()
    rep2, _ = stage_collective(fab2, paths)
    assert rep2.broadcast_time == 0.0
    # on_root returns the broadcast duration alongside the result
    lead = LeaderGroup(fab)
    result, bcast = lead.on_root(lambda: files)
    assert result == files and bcast == pytest.approx(expect)


def test_leader_glob_beats_per_rank_glob():
    """§IV: one rank globs + broadcast << every rank globbing."""
    fab = Fabric(n_hosts=64, ranks_per_host=16, constants=BGQ)
    for i in range(20):
        fab.fs.put(f"s/f{i}.py", np.ones(8, np.uint8))
    _, t_leader = resolve_manifest(fab, ["s/*.py"], 0.0)
    fab2 = Fabric(n_hosts=64, ranks_per_host=16, constants=BGQ)
    for i in range(20):
        fab2.fs.put(f"s/f{i}.py", np.ones(8, np.uint8))
    t_naive = naive_per_rank_globs(fab2, ["s/*.py"])
    assert t_naive > 10 * t_leader


def test_staged_loader_yields_batches():
    import jax.numpy as jnp
    from repro.data.pipeline import StagedLoader, write_token_shards
    fab = Fabric(n_hosts=4)
    write_token_shards(fab, n_shards=4, tokens_per_shard=4096, vocab=1000)
    loader = StagedLoader(fab, "data/*.bin", batch=2, seq=64)
    rep = loader.stage(collective=True)
    assert rep.fs_bytes == 4 * 4096 * 4          # 1x dataset
    b = next(loader.batches())
    assert b["tokens"].shape == (2, 64)
    assert int(jnp.max(b["tokens"])) < 1000


@given(n_hosts=st.sampled_from([2, 8, 64, 512, 4096]))
@settings(max_examples=5, deadline=None)
def test_collective_time_model_sublinear_in_hosts(n_hosts):
    """Staged time grows only logarithmically with P (never linearly) and
    beats the naive bandwidth lower bound once replication volume dominates
    per-file collective overhead (64 MB @ >=512 hosts)."""
    blob = np.zeros(64 << 20, np.uint8)
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    fab.fs.files["d/x.bin"] = blob
    rep, _ = stage_collective(fab, ["d/x.bin"])
    # log-ish growth: stage_time bounded by base + log2(P) * coeff + bw
    bound = (BGQ.coll_latency_base + BGQ.coll_latency_log * 13
             + BGQ.fs_op_latency + blob.size / BGQ.fs_seq_bw) * 1.01
    assert rep.stage_time <= bound
    if n_hosts >= 512:
        naive_lb = n_hosts * blob.size / BGQ.fs_rand_bw
        assert rep.stage_time < naive_lb

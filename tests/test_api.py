"""Unified staging client API: typed configs, engine registry, client
parity with the pre-redesign entrypoints, and session-scoped campaigns."""
import json

import numpy as np
import pytest

from repro.core.api import (ENGINES, BroadcastEntry, ClientSession,
                            CollectiveConfig, EngineConfig, EngineRegistry,
                            NaiveConfig, PipelinedConfig, Report,
                            ServiceConfig, StagingClient, StagingSpec,
                            StreamConfig, as_spec)
from repro.core.fabric import BGQ, Fabric


def make_fabric(n_hosts=8, n_files=4, size=1 << 14, seed=0, prefix="d"):
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    rng = np.random.default_rng(seed)
    paths = []
    for i in range(n_files):
        p = f"{prefix}/f{i}.bin"
        fab.fs.put(p, rng.integers(0, 255, size, dtype=np.uint8))
        paths.append(p)
    return fab, paths


def assert_replicas_exact(fab, paths):
    for host in fab.hosts:
        for p in paths:
            assert np.array_equal(host.store.data[p], fab.fs.files[p])


# ---------------------------------------------------------------------------
# typed config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [0, -1, -(8 << 20)])
def test_pipelined_config_rejects_bad_chunk(bad):
    with pytest.raises(ValueError, match="chunk_bytes must be a positive"):
        PipelinedConfig(chunk_bytes=bad)


@pytest.mark.parametrize("bad", [0.0, -2.0])
def test_stream_config_rejects_bad_rate(bad):
    with pytest.raises(ValueError, match="rate_hz must be a positive"):
        StreamConfig(rate_hz=bad)


@pytest.mark.parametrize("bad", [0, -1024])
def test_stream_config_rejects_bad_window(bad):
    with pytest.raises(ValueError, match="window_bytes must be a positive"):
        StreamConfig(window_bytes=bad)


@pytest.mark.parametrize("bad", [0, -1, -(1 << 30)])
def test_service_config_rejects_bad_budget(bad):
    with pytest.raises(ValueError, match="budget_bytes must be a positive"):
        ServiceConfig(budget_bytes=bad)


def test_service_config_rejects_non_batch_engine_at_construction():
    """A known non-batch engine fails FAST — at config construction, not
    at the first (lazily-built) service touch."""
    with pytest.raises(ValueError, match="must be a batch engine"):
        ServiceConfig(budget_bytes=1 << 20, engine=StreamConfig())


def test_stage_pin_knob_on_convenience_forms():
    """pin=False on a bare pattern/path list keeps the replicas
    evictable — the bare-engine-call semantics of the migration table."""
    fab, paths = make_fabric(n_hosts=2)
    rep = StagingClient(fab).stage("d/*.bin", CollectiveConfig(), pin=False)
    assert rep.resolved_files == paths
    for host in fab.hosts:
        assert not host.store.pinned
    fab2, _ = make_fabric(n_hosts=2)
    StagingClient(fab2).stage("d/*.bin", CollectiveConfig())  # default pins
    assert all(p in fab2.hosts[0].store.pinned for p in paths)


def test_stream_window_smaller_than_one_frame_rejected():
    """A bounded window that cannot hold even the largest frame is a
    config error surfaced BEFORE ingest wedges."""
    fab, paths = make_fabric(n_hosts=2, n_files=3, size=1 << 12)
    client = StagingClient(fab)
    with pytest.raises(ValueError, match="smaller than the largest frame"):
        client.stage(paths, StreamConfig(window_bytes=1 << 10),
                     resolve=False)


def test_valid_configs_construct():
    CollectiveConfig()
    NaiveConfig()
    PipelinedConfig(chunk_bytes=1 << 20)
    StreamConfig()                                   # replay, unbounded
    StreamConfig(rate_hz=10.0, window_bytes=1 << 20)
    ServiceConfig(budget_bytes=1 << 20, engine=PipelinedConfig())


# ---------------------------------------------------------------------------
# spec round-trip through typed configs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("config", [
    None,
    CollectiveConfig(),
    NaiveConfig(),
    PipelinedConfig(chunk_bytes=1 << 20),
    StreamConfig(rate_hz=4.0, window_bytes=1 << 16),
])
def test_spec_json_roundtrip_with_config(config):
    spec = StagingSpec([BroadcastEntry(files=("scan/*.bin",), pin=False),
                        BroadcastEntry(files=("dark/*.bin",))],
                       config=config)
    spec2 = StagingSpec.from_json(spec.to_json())
    assert spec2 == spec
    assert spec2.config == config                    # typed config survives


def test_spec_json_legacy_payload_still_loads():
    """Pre-redesign JSON (no engine block) parses with config=None."""
    spec = StagingSpec.from_json(
        json.dumps({"broadcasts": [{"files": ["a/*.bin"]}]}))
    assert spec.broadcasts[0].files == ("a/*.bin",)
    assert spec.config is None


def test_spec_json_invalid_engine_params_loud():
    with pytest.raises(ValueError, match="rate_hz must be a positive"):
        StagingSpec.from_json(json.dumps({
            "broadcasts": [{"files": ["a"]}],
            "engine": {"name": "stream", "params": {"rate_hz": -1.0}}}))


def test_as_spec_normalizes_patterns():
    assert as_spec("a/*.bin").broadcasts[0].files == ("a/*.bin",)
    assert as_spec(["a", "b"]).broadcasts[0].files == ("a", "b")
    spec = StagingSpec([BroadcastEntry(("x",))])
    assert as_spec(spec) is spec


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

def test_registry_holds_builtin_engines():
    assert ENGINES.names() == ["collective", "naive", "pipelined",
                           "replicated", "stream", "wan"]
    assert ENGINES.names(batch_only=True) == ["collective", "naive",
                                              "pipelined", "replicated"]
    assert ENGINES.name_of(PipelinedConfig()) == "pipelined"
    cfg = ENGINES.config_for("pipelined", chunk_bytes=123)
    assert cfg == PipelinedConfig(chunk_bytes=123)


def test_registry_unknown_mode_lists_registered_engines():
    with pytest.raises(ValueError, match="unknown staging mode") as exc:
        ENGINES.config_for("two_phase")
    for name in ("collective", "naive", "pipelined", "stream"):
        assert name in str(exc.value)


def test_registry_unknown_parameter_loud():
    with pytest.raises(ValueError, match="unknown parameter"):
        ENGINES.config_for("pipelined", chunk_byte=1)  # typo'd stage_kw


def test_registry_duplicate_registration_rejected():
    reg = EngineRegistry.default()
    with pytest.raises(ValueError, match="already registered"):
        reg.register("collective", CollectiveConfig, lambda *a, **k: None)


def test_registry_batch_only_excludes_stream():
    with pytest.raises(ValueError, match="not batch-capable"):
        ENGINES.config_for("stream", batch_only=True)


def test_custom_engine_plugs_in_through_registry():
    """Adding an engine is one register() call: the client dispatches to
    it straight from its typed config — on the direct path, as the
    SERVICE engine, and through spec JSON (given the same registry)."""
    from dataclasses import dataclass

    from repro.core.staging import stage_naive

    calls = {"n": 0}

    @dataclass(frozen=True)
    class EchoConfig(EngineConfig):
        tag: str = "echo"

    def stage_echo(fabric, paths, t0=0.0, tag="echo"):
        calls["tag"] = tag
        calls["n"] += 1
        return stage_naive(fabric, paths, t0)

    reg = EngineRegistry.default()
    reg.register("echo", EchoConfig, stage_echo)
    fab, paths = make_fabric(n_hosts=2)
    rep = StagingClient(fab, registry=reg).stage(
        paths, EchoConfig(tag="hi"), resolve=False)
    assert calls["tag"] == "hi"
    assert rep.engine == "echo"
    assert_replicas_exact(fab, paths)

    # the client's registry reaches the catalog path too: a custom engine
    # can be the staging service's engine
    fab2, paths2 = make_fabric(n_hosts=2, prefix="scans")
    client = StagingClient(
        fab2, service=ServiceConfig(budget_bytes=1 << 20,
                                    engine=EchoConfig(tag="svc")),
        registry=reg)
    srep = client.stage("scans/*.bin", session="alice")
    assert srep.engine == "service" and calls["tag"] == "svc"
    assert_replicas_exact(fab2, paths2)

    # and spec JSON round-trips the custom config through that registry
    spec = StagingSpec([BroadcastEntry(("scans/*",))],
                       config=EchoConfig(tag="wire"))
    spec2 = StagingSpec.from_json(spec.to_json(registry=reg), registry=reg)
    assert spec2 == spec


def test_service_rejects_non_batch_engine_with_clear_message():
    """A REGISTERED non-batch engine (stream) is not mislabeled as
    unknown — the message says it is not batch-capable."""
    from repro.core.datasvc import StagingService
    fab, _ = make_fabric(n_hosts=2)
    with pytest.raises(ValueError, match="not.*batch-capable"):
        StagingService(fab, budget_bytes=1 << 20, engine=StreamConfig())
    with pytest.raises(ValueError, match="not.*batch-capable"):
        StagingService(fab, budget_bytes=1 << 20, mode="stream")
    with pytest.raises(ValueError, match="unknown staging mode"):
        StagingService(fab, budget_bytes=1 << 20, mode="bogus")


# ---------------------------------------------------------------------------
# client parity vs the pre-redesign entrypoints
# ---------------------------------------------------------------------------

ENGINE_CASES = [
    ("collective", None, CollectiveConfig()),
    ("pipelined", {"chunk_bytes": 1 << 12},
     PipelinedConfig(chunk_bytes=1 << 12)),
    ("naive", None, NaiveConfig()),
    ("stream", {"rate_hz": 5.0}, StreamConfig(rate_hz=5.0)),
]


@pytest.mark.parametrize("mode,stage_kw,config", ENGINE_CASES)
def test_client_parity_with_legacy_hook(mode, stage_kw, config):
    """Every engine reached through client.stage is byte-exact and
    simulated-time-identical to the legacy run_io_hook signature."""
    from repro.core.iohook import run_io_hook

    fab_old, paths = make_fabric()
    fab_new, _ = make_fabric()
    spec = StagingSpec([BroadcastEntry(("d/*.bin",))])
    with pytest.deprecated_call():
        old = run_io_hook(fab_old, spec, mode=mode, stage_kw=stage_kw)
    new = StagingClient(fab_new).stage(spec, config)

    assert new.engine == mode
    assert new.total_time == old.total_time
    assert new.metadata_time == old.metadata_time
    assert new.resolved_files == old.resolved_files
    assert len(new.reports) == len(old.reports)
    for a, b in zip(new.reports, old.reports):
        assert a.total_time == b.total_time
        assert a.stage_time == b.stage_time
        assert a.comm_time == b.comm_time
        assert a.write_time == b.write_time
        assert a.broadcast_time == b.broadcast_time
        assert (a.fs_bytes, a.net_bytes, a.mode) == \
            (b.fs_bytes, b.net_bytes, b.mode)
    assert_replicas_exact(fab_new, paths)
    for host_old, host_new in zip(fab_old.hosts, fab_new.hosts):
        for p in paths:
            assert np.array_equal(host_old.store.data[p],
                                  host_new.store.data[p])
            assert (p in host_old.store.pinned) == (p in host_new.store.pinned)


@pytest.mark.parametrize("mode,config", [
    ("collective", CollectiveConfig()),
    ("pipelined", PipelinedConfig()),
    ("naive", NaiveConfig()),
])
def test_client_parity_with_direct_engine_call(mode, config):
    """resolve=False runs the bare engine: no glob, no broadcast, no pin —
    identical accounting to calling the stage function directly."""
    fab_a, paths = make_fabric(n_hosts=4)
    fab_b, _ = make_fabric(n_hosts=4)
    rep_direct, t_direct = ENGINES.stage_fn(mode)(fab_a, paths, 1.5)
    spec = StagingSpec([BroadcastEntry(tuple(paths), pin=False)])
    crep = StagingClient(fab_b).stage(spec, config, t0=1.5, resolve=False)
    assert crep.metadata_time == 0.0
    assert crep.broadcast_time == 0.0
    # the entry report carries the engine's exact accounting
    assert crep.reports[0].total_time == rep_direct.total_time
    assert 1.5 + crep.reports[0].total_time == t_direct
    assert crep.total_time == pytest.approx(rep_direct.total_time)
    assert crep.reports[0].fs_bytes == rep_direct.fs_bytes
    assert not fab_b.hosts[0].store.pinned          # pin=False honored
    assert_replicas_exact(fab_b, paths)


def test_client_service_path_parity_and_coalescing():
    """The catalog path through the client matches the legacy
    run_io_hook(service=...) accounting, and concurrent client calls
    coalesce into one stage."""
    from repro.core.datasvc import StagingService
    from repro.core.iohook import run_io_hook

    fab_old, paths = make_fabric(n_hosts=4, prefix="scans")
    fab_new, _ = make_fabric(n_hosts=4, prefix="scans")
    spec = StagingSpec([BroadcastEntry(("scans/*.bin",))])

    svc_old = StagingService(fab_old, budget_bytes=1 << 20)
    with pytest.deprecated_call():
        old1 = run_io_hook(fab_old, spec, service=svc_old, session="alice")
        old2 = run_io_hook(fab_old, spec, t0=old1.total_time / 2,
                           service=svc_old, session="bob")

    svc_new = StagingService(fab_new, budget_bytes=1 << 20)
    client = StagingClient(fab_new, service=svc_new)
    new1 = client.stage(spec, session="alice")
    new2 = client.stage(spec, t0=new1.total_time / 2, session="bob")

    assert new1.engine == "service" and new1.service is svc_new
    for old, new in ((old1, new1), (old2, new2)):
        assert new.total_time == old.total_time
        assert new.metadata_time == old.metadata_time
        assert new.resolved_files == old.resolved_files
        assert [l.t_ready for l in new.leases] == \
            [l.t_ready for l in old.leases]
    assert svc_new.stats.stages == svc_old.stats.stages == 1
    assert svc_new.stats.coalesced == 1              # second call joined
    assert fab_new.fs.bytes_read == fab_old.fs.bytes_read
    assert_replicas_exact(fab_new, paths)


def test_client_builds_service_from_config():
    fab, paths = make_fabric(n_hosts=2, prefix="scans")
    client = StagingClient(fab, service=ServiceConfig(
        budget_bytes=1 << 20, engine=PipelinedConfig(chunk_bytes=1 << 12)))
    rep = client.stage("scans/*.bin", session="alice")
    assert rep.engine == "service"
    assert rep.reports[0].mode == "pipelined"        # service engine config
    assert rep.reports[0].n_chunks > 1
    assert_replicas_exact(fab, paths)


def test_service_config_rejected_per_call():
    """A per-call ServiceConfig would silently reroute later config-less
    calls through the catalog (leaking unscoped leases) — it belongs in
    the constructor, and stage() says so."""
    fab, paths = make_fabric(n_hosts=2, prefix="scans")
    client = StagingClient(fab)
    with pytest.raises(ValueError, match="configures the client"):
        client.stage("scans/*.bin", ServiceConfig(budget_bytes=1 << 20))
    # the client stayed engine-only: config-less stage is still direct
    rep = client.stage("scans/*.bin")
    assert rep.engine == "collective" and rep.leases == []
    assert client.service is None


def test_attached_service_wins_over_spec_embedded_config():
    """On a service-attached client a config-less stage routes through
    the catalog even when the spec embeds an engine config — a session
    must never silently fall back to an unleased direct stage."""
    fab, paths = make_fabric(n_hosts=2, prefix="scans")
    client = StagingClient(fab, service=ServiceConfig(budget_bytes=1 << 20))
    spec = StagingSpec([BroadcastEntry(("scans/*.bin",))],
                       config=CollectiveConfig())
    with client.session("alice") as sess:
        rep = sess.stage(spec)
        assert rep.engine == "service"
        assert len(rep.leases) == 1              # leased, scope-owned
        assert len(client.service.catalog) == 1
    assert client.service.catalog[rep.leases[0].dataset].lease_count == 0
    # plain client.stage (no session scope) routes through the catalog too
    rep2 = client.stage(spec, t0=rep.total_time + 1.0, session="bob")
    assert rep2.engine == "service"
    # an EXPLICIT engine config is the escape hatch to a direct stage
    rep3 = client.stage(spec, NaiveConfig(), t0=rep.total_time + 2.0)
    assert rep3.engine == "naive" and rep3.leases == []


# ---------------------------------------------------------------------------
# unified Report invariants
# ---------------------------------------------------------------------------

def test_report_accounting_invariants_direct_path():
    fab, paths = make_fabric(n_hosts=16, n_files=3)
    rep = StagingClient(fab).stage("d/*.bin", CollectiveConfig())
    total = sum(fab.fs.size(p) for p in paths)
    assert rep.total_bytes == rep.staged_bytes == total
    assert rep.fs_bytes == total                     # 1x dataset
    assert rep.delivered_bytes == 16 * total         # replica per host
    assert rep.broadcast_time > 0.0                  # manifest push charged
    assert rep.metadata_time > 0.0
    assert rep.accounting_closes()
    r = rep.reports[0]
    assert r.total_time == pytest.approx(
        rep.stage_time + rep.comm_time + rep.write_time + rep.broadcast_time)


def test_report_stream_engine_reads_no_fs_bytes():
    fab, paths = make_fabric(n_hosts=4)
    rep = StagingClient(fab).stage("d/*.bin", StreamConfig(rate_hz=100.0))
    assert rep.engine == "stream"
    assert rep.fs_bytes == 0                         # never read back
    assert rep.delivered_bytes == 4 * rep.total_bytes
    assert rep.accounting_closes()
    assert_replicas_exact(fab, paths)


# ---------------------------------------------------------------------------
# legacy shim behaviour
# ---------------------------------------------------------------------------

def test_run_io_hook_unknown_mode_lists_registered_engines():
    from repro.core.iohook import run_io_hook
    fab, _ = make_fabric(n_hosts=2)
    spec = StagingSpec([BroadcastEntry(("d/*.bin",))])
    with pytest.raises(ValueError, match="unknown staging mode") as exc:
        with pytest.deprecated_call():
            run_io_hook(fab, spec, mode="two_phase")
    for name in ENGINES.names():
        assert name in str(exc.value)


def test_run_io_hook_legacy_collective_flag_honored():
    from repro.core.iohook import run_io_hook
    fab, paths = make_fabric(n_hosts=2)
    with pytest.deprecated_call():
        res = run_io_hook(fab, StagingSpec([BroadcastEntry(("d/*.bin",))]),
                          collective=False)
    assert res.reports[0].mode == "naive"
    assert_replicas_exact(fab, paths)


def test_run_io_hook_legacy_stream_pin_paths_stage_kw_honored():
    """The pre-redesign escape hatch — explicit pin_paths in stage_kw for
    mode='stream' with an unpinned entry — keeps working via the shim
    (pinned AT INGEST, surviving window eviction)."""
    from repro.core.iohook import run_io_hook
    fab = Fabric(n_hosts=2, constants=BGQ)
    paths = []
    for i in range(4):
        p = f"p/{i}.bin"
        fab.fs.put(p, np.full(1 << 10, i, np.uint8))
        paths.append(p)
    spec = StagingSpec([BroadcastEntry(("p/*.bin",), pin=False)])
    with pytest.deprecated_call():
        res = run_io_hook(fab, spec, mode="stream",
                          stage_kw={"window_bytes": 2 << 10,
                                    "pin_paths": [paths[0]]})
    assert res.reports[0].n_chunks == 4
    for host in fab.hosts:
        assert paths[0] in host.store.data       # pinned frame survived
        assert paths[0] in host.store.pinned
        assert paths[1] not in host.store.data   # unpinned ones slid out


def test_stream_config_pin_paths_normalizes_and_roundtrips():
    cfg = StreamConfig(pin_paths=["a", "b"])     # list normalizes to tuple
    assert cfg.pin_paths == ("a", "b")
    assert cfg == StreamConfig(pin_paths=("a", "b"))
    spec = StagingSpec([BroadcastEntry(("p/*",))], config=cfg)
    assert StagingSpec.from_json(spec.to_json()) == spec


def test_resolve_false_rejected_on_catalog_path():
    """resolve=False must not be silently ignored (re-globbing concrete
    paths as patterns); the catalog path refuses it loudly."""
    fab, paths = make_fabric(n_hosts=2, prefix="scans")
    client = StagingClient(fab, service=ServiceConfig(budget_bytes=1 << 20))
    with pytest.raises(ValueError, match="resolve=False is not supported"):
        client.stage(paths, resolve=False)


def test_run_io_hook_honors_spec_embedded_config():
    """A spec that fully selects its transport (the JSON engine block)
    stages identically through the shim and the client; explicit legacy
    arguments still override it."""
    from repro.core.iohook import run_io_hook
    fab_a, paths = make_fabric()
    fab_b, _ = make_fabric()
    spec = StagingSpec.from_json(StagingSpec(
        [BroadcastEntry(("d/*.bin",))],
        config=PipelinedConfig(chunk_bytes=512)).to_json())
    with pytest.deprecated_call():
        old = run_io_hook(fab_a, spec)
    new = StagingClient(fab_b).stage(spec)
    assert old.reports[0].mode == new.reports[0].mode == "pipelined"
    assert old.reports[0].n_chunks == new.reports[0].n_chunks > 4
    assert old.total_time == new.total_time
    # explicit legacy args still win over the embedded config
    fab_c, _ = make_fabric()
    with pytest.deprecated_call():
        res = run_io_hook(fab_c, spec, collective=False)
    assert res.reports[0].mode == "naive"


def test_service_rejects_conflicting_engine_and_legacy_args():
    from repro.core.datasvc import StagingService
    fab, _ = make_fabric(n_hosts=2)
    with pytest.raises(ValueError, match="not both"):
        StagingService(fab, budget_bytes=1 << 20, mode="pipelined",
                       engine=NaiveConfig())
    with pytest.raises(ValueError, match="not both"):
        StagingService(fab, budget_bytes=1 << 20,
                       stage_kw={"chunk_bytes": 1 << 12},
                       engine=PipelinedConfig())


def test_stream_stager_honors_config_pin_paths():
    fab, _ = make_fabric(n_hosts=2)
    client = StagingClient(fab)
    stager = client.stream_stager(
        StreamConfig(window_bytes=2 << 10, pin_paths=("s/0.bin",)))
    recs = []
    for i in range(4):
        rec = stager.ingest(f"s/{i}.bin", np.full(1 << 10, i, np.uint8),
                            float(i))
        stager.release(rec.path, rec.t_avail)
        recs.append(rec)
    assert "s/0.bin" in stager._resident         # pre-pinned: survived
    assert "s/1.bin" not in stager._resident     # unpinned: slid out
    with pytest.raises(ValueError, match="needs a StreamConfig"):
        client.stream_stager(CollectiveConfig())
    with pytest.raises(ValueError, match="window_bytes is required"):
        client.stream_stager(StreamConfig(rate_hz=1.0))


def test_run_io_hook_bad_stage_kw_loud():
    from repro.core.iohook import run_io_hook
    fab, _ = make_fabric(n_hosts=2)
    spec = StagingSpec([BroadcastEntry(("d/*.bin",))])
    with pytest.raises(ValueError, match="unknown parameter"):
        with pytest.deprecated_call():
            run_io_hook(fab, spec, mode="pipelined",
                        stage_kw={"chunk": 1 << 12})


# ---------------------------------------------------------------------------
# session-scoped campaigns (auto-released leases)
# ---------------------------------------------------------------------------

def service_client(n_hosts=4, budget_files=8):
    fab, paths = make_fabric(n_hosts=n_hosts, prefix="scans")
    client = StagingClient(
        fab, service=ServiceConfig(budget_bytes=budget_files * (1 << 14)))
    return fab, paths, client


def test_client_session_releases_on_exit():
    fab, paths, client = service_client()
    with client.session("alice") as sess:
        rep = sess.stage("scans/*.bin")
        name = rep.leases[0].dataset
        assert client.service.catalog[name].lease_count == 1
    entry = client.service.catalog[name]
    assert entry.lease_count == 0                    # auto-released
    assert entry.t_unleased >= rep.leases[0].t_ready


def test_client_session_releases_under_exception():
    fab, paths, client = service_client()
    with pytest.raises(RuntimeError, match="boom"):
        with client.session("alice") as sess:
            rep = sess.stage("scans/*.bin")
            raise RuntimeError("boom")
    name = rep.leases[0].dataset
    assert client.service.catalog[name].lease_count == 0


def test_client_session_kills_the_wedge_footgun():
    """Two sessions that 'forget' to release: with context scoping, a
    third admission that needs their memory no longer wedges."""
    fab = Fabric(n_hosts=2, constants=BGQ)
    rng = np.random.default_rng(0)
    for d in range(3):
        for i in range(4):
            fab.fs.put(f"d{d}/f{i}.bin",
                       rng.integers(0, 255, 1 << 12, dtype=np.uint8))
    client = StagingClient(fab,
                           service=ServiceConfig(budget_bytes=8 * (1 << 12)))
    svc = client.service
    for d in range(3):
        svc.register(f"d{d}", patterns=[f"d{d}/f*.bin"])
    with client.session("alice") as a, client.session("bob") as b:
        a.acquire("d0", 0.0)
        b.acquire("d1", 0.0)
        # no releases inside the scope — the old footgun
    lease = svc.session("carol").acquire("d2", 100.0)  # would have wedged
    assert lease.t_ready >= 100.0
    assert svc.stats.evictions >= 1


def test_client_session_delegates_to_analysis_session():
    fab, paths, client = service_client()
    with client.session("alice") as sess:
        assert isinstance(sess, ClientSession)
        assert sess.session_id == "alice"
        srep = sess.stage("scans/*.bin")
        out = np.arange(100, dtype=np.float32)
        path, t_put = sess.put_result("r", out, srep.total_time + 1.0)
        rep, t_done = sess.flush(t_put)
        assert np.array_equal(fab.fs.files[path],
                              out.view(np.uint8).ravel())
    assert client.service.catalog["scans/*.bin"].lease_count == 0


def test_session_required_for_sessionless_client():
    fab, _ = make_fabric()
    with pytest.raises(ValueError, match="no staging service"):
        StagingClient(fab).session("alice")


# ---------------------------------------------------------------------------
# Dataflow stage= hook
# ---------------------------------------------------------------------------

def test_dataflow_stages_declared_inputs_before_execution():
    from repro.core.dataflow import Dataflow

    fab, paths = make_fabric(n_hosts=2, n_files=3)
    flow = Dataflow(fab, stage="d/*.bin",
                    stage_config=PipelinedConfig(chunk_bytes=1 << 12))
    futs = flow.foreach(lambda p: p, paths, durations=[0.5] * len(paths),
                        inputs_of=lambda p: [p])
    stats = flow.run(n_workers=2)
    assert flow.stage_report is not None
    assert flow.stage_report.engine == "pipelined"
    assert_replicas_exact(fab, paths)
    # staged inputs gate execution: nothing starts before replicas land
    t_staged = flow.stage_report.total_time
    assert all(e.start >= t_staged for e in stats.events)
    # and the staged replicas serve the inputs (no shared-FS fallback)
    assert stats.cache_hits == len(paths)
    assert stats.cache_misses == 0
    assert [f.result() for f in futs] == paths


def test_dataflow_without_stage_hook_unchanged():
    from repro.core.dataflow import Dataflow
    fab, _ = make_fabric(n_hosts=2)
    flow = Dataflow(fab)
    fut = flow.task(lambda: 41, duration=1.0)
    flow.run(n_workers=1)
    assert flow.stage_report is None
    assert fut.result() == 41

"""Per-arch smoke tests: reduced configs, one forward + one train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, padded_vocab
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config, supported_shapes
from repro.models import model as M
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

# every test here pays a fresh XLA compile per arch (tens of seconds
# each) — slow lane; see pytest.ini
pytestmark = pytest.mark.slow

key = jax.random.PRNGKey(0)


def smoke_inputs(cfg, B=2, S=32):
    if cfg.frontend.kind == "vision_patches":
        P = cfg.frontend.num_prefix_tokens
        return {"tokens": jnp.ones((B, S - P), jnp.int32),
                "image_embeds": jnp.ones((B, P, cfg.frontend.feature_dim),
                                         jnp.float32),
                "labels": jnp.ones((B, S - P), jnp.int32)}
    if cfg.frontend.kind == "audio_frames":
        return {"features": jnp.ones((B, S, cfg.frontend.feature_dim),
                                     jnp.float32),
                "labels": jnp.ones((B, S), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(key, cfg)
    inputs = smoke_inputs(cfg)
    x, aux = M.forward(params, cfg, inputs, remat=False)
    B = 2
    assert x.shape[0] == B and x.shape[-1] == cfg.d_model
    assert not bool(jnp.any(jnp.isnan(x)))
    loss, metrics = M.loss_fn(params, cfg, inputs, remat=False)
    assert np.isfinite(float(loss))
    # untrained CE should be near ln(vocab)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab)) < 2.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = OptConfig(total_steps=10, warmup_steps=2, peak_lr=1e-3)
    params, opt_state = init_train_state(key, cfg, opt)
    shape = ShapeConfig("smoke", "train", 32, 2, num_microbatches=1,
                        remat=True)
    step = jax.jit(make_train_step(cfg, shape, opt))
    inputs = smoke_inputs(cfg)
    params, opt_state, m = step(params, opt_state, inputs)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    leaves = jax.tree.leaves(params)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in leaves)


def test_vocab_padding_is_masked():
    from repro.configs.base import with_overrides
    cfg = with_overrides(get_smoke_config("qwen2_72b"), vocab=500)
    params = M.init_model(key, cfg)
    caches = M.init_decode_state(cfg, 2, 8)
    logits, _ = M.decode_step(params, cfg, jnp.ones((2, 1), jnp.int32), caches)
    v_pad = padded_vocab(cfg.vocab)
    assert logits.shape[-1] == v_pad
    assert float(jnp.max(logits[:, cfg.vocab:])) < -1e29


def test_cell_accounting_covers_40():
    runnable = sum(len(supported_shapes(get_config(a))) for a in ARCH_IDS)
    assert runnable == 32            # + 8 documented skips = 40 assigned

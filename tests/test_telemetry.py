"""Timeline-resolved telemetry: tracer, metrics, exporters, neutrality.

The load-bearing claims pinned down here:

  * telemetry OFF is the exact pre-telemetry code path — a traced and an
    untraced twin of the same workload produce bit-identical simulated
    accounting (StagingReport fields, ServiceStats, tier_bytes, FS
    busy/wait), and a fresh ``Fabric`` carries the shared
    :data:`~repro.core.telemetry.NULL_TRACER`;
  * the Chrome trace-event export is structurally valid JSON (checked
    through a full ``json`` round-trip at P=1024) with children
    contained inside their parents' intervals;
  * histogram percentiles follow the closed-form Prometheus
    ``histogram_quantile`` interpolation, and
    :func:`~repro.core.telemetry.exact_percentile` is bit-exact with
    ``np.percentile``;
  * the flight recorder's phase breakdown partitions each stage's
    ``total_time`` exactly, and per-tier attribution partitions each
    collective's duration;
  * the span taxonomy lands where documented: engine regions with phase
    children, ``fs.*``/``fs.wait`` on the fs track, ``collective.*``
    with per-tier children, ``svc.acquire`` with outcome attribution,
    ``qos.request`` lifecycles with park reasons, ``stream.frame``
    deliveries with stall spans;
  * the EventLoop's fired-history ring buffer stays bounded (globally
    and per key) and counts what it drops.
"""
import json
import math

import numpy as np
import pytest

from conftest import make_fabric, make_service

from repro.core.telemetry import (DEFAULT_SECONDS_BUCKETS, Histogram,
                                  MetricsRegistry, NULL_TRACER, Tracer,
                                  exact_percentile, flight_recorder,
                                  to_chrome_trace, validate_chrome_trace)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_exact_percentile_matches_numpy():
    vals = [0.5, 1.25, 7.0, 2.0, 0.125]
    for p in (0, 25, 50, 90, 99, 100):
        assert exact_percentile(vals, p) == float(np.percentile(vals, p))


def test_histogram_percentile_closed_form():
    # one bucket (le 10) holding everything: the uniform-in-bucket
    # interpolation has an exact closed form lo + (p/100)*(hi-lo) with
    # lo=0, hi=10, clamped to [vmin, vmax]
    h = Histogram("t", buckets=(10.0,))
    for v in (2.0, 4.0, 6.0, 8.0):
        h.observe(v)
    assert h.percentile(50) == pytest.approx(5.0)      # 0 + 0.5 * 10
    assert h.percentile(99) == pytest.approx(8.0)      # 9.9 clamped to vmax
    assert h.percentile(0) == pytest.approx(2.0)       # clamped to vmin
    assert math.isnan(Histogram("e", buckets=(1.0,)).percentile(50))


def test_histogram_buckets_and_overflow():
    h = Histogram("t", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 3 and snap["sum"] == 55.5
    assert snap["buckets"] == {"le_1": 1, "le_10": 1}
    assert snap["overflow"] == 1
    assert snap["min"] == 0.5 and snap["max"] == 50.0
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))


def test_registry_get_or_create_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(2.0)
    reg.gauge("g").record(0.0, 1.0)
    reg.gauge("g").record(1.0, 3.0)
    reg.histogram("h").observe(0.02)
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 3.0}
    assert snap["gauges"]["g"] == {"n": 2, "last": 3.0, "min": 1.0,
                                   "max": 3.0}
    assert snap["histograms"]["h"]["count"] == 1
    # same instance on re-lookup
    assert reg.histogram("h") is reg.histogram("h")


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_region_auto_parenting_and_track_inheritance():
    tr = Tracer()
    with tr.region("outer", 0.0, track="engine") as outer:
        inner = tr.span("inner", 0.5, 1.0)        # inherits parent + track
        explicit = tr.span("other", 0.2, 0.3, track="fs")
        outer.t_end = 2.0
    after = tr.span("after", 3.0, 4.0)
    assert inner.parent == outer.span_id and inner.track == "engine"
    assert explicit.parent == outer.span_id and explicit.track == "fs"
    assert outer.t_end == 2.0 and outer.duration == 2.0
    assert after.parent is None
    assert tr.roots() == [outer, after]
    assert tr.children(outer) == [inner, explicit]
    # a region left without an explicit end collapses to an instant —
    # telemetry never invents durations
    with tr.region("unclosed", 5.0):
        pass
    assert tr.spans[-1].t_end == 5.0


def test_null_tracer_is_inert_default():
    from repro.core.fabric import Fabric
    fab = Fabric(n_hosts=4)
    assert fab.tracer is NULL_TRACER
    assert fab.fs.tracer is NULL_TRACER and fab.net.tracer is NULL_TRACER
    assert not NULL_TRACER.enabled
    with NULL_TRACER.region("x", 0.0) as sp:
        NULL_TRACER.span("y", 0.0, 1.0)
        NULL_TRACER.instant("z", 0.0)
        NULL_TRACER.metrics.counter("c").inc()
        NULL_TRACER.metrics.histogram("h").observe(1.0)
    assert sp.name == "null" and NULL_TRACER.roots() == []
    assert NULL_TRACER.metrics.snapshot() == {
        "counters": {}, "gauges": {}, "histograms": {}}


# ---------------------------------------------------------------------------
# bit-exactness: tracer on == tracer off
# ---------------------------------------------------------------------------

def _report_tuple(rep):
    r = rep.reports[0]
    return (rep.total_time, rep.metadata_time, r.stage_time, r.comm_time,
            r.write_time, r.broadcast_time, r.fs_bytes, r.net_bytes,
            dict(r.tier_bytes))


def test_stage_parity_traced_vs_untraced():
    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                PipelinedConfig, ReplicatedConfig,
                                StagingClient, StagingSpec)
    for cfg in (CollectiveConfig(), PipelinedConfig(chunk_bytes=1 << 14),
                ReplicatedConfig(replication=2)):
        fab_a, paths = make_fabric(n_hosts=8)
        fab_b, _ = make_fabric(n_hosts=8)
        spec = StagingSpec([BroadcastEntry(tuple(paths), pin=False)])
        off = StagingClient(fab_a).stage(spec, cfg, resolve=False)
        on = StagingClient(fab_b, trace=True).stage(spec, cfg,
                                                    resolve=False)
        assert _report_tuple(off) == _report_tuple(on), type(cfg).__name__
        assert fab_a.fs.wait_time == fab_b.fs.wait_time
        assert fab_a.fs.busy_time == fab_b.fs.busy_time
        assert fab_a.net.bytes_moved == fab_b.net.bytes_moved


def test_service_parity_traced_vs_untraced():
    fab_a, svc_a = make_service(budget_files=8)
    fab_b, svc_b = make_service(budget_files=8)
    fab_b.attach_tracer(Tracer())
    for svc in (svc_a, svc_b):
        svc.acquire("alice", "d0", 0.0)
        svc.acquire("bob", "d0", 0.0)            # coalesced
        l = svc.acquire("alice", "d1", 5.0)
        svc.release("alice", "d1", l.t_ready + 1.0)
        svc.acquire("carol", "d2", l.t_ready + 2.0)   # forces eviction
    sa, sb = svc_a.stats, svc_b.stats
    assert (sa.stages, sa.hits, sa.coalesced, sa.evictions,
            sa.stage_time, sa.queue_wait_time) == \
           (sb.stages, sb.hits, sb.coalesced, sb.evictions,
            sb.stage_time, sb.queue_wait_time)
    # and the traced twin actually recorded the service lifecycle
    names = {s.name for s in fab_b.tracer.spans}
    assert "svc.acquire" in names and "dataset.resident" in names


def test_qos_parity_and_request_spans():
    from repro.core.qos import FIFO, QoSScheduler

    def run(traced):
        fab, svc = make_service(budget_files=4)
        tracer = fab.attach_tracer(Tracer()) if traced else None
        sched = QoSScheduler(svc, policy=FIFO)
        for i, (ds, t) in enumerate((("d0", 0.0), ("d1", 0.01),
                                     ("d2", 0.02), ("d0", 0.03))):
            sched.submit(f"s{i}", ds, t, priority=i % 2, hold=0.5)
        sched.run()
        return sched, tracer

    off, _ = run(False)
    on, tracer = run(True)
    assert off.summary() == on.summary()
    assert [r.latency for r in off.completed] == \
           [r.latency for r in on.completed]
    reqs = [s for s in tracer.spans if s.name == "qos.request"]
    assert len(reqs) == len(on.completed)
    parked = [r for r in on.completed if r.park_reason is not None]
    for req in parked:       # under fifo a full budget parks with reasons
        assert req.park_reason in ("budget", "fifo_head_of_line")
    by_session = {s.attrs["session"]: s for s in reqs}
    for req in on.completed:
        sp = by_session[req.session_id]
        assert sp.t_start == req.t_submit and sp.t_end == req.t_release
        kid_names = {c.name for c in tracer.children(sp)}
        if req.t_admit > req.t_submit:
            assert "qos.parked" in kid_names
    hist = tracer.metrics.histograms["qos.latency_s"]
    assert hist.count == len(on.completed)


def test_stream_parity_and_frame_spans():
    from repro.core.streaming import StreamStager
    rng = np.random.default_rng(3)
    frames = [rng.integers(0, 255, 1 << 12, dtype=np.uint8)
              for _ in range(6)]

    def run(traced):
        fab, _ = make_fabric(n_hosts=4, n_files=0)
        tracer = fab.attach_tracer(Tracer()) if traced else None
        stager = StreamStager(fab, window_bytes=6 << 12)
        for i, f in enumerate(frames):
            stager.ingest(f"scan/{i:04d}.bin", f, t_emit=i * 1e-4)
        return stager.finish(), tracer

    off, _ = run(False)
    on, tracer = run(True)
    assert (off.ingest_makespan, off.mean_latency, off.stall_time,
            off.evictions) == (on.ingest_makespan, on.mean_latency,
                               on.stall_time, on.evictions)
    fr = [s for s in tracer.spans if s.name == "stream.frame"]
    assert len(fr) == len(frames)
    assert tracer.metrics.histograms["stream.frame_latency_s"].count == \
        len(frames)
    # each frame span decomposes into scatter / broadcast / local write
    for sp in fr:
        kid_names = [c.name for c in tracer.children(sp)]
        assert "stream.scatter" in kid_names
        assert "stream.broadcast" in kid_names
        assert "stream.local_write" in kid_names


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _traced_stage(n_hosts, n_files=4, size=1 << 16):
    from repro.core.api import (BroadcastEntry, CollectiveConfig,
                                StagingClient, StagingSpec)
    fab, paths = make_fabric(n_hosts=n_hosts, n_files=n_files, size=size)
    client = StagingClient(fab, trace=True)
    rep = client.stage(StagingSpec([BroadcastEntry(tuple(paths),
                                                   pin=False)]),
                       CollectiveConfig(), resolve=False)
    return client, rep


def test_chrome_trace_schema_roundtrip_p1024():
    client, _ = _traced_stage(1024)
    trace = json.loads(json.dumps(to_chrome_trace(client.tracer)))
    n = validate_chrome_trace(trace)
    assert n == len(trace["traceEvents"]) and n > 0

    events = trace["traceEvents"]
    # ph:X complete events, ts/dur in microseconds, per-track pids
    xs = {e["args"]["span_id"]: e for e in events if e["ph"] == "X"}
    tracks = {e["args"]["name"]: e["pid"] for e in events
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"engine", "fs", "net"} <= set(tracks)
    spans = {s.span_id: s for s in client.tracer.spans}
    for sid, ev in xs.items():
        sp = spans[sid]
        assert ev["ts"] == pytest.approx(sp.t_start * 1e6)
        assert ev["dur"] == pytest.approx(sp.duration * 1e6)
        assert ev["pid"] == tracks[sp.track]
        # children are monotone within their parent's interval
        parent = ev["args"].get("parent")
        if parent is not None and parent in xs:
            pev = xs[parent]
            assert ev["ts"] >= pev["ts"] - 1e-6
            assert ev["ts"] + ev["dur"] <= pev["ts"] + pev["dur"] + 1e-6


def test_chrome_trace_lanes_separate_overlapping_roots():
    tr = Tracer()
    tr.span("a", 0.0, 2.0, track="qos")
    tr.span("b", 1.0, 3.0, track="qos")        # overlaps a -> new lane
    tr.span("c", 2.5, 4.0, track="qos")        # fits lane 1 again
    trace = to_chrome_trace(tr)
    tids = [e["tid"] for e in trace["traceEvents"] if e["ph"] == "X"]
    assert tids[0] != tids[1] and tids[2] == tids[0]


def test_client_write_trace_and_flight_report(tmp_path):
    client, rep = _traced_stage(8)
    out = tmp_path / "trace.json"
    client.write_trace(str(out))
    with open(out) as f:
        validate_chrome_trace(json.load(f))
    text = client.flight_report()
    assert "flight recorder" in text and "stage.collective" in text
    assert "critical path" in text

    from repro.core.api import StagingClient
    from repro.core.fabric import Fabric
    untraced = StagingClient(Fabric(n_hosts=2))
    with pytest.raises(ValueError):
        untraced.write_trace(str(out))
    with pytest.raises(ValueError):
        untraced.flight_report()


def test_flight_recorder_phase_partition_is_exact():
    client, rep = _traced_stage(8)
    tr = client.tracer
    r = rep.reports[0]
    (stage_root,) = [s for s in tr.spans if s.name == "stage.collective"]
    phases = [c for c in tr.children(stage_root)
              if c.name.startswith("phase.")]
    # the phase children PARTITION [t0, t0 + total_time): exact by
    # construction, so the flight recorder's breakdown sums to the total
    assert sum(c.duration for c in phases) == pytest.approx(
        r.total_time, abs=1e-9)
    assert stage_root.duration == pytest.approx(r.total_time, abs=1e-9)
    # per-tier attribution partitions each collective's duration
    colls = [s for s in tr.spans if s.name.startswith("collective.")]
    assert colls
    for c in colls:
        tiers = [k for k in tr.children(c) if k.name.startswith("tier.")]
        if c.duration > 0:
            assert sum(k.duration for k in tiers) == pytest.approx(
                c.duration, abs=1e-9)
            assert sum(k.attrs["nbytes"] for k in tiers) == \
                c.attrs["wire_bytes"]


def test_fs_contention_wait_spans():
    fab, paths = make_fabric(n_hosts=4, n_files=2)
    tracer = fab.attach_tracer(Tracer())
    # two overlapping reads at the same t: the second queues behind the
    # first on the shared-FS bandwidth stream
    fab.fs.read(paths[0], 0, 1 << 16, 0.0, coordinated=False)
    fab.fs.read(paths[1], 0, 1 << 16, 0.0, coordinated=False)
    waits = [s for s in tracer.spans if s.name == "fs.wait"]
    assert len(waits) == 1
    assert tracer.metrics.counters["fs.contention_waits"].value == 1
    assert waits[0].duration == pytest.approx(fab.fs.wait_time)
    reads = [s for s in tracer.spans if s.name == "fs.read"]
    assert len(reads) == 2 and all(s.track == "fs" for s in reads)


# ---------------------------------------------------------------------------
# event-loop history ring buffer
# ---------------------------------------------------------------------------

def test_eventloop_history_global_cap():
    from repro.core.events import EventLoop
    loop = EventLoop(history_limit=10)
    for i in range(25):
        loop.schedule(float(i), lambda: None, key=f"k{i % 3}")
    while loop.step():
        pass
    assert loop.fired == 25                  # counting is never capped
    assert len(loop.history) == 10
    assert loop.history_dropped == 15
    # the ring keeps the NEWEST events, still in firing order
    assert [ev.t for ev in loop.history] == [float(i) for i in range(15, 25)]


def test_eventloop_history_per_key_cap():
    from repro.core.events import EventLoop
    loop = EventLoop(history_key_limit=2)
    for i in range(6):
        loop.schedule(float(i), lambda: None, key="chatty")
    loop.schedule(6.0, lambda: None, key="quiet")
    while loop.step():
        pass
    assert loop.history_dropped == 4
    chatty = [ev.t for ev in loop.history if ev.key == "chatty"]
    assert chatty == [4.0, 5.0]              # oldest chatty evicted first
    assert [ev.t for ev in loop.history if ev.key == "quiet"] == [6.0]


def test_eventloop_default_history_unbounded_in_practice():
    from repro.core.events import EventLoop
    loop = EventLoop()
    assert loop.history_limit == 100_000
    for i in range(50):
        loop.schedule(float(i), lambda: None)
    while loop.step():
        pass
    assert len(loop.history) == 50 and loop.history_dropped == 0

"""The paper's end-to-end interactive HEDM workflow (Fig. 7), simulated:

  detector -> shared FS -> [Swift I/O hook: collective staging] ->
  stage-1 reduction (Pallas kernel) -> stage-2 FitOrientation (many-task)

Reports the makespan against the paper's 5-minute interactive budget, and
the staged-vs-naive input comparison.

    PYTHONPATH=src python examples/hedm_interactive.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.api import (BroadcastEntry, CollectiveConfig, NaiveConfig,
                            StagingClient, StagingSpec)
from repro.core.fabric import BGQ, Fabric
from repro.core.manytask import ManyTaskEngine, Task
from repro.hedm.pipeline import (fit_grid, make_gvectors, reduce_frames,
                                 simulate_detector_frames, stream_to_fs,
                                 synth_grid_observations)


def main():
    n_frames, grid_points = 24, 256
    print("=== NF-HEDM interactive pipeline (paper Fig. 7) ===")

    # (1) detector writes frames to the shared FS
    fabric = Fabric(n_hosts=128, ranks_per_host=16, constants=BGQ)
    frames, dark = simulate_detector_frames(n_frames, size=128, n_spots=6)
    paths = stream_to_fs(fabric, frames)
    print(f"(1) detector: {n_frames} frames -> shared FS "
          f"({fabric.fs.size(paths[0]) >> 10} KB each)")

    # (2) Swift I/O hook via the unified client: typed config picks the
    # engine (the legacy run_io_hook(collective=...) shim still works)
    spec = StagingSpec([BroadcastEntry(files=("scan/*.bin",))])
    res = StagingClient(fabric).stage(spec, CollectiveConfig())
    print(f"(2) I/O hook: staged {len(res.resolved_files)} files to "
          f"{fabric.n_hosts} nodes in {res.total_time:.3f}s (simulated)")
    fab2 = Fabric(n_hosts=128, ranks_per_host=16, constants=BGQ)
    stream_to_fs(fab2, frames)
    naive = StagingClient(fab2).stage(spec, NaiveConfig())
    print(f"    naive per-node input would take {naive.total_time:.3f}s "
          f"({naive.total_time / res.total_time:.1f}x)")

    # (3) stage 1: reduction (real kernel compute, measured)
    t0 = time.perf_counter()
    reduced = reduce_frames(frames, dark, threshold=200.0, use_kernel=True)
    t1 = time.perf_counter() - t0
    n_spots = sum(r.n_spots for r in reduced)
    print(f"(3) stage 1: {n_frames} frames reduced in {t1:.2f}s wall — "
          f"{n_spots} diffraction spots")

    # (4) stage 2: FitOrientation over the sample grid — many-task + JAX
    gvec = make_gvectors()
    truth, obs = synth_grid_observations(grid_points, gvec)
    t0 = time.perf_counter()
    fit = fit_grid(jnp.asarray(obs), jnp.asarray(gvec),
                   jnp.zeros((grid_points, 3)))
    fit.block_until_ready()
    t2 = time.perf_counter() - t0
    err = np.abs(np.asarray(fit) - truth).max(axis=1)
    print(f"(4) stage 2: {grid_points} grid points fit in {t2:.2f}s wall — "
          f"{(err < 0.05).mean() * 100:.0f}% recovered")

    # (5) makespan accounting in the simulated cluster (paper Fig. 8 scale)
    eng = ManyTaskEngine(fabric, n_workers=2048)
    per_point = 30.0                      # paper: ~30 s per grid point
    stats = eng.run([Task(task_id=i, duration=per_point,
                          inputs=(paths[i % n_frames],))
                     for i in range(100_000)])
    print(f"(5) at scale: 100k grid points x 30s on 2048 workers -> "
          f"makespan {stats.makespan / 60:.1f} min "
          f"(cache hits {stats.cache_hits})")
    budget = 5 * 60
    total = res.total_time + stats.makespan
    print(f"==> interactive budget: {total / 60:.1f} min vs 5 min target "
          f"({'MET with >=10k workers' if stats.makespan > budget else 'MET'})")


if __name__ == "__main__":
    main()

"""The paper's Fig. 4 MapReduce-in-Swift example on the dataflow engine —
including the no-barrier property (Fig. 5): merges start while maps run.

    PYTHONPATH=src python examples/mapreduce_dataflow.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import random

from repro.core.dataflow import Dataflow
from repro.core.fabric import Fabric


def main():
    fabric = Fabric(n_hosts=8, ranks_per_host=4)
    df = Dataflow(fabric)
    r = random.Random(0)

    N = 32
    # map phase: find_file(i) |> map_function  (paper lines 6-8)
    maps = df.foreach(lambda i: {"file": f"part{i}", "count": i * i},
                      list(range(N)),
                      durations=[r.uniform(0.5, 4.0) for _ in range(N)])

    # reduce phase: recursive pairwise merge (paper lines 13-23)
    def merge_pair(a, b):
        return {"file": "merged", "count": a["count"] + b["count"]}

    final = df.merge_pairwise(merge_pair, maps, duration=0.2)
    stats = df.run(n_workers=8)

    print(f"final.data -> count={final.result()['count']} "
          f"(expected {sum(i * i for i in range(N))})")
    print(f"makespan {stats.makespan:.2f}s on 8 workers "
          f"(sum of work {stats.cpu_seconds():.2f}s)")
    events = {e.task_id: e for e in stats.events}
    first_merge = min(e.start for tid, e in events.items() if tid >= N)
    last_map = max(e.end for tid, e in events.items() if tid < N)
    print(f"no barrier: first merge at t={first_merge:.2f}s, "
          f"last map finishes t={last_map:.2f}s")


if __name__ == "__main__":
    main()

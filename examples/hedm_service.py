"""Interactive multi-session HEDM over the dataset catalog + staging service.

The paper's interactivity claim is about data living in node memory for
EXTENDED periods while VARIOUS processing tasks access it. This demo runs
that regime end to end: four concurrent analysis sessions lease three
scans through the long-lived `repro.core.datasvc.StagingService` under a
node-memory budget that only fits two scans at once — so concurrent
requests coalesce into shared collective stages, unleased datasets evict
(cheapest-to-restage first) and transparently re-stage on the next miss,
admissions queue on lease releases, and every session's reduced results
are written back to the shared FS with the collective ``stage_out``
(disjoint 1/P stripe writes) rather than the naive every-host-writes path.

    PYTHONPATH=src python examples/hedm_service.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.api import StagingClient
from repro.core.fabric import BGQ, Fabric
from repro.hedm.pipeline import (SessionScript, pack_reduced, reduce_frames,
                                 run_interactive_hedm,
                                 simulate_detector_frames)

N_FRAMES, SIZE = 16, 128


def main():
    scans, dark = {}, None
    for i, name in enumerate(["scanA", "scanB", "scanC"]):
        frames, dark = simulate_detector_frames(N_FRAMES, size=SIZE,
                                                n_spots=6, seed=i)
        scans[name] = frames
    frame_bytes = SIZE * SIZE * 4
    budget = 2 * N_FRAMES * frame_bytes + 1024      # 2 of the 3 scans fit

    fab = Fabric(n_hosts=64, constants=BGQ)
    sessions = [
        SessionScript("ana", ["scanA", "scanB", "scanC"]),
        SessionScript("ben", ["scanA", "scanC", "scanB"]),
        SessionScript("cam", ["scanB", "scanA", "scanC"], t_start=0.5),
        SessionScript("dee", ["scanC", "scanB", "scanA"], t_start=1.0),
    ]
    print("=== Interactive HEDM: dataset catalog + staging service ===")
    print(f"{len(scans)} scans x {N_FRAMES} frames "
          f"({N_FRAMES * frame_bytes >> 20} MB each), budget "
          f"{budget >> 20} MB/node, {len(sessions)} sessions\n")

    res = run_interactive_hedm(fab, scans, dark, sessions, budget)
    svc, st = res.service, res.service.stats

    print("catalog lifecycle:")
    for entry in svc.catalog:
        trail = " -> ".join(f"{s.value}@{t:.2f}s" for t, s in entry.history)
        print(f"  {entry.name}: {trail}")
        print(f"    residencies={entry.stage_count} acquires={entry.acquires}"
              f" (coalesced={entry.coalesced}, hits={entry.hits})")

    print(f"\nservice: {st.stages} stages ({st.restages} transparent "
          f"re-stages), {st.coalesced} coalesced acquires, "
          f"{st.evictions} evictions, {st.queue_waits} queued admissions "
          f"({st.queue_wait_time:.2f}s waiting on leases)")

    print("\nwrite-back (collective stage_out):")
    for name, rep in sorted(res.writeback.items()):
        print(f"  {name}: {rep.fs_write_bytes >> 10} KB in "
              f"{rep.total_time * 1e3:.1f} ms "
              f"(done at {res.session_done[name]:.2f}s)")

    # late-arriving tenant through the unified client API: a session
    # SCOPE auto-releases its leases on exit — even under an exception —
    # so a forgotten release can no longer wedge later admissions
    client = StagingClient(fab, service=svc)
    t_late = res.turnaround + 1.0
    with client.session("emma") as emma:
        lease = emma.acquire("scanA", t_late)
        hit = "residency hit" if lease.t_ready == t_late else "re-stage"
        print(f"\nlate session 'emma': scanA leased at t={t_late:.2f}s "
              f"({hit}, ready {lease.t_ready:.2f}s) — no explicit release")
    print(f"  after scope exit: scanA lease count "
          f"{svc.catalog['scanA'].lease_count} (auto-released)")

    # every session's outputs are byte-exact vs direct reduction,
    # eviction/re-staging notwithstanding
    exact = True
    for name, frames in scans.items():
        ref = pack_reduced(reduce_frames(np.float32(frames), dark,
                                         use_kernel=False))
        for outs in res.outputs.values():
            exact &= np.array_equal(outs[name], ref)
    print(f"\n==> turnaround {res.turnaround:.2f}s; all "
          f"{sum(len(o) for o in res.outputs.values())} session outputs "
          f"byte-exact vs direct reduction: {exact}")
    assert exact


if __name__ == "__main__":
    main()

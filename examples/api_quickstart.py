"""Unified staging client API in five minutes.

One surface for every way data reaches compute-node memory:

  1. typed engine configs (validated — no stringly-typed stage_kw dicts),
  2. the pluggable engine registry (mode name -> config type -> engine),
  3. ``client.stage(spec_or_patterns, config)`` for any one-shot engine,
  4. a declarative spec that round-trips its engine config through JSON
     (the Fig. 6 env-var hook, now fully typed),
  5. catalog-backed acquisition with ``with client.session(...)`` scopes
     whose leases auto-release — even when the body raises.

    PYTHONPATH=src python examples/api_quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.api import (ENGINES, BroadcastEntry, CollectiveConfig,
                            PipelinedConfig, ServiceConfig, StagingClient,
                            StagingSpec, StreamConfig)
from repro.core.fabric import BGQ, Fabric


def make_fabric(n_hosts=32):
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    rng = np.random.default_rng(0)
    for i in range(6):
        fab.fs.put(f"scan/frame_{i:03d}.bin",
                   rng.integers(0, 255, 1 << 16, dtype=np.uint8))
    return fab


def main():
    print("=== Unified staging client API ===\n")

    # (1) the registry: every engine, its typed config, one table
    print("registered engines (config -> engine matrix):")
    for e in ENGINES.entries():
        kind = "one-shot batch" if e.batch else "streamed delivery"
        print(f"  {e.name:<11} {e.config_type.__name__:<17} "
              f"{e.stage_fn.__module__.split('.')[-1]}.{e.stage_fn.__name__}"
              f"  ({kind})")

    # (2) one-shot staging through the client, engine picked by config
    fab = make_fabric()
    client = StagingClient(fab)
    rep = client.stage("scan/*.bin", CollectiveConfig())
    print(f"\n(collective) staged {len(rep.resolved_files)} files "
          f"({rep.total_bytes >> 10} KB) to {rep.n_hosts} nodes in "
          f"{rep.total_time:.3f}s simulated — fs_bytes {rep.fs_bytes >> 10} "
          f"KB (1x), delivered {rep.delivered_bytes >> 20} MB")

    rep_p = StagingClient(make_fabric()).stage(
        "scan/*.bin", PipelinedConfig(chunk_bytes=1 << 14))
    print(f"(pipelined)  same dataset in {rep_p.total_time:.3f}s "
          f"({rep_p.reports[0].n_chunks} chunks, "
          f"{rep_p.reports[0].overlap_saved * 1e3:.2f} ms hidden)")

    rep_s = StagingClient(make_fabric()).stage(
        "scan/*.bin", StreamConfig(rate_hz=50.0))
    print(f"(stream)     detector-push in {rep_s.total_time:.3f}s — "
          f"fs_bytes {rep_s.fs_bytes} (never read back)")

    # typed configs fail loudly instead of silently ignoring a typo
    try:
        StreamConfig(rate_hz=-1.0)
    except ValueError as e:
        print(f"(validation) StreamConfig(rate_hz=-1.0) -> ValueError: {e}")

    # (3) the declarative spec carries its engine config through JSON
    spec = StagingSpec([BroadcastEntry(files=("scan/*.bin",))],
                       config=PipelinedConfig(chunk_bytes=1 << 14))
    wire = spec.to_json()
    spec2 = StagingSpec.from_json(wire)
    assert spec2 == spec
    print(f"\nspec JSON round-trip (engine included): {wire[:74]}...")

    # (4) catalog-backed acquisition with session scopes
    fab = make_fabric()
    client = StagingClient(fab, service=ServiceConfig(budget_bytes=1 << 22))
    with client.session("alice") as alice:
        arep = alice.stage("scan/*.bin")
        print(f"\n(service) alice leased "
              f"{arep.leases[0].dataset!r} (ready at "
              f"{arep.leases[0].t_ready:.3f}s); coalesces with concurrent "
              f"tenants, auto-releases on scope exit")
    name = arep.leases[0].dataset
    assert client.service.catalog[name].lease_count == 0
    print(f"          lease count after scope: "
          f"{client.service.catalog[name].lease_count} (no wedge footgun)")

    # even an exception cannot leak the lease
    try:
        with client.session("bob") as bob:
            bob.stage("scan/*.bin")
            raise RuntimeError("analysis crashed")
    except RuntimeError:
        pass
    assert client.service.catalog[name].lease_count == 0
    print("          crashed session released its leases too")

    # staged replicas are byte-exact on every node, whatever the path
    for host in fab.hosts:
        for i in range(6):
            p = f"scan/frame_{i:03d}.bin"
            assert np.array_equal(host.store.data[p], fab.fs.files[p])
    print("\n==> all replicas byte-exact on every node-local store")


if __name__ == "__main__":
    main()

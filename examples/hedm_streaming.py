"""Online HEDM over streamed detector ingestion, end to end.

The batch workflow (examples/hedm_interactive.py) waits for the full scan
to land on the shared FS, stages it collectively, then reduces. This demo
runs the streaming follow-on: frames are pushed straight into node-local
memory as the detector produces them (scatter to the owning leader + ring
broadcast, bounded sliding window with watermark eviction and
backpressure), and stage-1 reduction runs per window while acquisition is
still in flight — with bit-identical results to the batch path.

    PYTHONPATH=src python examples/hedm_streaming.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.fabric import BGQ, Fabric
from repro.core.streaming import StreamScenario
from repro.hedm.pipeline import run_batch_hedm, run_online_hedm

REDUCE_S_PER_FRAME = 0.15        # declared stage-1 cost (simulated s/frame)


def main():
    sc = StreamScenario(n_hosts=64, n_frames=32, frame_size=128, n_spots=8,
                        rate_hz=4.0, window_frames=8, cache_frames=16)
    frames, dark = sc.make_frames()
    print("=== Online HEDM: streaming detector ingestion ===")
    print(f"scan: {sc.n_frames} frames x {sc.frame_bytes >> 10} KB at "
          f"{sc.rate_hz:g} Hz -> acquisition spans "
          f"{sc.n_frames / sc.rate_hz:.1f}s (simulated)")

    # batch baseline: detector -> FS -> stage_collective -> one-shot reduce
    batch, t_batch, stage_rep = run_batch_hedm(
        sc.make_fabric(), frames, dark, rate_hz=sc.rate_hz,
        use_kernel=False, reduce_time_per_frame=REDUCE_S_PER_FRAME)
    print(f"\n(batch)  scan closes at {sc.n_frames / sc.rate_hz:.1f}s, "
          f"staging {stage_rep.total_time:.2f}s "
          f"({stage_rep.mode}), reduce "
          f"{sc.n_frames * REDUCE_S_PER_FRAME:.1f}s "
          f"-> turnaround {t_batch:.2f}s")

    # streaming: frames reduced per window while acquisition runs
    online = run_online_hedm(
        sc.make_fabric(), frames, dark, rate_hz=sc.rate_hz,
        window=sc.window_frames, use_kernel=False,
        cache_frames=sc.cache_frames,
        reduce_time_per_frame=REDUCE_S_PER_FRAME)
    srep = online.stream
    print(f"(stream) first results at {online.window_done[0]:.2f}s "
          f"(acquisition still running), turnaround "
          f"{online.turnaround:.2f}s -> {t_batch / online.turnaround:.2f}x")
    print(f"         window: peak {srep.peak_resident_bytes >> 10} KB "
          f"of {sc.window_bytes >> 10} KB budget, "
          f"{srep.evictions} evictions, "
          f"backpressure stall {srep.stall_time:.2f}s, "
          f"mean frame latency {srep.mean_latency * 1e3:.2f} ms")

    # the two paths are bit-identical
    exact = all(a.frame_id == b.frame_id and a.n_spots == b.n_spots
                and np.array_equal(a.peaks, b.peaks)
                for a, b in zip(online.reduced, batch))
    n_spots = sum(r.n_spots for r in online.reduced)
    print(f"\n==> {len(online.reduced)} frames reduced, {n_spots} spots; "
          f"streaming output bit-identical to batch: {exact}")
    assert exact


if __name__ == "__main__":
    main()

"""Quickstart: train a small LM a few steps, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.serve.engine import Request, ServeSession
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    cfg = get_smoke_config("qwen3_32b")
    print(f"arch: {cfg.name} (reduced) — {cfg.n_layers}L d={cfg.d_model}")

    opt = OptConfig(total_steps=40, warmup_steps=5, peak_lr=3e-3)
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    shape = ShapeConfig("demo", "train", 64, 8, num_microbatches=2, remat=True)
    step = jax.jit(make_train_step(cfg, shape, opt))

    rng = np.random.default_rng(0)
    print("training on synthetic tokens ...")
    for i in range(20):
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 64), dtype=np.int32))
        batch = {"tokens": toks, "labels": toks}
        params, opt_state, m = step(params, opt_state, batch)
        if i % 5 == 0:
            print(f"  step {i:3d}  loss={float(m['loss']):.4f}  "
                  f"lr={float(m['lr']):.2e}")

    print("serving with continuous batching ...")
    sess = ServeSession(params, cfg, batch_slots=2, capacity=128)
    for rid in range(4):
        sess.submit(Request(request_id=rid,
                            prompt=rng.integers(0, cfg.vocab, 12,
                                                dtype=np.int32),
                            max_new_tokens=8))
    for req in sess.run_to_completion():
        print(f"  request {req.request_id}: generated {req.generated}")


if __name__ == "__main__":
    main()

"""Quickstart: stage training data through the client API, train a small
LM a few steps, then serve it.

The data path uses the PR-4 unified staging client (typed engine config +
an explicit `repro.core.topology.TopologyConfig` — the deprecated
``run_io_hook`` spelling is gone): token shards land on the simulated
shared FS, are staged collectively to every node-local store under the
BGQ 5D-torus machine model, and training reads the staged replica.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.core.api import CollectiveConfig, StagingClient, TopologyConfig
from repro.core.fabric import BGQ, Fabric
from repro.serve.engine import Request, ServeSession
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


def stage_tokens(n_steps: int, batch: int, seq: int, vocab: int,
                 n_hosts: int = 16):
    """Produce token shards on the shared FS and stage them to node-local
    memory with the unified client API, topology selected explicitly."""
    rng = np.random.default_rng(0)
    fab = Fabric(n_hosts=n_hosts, constants=BGQ)
    toks = rng.integers(0, vocab, (n_steps, batch, seq), dtype=np.int32)
    fab.fs.put("tokens/train.bin", toks)

    client = StagingClient(fab)
    config = CollectiveConfig(topology=TopologyConfig("bgq_torus"))
    rep = client.stage("tokens/*.bin", config)
    r = rep.reports[0]
    tiers = ", ".join(f"{k}={v >> 10} KiB" for k, v in r.tier_bytes.items())
    print(f"staged {rep.total_bytes >> 10} KiB to {rep.n_hosts} hosts in "
          f"{rep.total_time * 1e3:.1f} simulated ms "
          f"(engine={rep.engine}, wire: {tiers or 'none'})")

    # train from the staged node-local replica (byte-exact with the FS)
    replica = fab.hosts[0].store.read("tokens/train.bin")
    return np.frombuffer(replica.tobytes(), dtype=np.int32).reshape(
        n_steps, batch, seq)


def main():
    cfg = get_smoke_config("qwen3_32b")
    print(f"arch: {cfg.name} (reduced) — {cfg.n_layers}L d={cfg.d_model}")

    opt = OptConfig(total_steps=40, warmup_steps=5, peak_lr=3e-3)
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    shape = ShapeConfig("demo", "train", 64, 8, num_microbatches=2, remat=True)
    step = jax.jit(make_train_step(cfg, shape, opt))

    print("staging synthetic tokens ...")
    tokens = stage_tokens(n_steps=20, batch=8, seq=64, vocab=cfg.vocab)

    print("training on staged tokens ...")
    for i in range(len(tokens)):
        toks = jnp.asarray(tokens[i])
        batch = {"tokens": toks, "labels": toks}
        params, opt_state, m = step(params, opt_state, batch)
        if i % 5 == 0:
            print(f"  step {i:3d}  loss={float(m['loss']):.4f}  "
                  f"lr={float(m['lr']):.2e}")

    print("serving with continuous batching ...")
    rng = np.random.default_rng(0)
    sess = ServeSession(params, cfg, batch_slots=2, capacity=128)
    for rid in range(4):
        sess.submit(Request(request_id=rid,
                            prompt=rng.integers(0, cfg.vocab, 12,
                                                dtype=np.int32),
                            max_new_tokens=8))
    for req in sess.run_to_completion():
        print(f"  request {req.request_id}: generated {req.generated}")


if __name__ == "__main__":
    main()

"""End-to-end training driver example: staged data pipeline + checkpointed,
fault-tolerant training of a ~100M-param LM.

    PYTHONPATH=src python examples/train_lm.py --preset demo --steps 30
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the deliverable configuration (a few hundred steps on
real hardware); `demo` shrinks it for the CPU container.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import ModelConfig, ShapeConfig
from repro.runtime.driver import TrainDriver
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

PRESETS = {
    # ~100M params: 12L d=768 12H (GPT-2-small-like, llama-style blocks)
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                        vocab=32000, head_dim=64,
                        param_dtype="float32", compute_dtype="float32"),
    "demo": ModelConfig(name="lm-demo", family="dense", n_layers=4,
                        d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                        vocab=2048, head_dim=32,
                        param_dtype="float32", compute_dtype="float32"),
}


def synthetic_batches(cfg, batch, seq, seed=0):
    """Staged input pipeline stand-in: a Zipf-ish synthetic token stream."""
    rng = np.random.default_rng(seed)
    while True:
        z = rng.zipf(1.5, size=(batch, seq)).astype(np.int64)
        toks = jnp.asarray(np.minimum(z, cfg.vocab - 1), dtype=jnp.int32)
        yield {"tokens": toks, "labels": toks}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step (restart demo)")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n_params = cfg.param_count()
    print(f"model {cfg.name}: ~{n_params/1e6:.0f}M params")
    opt = OptConfig(total_steps=max(args.steps, 10),
                    warmup_steps=max(2, args.steps // 10), peak_lr=1e-3)
    shape = ShapeConfig("train", "train", args.seq, args.batch,
                        num_microbatches=1, remat=True)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    store = CheckpointStore(ckpt_dir)
    batches = synthetic_batches(cfg, args.batch, args.seq)

    def build_step(mesh_spec):
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        raw_step = jax.jit(make_train_step(cfg, shape, opt))

        def step_fn(state):
            params, opt_state = state
            params, opt_state, m = raw_step(params, opt_state, next(batches))
            return (params, opt_state), m
        return step_fn, (params, opt_state)

    schedule = {args.fail_at: "fail"} if args.fail_at else {}
    driver = TrainDriver(store, build_step, checkpoint_every=10,
                         failure_schedule=schedule)
    report = driver.run(args.steps, mesh_spec={})
    print(f"steps={report.steps_completed} restarts={report.restarts} "
          f"checkpoints={report.checkpoints}")
    print(f"loss: {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()

"""Serving example: a request stream dispatched through the many-task engine
into the continuous-batching session — serving as "many-task over staged
node-local data" (weights + caches are the staged data; requests are tasks).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.fabric import Fabric, TPU_POD
from repro.core.manytask import ManyTaskEngine, Task
from repro.models import model as M
from repro.serve.engine import Request, ServeSession


def main():
    cfg = get_smoke_config("rwkv6_3b")     # O(1)-state decode arch
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    sess = ServeSession(params, cfg, batch_slots=4, capacity=64)
    rng = np.random.default_rng(0)

    # requests arrive as many-task work items; the engine accounts queueing/
    # locality while the session does the real decode compute
    fabric = Fabric(n_hosts=1, ranks_per_host=4, constants=TPU_POD)
    n_requests = 10
    t0 = time.perf_counter()
    for rid in range(n_requests):
        sess.submit(Request(request_id=rid,
                            prompt=rng.integers(0, cfg.vocab, 12,
                                                dtype=np.int32),
                            max_new_tokens=6))
    finished = sess.run_to_completion()
    wall = time.perf_counter() - t0

    eng = ManyTaskEngine(fabric, n_workers=4)
    stats = eng.run([Task(task_id=r.request_id,
                          duration=len(r.generated) * 0.02)
                     for r in finished])
    tokens = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)} requests / {tokens} tokens "
          f"in {wall:.2f}s wall ({tokens / wall:.1f} tok/s)")
    print(f"many-task makespan model: {stats.makespan:.2f}s on 4 workers")
    for r in finished[:3]:
        print(f"  req {r.request_id}: {r.generated}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single-pod: (16,16) = 256 v5e chips, axes
("data","model"). Multi-pod: (2,16,16) = 512 chips, axes ("pod","data",
"model") — the pod axis carries only gradient reduction (DCN).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this)")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh over the first prod(shape) devices (tests, elastic)."""
    import numpy as np
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(tuple(shape))
    return jax.sharding.Mesh(dev_array, tuple(axes))


# v5e hardware constants (per chip) — used by the roofline
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s/link (~3 usable links per v5e chip)
ICI_LINKS = 3
DCN_BW_PER_HOST = 25e9          # bytes/s across pods (per host of 4 chips)
HBM_PER_CHIP = 16 << 30         # bytes

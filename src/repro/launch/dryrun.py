import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh ((16,16) and/or (2,16,16)),
  2. eval_shape's params / optimizer / caches (ShapeDtypeStruct — nothing is
     allocated),
  3. jits the real step function (train_step / prefill_step / decode_step)
     with the FSDPxTPxEP shardings from repro.distributed.sharding,
  4. .lower().compile() — any sharding mismatch, OOM-at-compile, or
     unsupported collective fails here,
  5. records memory_analysis() + HLO-derived cost terms (FLOPs, HBM bytes,
     ICI/DCN collective bytes — scan bodies scaled by trip count) into
     results/dryrun/<cell>.json for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.configs.registry import (ARCH_IDS, all_cells, canonical,
                                    get_config, supported_shapes)
from repro.distributed import hlo_cost
from repro.distributed.sharding import (ShardCtx, cache_pspecs, input_pspecs,
                                        make_ctx, param_pspecs)
from repro.launch import mesh as mesh_mod
from repro.models import model as M
from repro.serve import engine as serve_engine
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# grad-accumulation microbatches per arch for train_4k (fits 16 GB HBM)
ARCH_MICROBATCH = {
    "qwen2_72b": 16,
    "qwen3_32b": 8,
    "internlm2_20b": 4,
    "zamba2_7b": 4,
    "qwen3_moe_30b_a3b": 4,
    "deepseek_v2_lite_16b": 4,
    "h2o_danube3_4b": 2,
    "internvl2_2b": 2,
    "hubert_xlarge": 2,
    "rwkv6_3b": 4,
}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    specs: Dict[str, Any] = {}
    fe = cfg.frontend
    if fe.kind == "audio_frames":
        specs["features"] = jax.ShapeDtypeStruct((B, S, fe.feature_dim),
                                                 jnp.bfloat16)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    if fe.kind == "vision_patches":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S - fe.num_prefix_tokens),
                                               i32)
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, fe.num_prefix_tokens, fe.feature_dim), jnp.bfloat16)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct(
                (B, S - fe.num_prefix_tokens), i32)
        return specs
    specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return specs


def _shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               sequence_parallel: bool = False,
               compress_dcn: bool = False):
    """Build and lower one cell. Returns (lowered, meta dict)."""
    arch = canonical(arch)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        shape = ShapeConfig(shape.name, shape.kind, shape.seq_len,
                            shape.global_batch,
                            num_microbatches=ARCH_MICROBATCH.get(arch, 1),
                            remat=True)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh, sequence_parallel=sequence_parallel)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    params_shape = jax.eval_shape(
        functools.partial(M.init_model, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg, params_shape, ctx)
    param_sh = _shardings(pspecs, mesh)
    ins = input_specs(cfg, shape)
    in_specs = input_pspecs(cfg, shape, ctx)

    if shape.kind == "train":
        opt = OptConfig()
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        opt_pspecs = {"step": P(), "master": pspecs, "m": pspecs, "v": pspecs}
        if compress_dcn:
            opt_shape["dcn_error"] = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                params_shape)
            opt_pspecs["dcn_error"] = pspecs
        opt_sh = _shardings(opt_pspecs, mesh)
        step = make_train_step(cfg, shape, opt, ctx=ctx,
                               compress_dcn=compress_dcn)
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, _shardings(in_specs, mesh)),
            donate_argnums=(0, 1))
        lowered = jitted.lower(params_shape, opt_shape, ins)
    elif shape.kind == "prefill":
        def pf(params, inputs):
            return serve_engine.prefill_step(params, cfg, inputs,
                                             capacity=shape.seq_len, ctx=ctx)
        jitted = jax.jit(pf, in_shardings=(param_sh,
                                           _shardings(in_specs, mesh)))
        lowered = jitted.lower(params_shape, ins)
    else:  # decode
        caches_shape = jax.eval_shape(
            functools.partial(M.init_decode_state, cfg,
                              shape.global_batch, shape.seq_len))
        c_pspecs = cache_pspecs(cfg, caches_shape, ctx)
        tok_spec = in_specs["tokens"]

        def dc(params, tokens, caches):
            return M.decode_step(params, cfg, tokens, caches)
        jitted = jax.jit(
            dc,
            in_shardings=(param_sh,
                          NamedSharding(mesh, tok_spec),
                          _shardings(c_pspecs, mesh)),
            donate_argnums=(2,))
        lowered = jitted.lower(params_shape, ins["tokens"], caches_shape)
    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "multi_pod": multi_pod, "n_devices": int(np.prod(list(
            mesh.shape.values()))),
        "num_microbatches": shape.num_microbatches,
        "sequence_parallel": sequence_parallel,
        "compress_dcn": compress_dcn,
    }
    return lowered, meta


def analyze(lowered, meta: Dict) -> Dict:
    """compile() + collect memory/cost/collective accounting."""
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    txt = compiled.as_text()
    cost = hlo_cost.analyze_hlo_text(
        txt, meta["n_devices"], n_pods=2 if meta["multi_pod"] else 1)
    out = dict(meta)
    out.update({
        "compile_seconds": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_live_bytes": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
        },
        "xla_cost": {"flops": ca.get("flops", 0.0),
                     "bytes": ca.get("bytes accessed", 0.0)},
        "hlo_cost": {
            "flops": cost.flops,
            "bytes": cost.bytes,
            "ici_collective_bytes": cost.ici_collective_bytes,
            "dcn_collective_bytes": cost.dcn_collective_bytes,
            "collectives": dict(cost.collective_breakdown),
        },
    })
    return out


def roofline_terms(result: Dict) -> Dict:
    """The three roofline terms (seconds) for one compiled cell."""
    hc = result["hlo_cost"]
    compute = hc["flops"] / mesh_mod.PEAK_FLOPS_BF16
    memory = hc["bytes"] / mesh_mod.HBM_BW
    ici = hc["ici_collective_bytes"] / (mesh_mod.ICI_BW_PER_LINK
                                        * mesh_mod.ICI_LINKS)
    dcn = hc["dcn_collective_bytes"] / (mesh_mod.DCN_BW_PER_HOST / 4)
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": ici + dcn, "ici_s": ici, "dcn_s": dcn,
            "bottleneck": max(
                [("compute", compute), ("memory", memory),
                 ("collective", ici + dcn)], key=lambda kv: kv[1])[0]}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, **kw) -> Dict:
    arch = canonical(arch)
    tag = f"{arch}.{shape_name}.{'multipod' if multi_pod else 'pod'}"
    for flag in ("sequence_parallel", "compress_dcn"):
        if kw.get(flag):
            tag += f".{flag}"
    print(f"=== {tag} ===", flush=True)
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod, **kw)
    print(f"  lowered in {time.time()-t0:.1f}s", flush=True)
    result = analyze(lowered, meta)
    result["roofline"] = roofline_terms(result)
    mem_gb = result["memory"]["peak_live_bytes"] / 2**30
    r = result["roofline"]
    print(f"  compiled in {result['compile_seconds']}s | "
          f"mem/device={mem_gb:.2f} GiB | "
          f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
          f"collective={r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}",
          flush=True)
    if mem_gb > mesh_mod.HBM_PER_CHIP / 2**30:
        print(f"  WARNING: exceeds {mesh_mod.HBM_PER_CHIP/2**30:.0f} GiB HBM",
              flush=True)
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--sequence-parallel", action="store_true")
    ap.add_argument("--compress-dcn", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod or not args.single_pod:
        meshes.append(True)

    if args.all:
        cells = all_cells()
    else:
        arch = args.arch or ARCH_IDS[0]
        shapes = [args.shape] if args.shape else supported_shapes(
            get_config(arch))
        cells = [(arch, s) for s in shapes]

    failures = []
    for arch, shape_name in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape_name, mp,
                         sequence_parallel=args.sequence_parallel,
                         compress_dcn=args.compress_dcn)
            except Exception as e:
                failures.append((arch, shape_name, mp, repr(e)))
                print(f"  FAILED: {e}", flush=True)
                traceback.print_exc()
    print(f"\n{len(cells)*len(meshes)-len(failures)} ok, "
          f"{len(failures)} failed")
    for f in failures:
        print("  FAIL:", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

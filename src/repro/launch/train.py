"""Production training launcher: mesh + sharded state + staged input
pipeline + checkpointed fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \
        --steps 20

Full-config runs lower the same code the dry-run validates; --smoke uses the
reduced config so the loop also runs on this CPU container.
"""
from __future__ import annotations

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import ShapeConfig
from repro.configs.registry import canonical, get_config, get_smoke_config
from repro.distributed.sharding import input_pspecs, make_ctx, param_pspecs
from repro.launch import mesh as mesh_mod
from repro.runtime.driver import TrainDriver
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 -> (data,model); default: single device")
    ap.add_argument("--compress-dcn", action="store_true")
    args = ap.parse_args()

    arch = canonical(args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    shape = ShapeConfig("train", "train", args.seq, args.batch,
                        num_microbatches=args.microbatches, remat=True)
    opt = OptConfig(total_steps=max(args.steps, 10),
                    warmup_steps=max(2, args.steps // 10), peak_lr=1e-3)

    ctx = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)] if len(dims) <= 2 else \
            ("pod", "data", "model")
        mesh = mesh_mod.make_mesh(dims, axes)
        ctx = make_ctx(mesh)

    store = CheckpointStore(args.ckpt_dir
                            or tempfile.mkdtemp(prefix="repro_train_"))
    rng = np.random.default_rng(0)

    def next_batch():
        toks = jnp.asarray(rng.integers(0, cfg.vocab,
                                        (args.batch, args.seq),
                                        dtype=np.int32))
        return {"tokens": toks, "labels": toks}

    def build_step(mesh_spec):
        params, opt_state = init_train_state(
            jax.random.PRNGKey(0), cfg, opt, compress_dcn=args.compress_dcn)
        if ctx is not None:
            pspecs = param_pspecs(cfg, params, ctx)
            sh = jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
            params = jax.tree.map(jax.device_put, params, sh)
        raw = jax.jit(make_train_step(cfg, shape, opt, ctx=ctx,
                                      compress_dcn=args.compress_dcn))

        def step_fn(state):
            p, o = state
            p, o, m = raw(p, o, next_batch())
            return (p, o), m
        return step_fn, (params, opt_state)

    driver = TrainDriver(store, build_step, checkpoint_every=10)
    report = driver.run(args.steps, mesh_spec={})
    print(f"completed {report.steps_completed} steps; "
          f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")


if __name__ == "__main__":
    main()

"""Serving launcher: continuous batching driven by a request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import canonical, get_config, get_smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServeSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=128)
    args = ap.parse_args()

    arch = canonical(args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    sess = ServeSession(params, cfg, batch_slots=args.slots,
                        capacity=args.capacity)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        sess.submit(Request(
            request_id=rid,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.max_new))
    finished = sess.run_to_completion()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)} requests / {tokens} tokens "
          f"in {dt:.2f}s ({tokens/dt:.1f} tok/s on this host)")
    for r in finished[:4]:
        print(f"  req {r.request_id}: {r.generated}")


if __name__ == "__main__":
    main()

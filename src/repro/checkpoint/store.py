"""Sharded checkpointing with collective-staged restore.

Save:    every param leaf is split into per-participant shards along its
         largest dim and written as independent objects (parallel writes,
         aggregate-storage bandwidth). An async mode snapshots off the
         critical path (double-buffer, thread).
Restore: the paper's staging pattern — each participant reads 1/P of the
         checkpoint (aggregate read = 1x checkpoint at coordinated rate),
         then replicas assemble via all-gather (ICI) instead of P full reads.
         `restore_resharded` restores onto a DIFFERENT mesh/participant count
         (elastic rescale after node failure).

The store is filesystem-backed (real bytes; np.save/np.load) plus an
optional simulated-fabric account of staging time for benchmarks.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k),
                                f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def _unflatten_like(template: Any, flat: Dict[str, Any], prefix: str = ""):
    if isinstance(template, dict):
        return {k: _unflatten_like(template[k], flat,
                                   f"{prefix}/{k}" if prefix else k)
                for k in template}
    if hasattr(template, "_fields"):
        return type(template)(*(
            _unflatten_like(getattr(template, k), flat,
                            f"{prefix}/{k}" if prefix else k)
            for k in template._fields))
    return flat[prefix]


@dataclass
class CheckpointMeta:
    step: int
    n_shards: int
    leaves: Dict[str, Dict]        # path -> {shape, dtype, shard_axis}


class CheckpointStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _leaf_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    @staticmethod
    def _shard_axis(shape: Tuple[int, ...]) -> int:
        if not shape:
            return -1
        return int(np.argmax(shape))

    def save(self, step: int, tree: Any, n_shards: int = 8) -> None:
        """Sharded synchronous save (each shard = independent object)."""
        flat = _flatten(tree)
        d = self._leaf_dir(step)
        os.makedirs(d, exist_ok=True)
        meta = {"step": step, "n_shards": n_shards, "leaves": {}}
        for path, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            # bf16 has no numpy dtype -> save as uint16 view w/ marker
            marker = ""
            if arr.dtype == jnp.bfloat16:
                arr = arr.view(np.uint16)
                marker = "bfloat16"
            ax = self._shard_axis(arr.shape)
            meta["leaves"][path] = {
                "shape": list(arr.shape),
                "dtype": marker or str(arr.dtype),
                "shard_axis": ax,
            }
            safe = path.replace("/", "__")
            if ax < 0 or arr.shape[ax] < n_shards:
                np.save(os.path.join(d, f"{safe}.full.npy"), arr)
            else:
                for i, piece in enumerate(np.array_split(arr, n_shards,
                                                         axis=ax)):
                    np.save(os.path.join(d, f"{safe}.shard{i}.npy"), piece)
        with open(os.path.join(d, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(self.root, "LATEST"), "w") as f:
            f.write(str(step))

    def save_async(self, step: int, tree: Any, n_shards: int = 8) -> None:
        """Snapshot to host (blocking only for device->host), write in a
        background thread (off the training critical path)."""
        snap = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        t = threading.Thread(target=self.save, args=(step, snap, n_shards))
        t.start()
        self._async_thread = t

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.root, "LATEST")
        if not os.path.exists(p):
            return None
        return int(open(p).read().strip())

    def restore(self, template: Any, step: Optional[int] = None,
                participant_shards: Optional[List[int]] = None) -> Any:
        """Restore a pytree. `participant_shards` simulates staged restore:
        only those shard indices are read "locally", the rest conceptually
        arrive via all-gather — with real files we read all, but staging
        accounting happens in benchmarks. Values are byte-exact."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no checkpoint")
        d = self._leaf_dir(step)
        meta = json.load(open(os.path.join(d, "meta.json")))
        flat = {}
        for path, info in meta["leaves"].items():
            safe = path.replace("/", "__")
            full = os.path.join(d, f"{safe}.full.npy")
            if os.path.exists(full):
                arr = np.load(full)
            else:
                pieces = [np.load(os.path.join(
                    d, f"{safe}.shard{i}.npy"))
                    for i in range(meta["n_shards"])]
                arr = np.concatenate(pieces, axis=info["shard_axis"])
            if info["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            flat[path] = arr
        return _unflatten_like(template, flat)

    def restore_resharded(self, template: Any, mesh, pspecs,
                          step: Optional[int] = None) -> Any:
        """Elastic restore: place restored leaves directly onto a (possibly
        different) mesh with the given PartitionSpecs."""
        from jax.sharding import NamedSharding
        host = self.restore(template, step)
        return jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            host, pspecs)

"""Sharded checkpointing with collective-staged restore.

Save:    every param leaf is split into per-participant shards along its
         largest dim and written as independent objects (parallel writes,
         aggregate-storage bandwidth). An async mode snapshots off the
         critical path (double-buffer, thread).
Restore: the paper's staging pattern — each participant reads 1/P of the
         checkpoint (aggregate read = 1x checkpoint at coordinated rate),
         then replicas assemble via all-gather (ICI) instead of P full reads.
         `restore_resharded` restores onto a DIFFERENT mesh/participant count
         (elastic rescale after node failure).

The store is filesystem-backed (real bytes; np.save/np.load) plus an
optional simulated-fabric account of staging time for benchmarks.

Beyond model state, the store also snapshots the DATASET CATALOG of a
`repro.core.datasvc.StagingService` (:meth:`CheckpointStore.save_catalog`
/ :meth:`CheckpointStore.restore_catalog`): a simulated service restart
rebuilds the service against the (surviving) fabric, re-verifies every
entry's replica coverage against what the node-local stores actually
hold, re-pins live leases, and marks entries whose replicas went missing
DEGRADED so the self-healing path (`StagingService.re_replicate`) brings
them back.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


class CheckpointError(RuntimeError):
    """A checkpoint object is missing or unreadable — the error names the
    offending shard/file so operators can see WHICH object to recover
    from replication instead of guessing from a bare traceback."""


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k),
                                f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def _unflatten_like(template: Any, flat: Dict[str, Any], prefix: str = ""):
    if isinstance(template, dict):
        return {k: _unflatten_like(template[k], flat,
                                   f"{prefix}/{k}" if prefix else k)
                for k in template}
    if hasattr(template, "_fields"):
        return type(template)(*(
            _unflatten_like(getattr(template, k), flat,
                            f"{prefix}/{k}" if prefix else k)
            for k in template._fields))
    return flat[prefix]


@dataclass
class CheckpointMeta:
    step: int
    n_shards: int
    leaves: Dict[str, Dict]        # path -> {shape, dtype, shard_axis}


class CheckpointStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _leaf_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    @staticmethod
    def _shard_axis(shape: Tuple[int, ...]) -> int:
        if not shape:
            return -1
        return int(np.argmax(shape))

    def save(self, step: int, tree: Any, n_shards: int = 8) -> None:
        """Sharded synchronous save (each shard = independent object)."""
        flat = _flatten(tree)
        d = self._leaf_dir(step)
        os.makedirs(d, exist_ok=True)
        meta = {"step": step, "n_shards": n_shards, "leaves": {}}
        for path, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            # bf16 has no numpy dtype -> save as uint16 view w/ marker
            marker = ""
            if arr.dtype == jnp.bfloat16:
                arr = arr.view(np.uint16)
                marker = "bfloat16"
            ax = self._shard_axis(arr.shape)
            meta["leaves"][path] = {
                "shape": list(arr.shape),
                "dtype": marker or str(arr.dtype),
                "shard_axis": ax,
            }
            safe = path.replace("/", "__")
            if ax < 0 or arr.shape[ax] < n_shards:
                np.save(os.path.join(d, f"{safe}.full.npy"), arr)
            else:
                for i, piece in enumerate(np.array_split(arr, n_shards,
                                                         axis=ax)):
                    np.save(os.path.join(d, f"{safe}.shard{i}.npy"), piece)
        with open(os.path.join(d, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(self.root, "LATEST"), "w") as f:
            f.write(str(step))

    def save_async(self, step: int, tree: Any, n_shards: int = 8) -> None:
        """Snapshot to host (blocking only for device->host), write in a
        background thread (off the training critical path)."""
        snap = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        t = threading.Thread(target=self.save, args=(step, snap, n_shards))
        t.start()
        self._async_thread = t

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.root, "LATEST")
        if not os.path.exists(p):
            return None
        return int(open(p).read().strip())

    @staticmethod
    def _load_object(fp: str, leaf: str, step: int) -> np.ndarray:
        """np.load with loud failure: a missing or truncated checkpoint
        object names ITSELF (shard path, leaf, step) so the operator knows
        exactly which object to re-fetch from replication."""
        if not os.path.exists(fp):
            raise CheckpointError(
                f"checkpoint step {step}: leaf {leaf!r} is missing object "
                f"{fp} — the shard was never written or was lost; restore "
                f"it from a replica or re-save the checkpoint")
        try:
            return np.load(fp)
        except Exception as exc:
            raise CheckpointError(
                f"checkpoint step {step}: leaf {leaf!r} object {fp} is "
                f"unreadable (truncated or corrupt: {exc}); restore it "
                f"from a replica or re-save the checkpoint") from exc

    def restore(self, template: Any, step: Optional[int] = None,
                participant_shards: Optional[List[int]] = None) -> Any:
        """Restore a pytree. `participant_shards` simulates staged restore:
        only those shard indices are read "locally", the rest conceptually
        arrive via all-gather — with real files we read all, but staging
        accounting happens in benchmarks. Values are byte-exact.

        A missing or truncated object (full leaf or any shard) raises
        :class:`CheckpointError` naming the bad file — never a bare
        ``FileNotFoundError``/pickle error deep inside numpy."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no checkpoint")
        d = self._leaf_dir(step)
        meta_path = os.path.join(d, "meta.json")
        if not os.path.exists(meta_path):
            raise CheckpointError(
                f"checkpoint step {step}: manifest {meta_path} is missing "
                f"— the checkpoint directory is incomplete")
        meta = json.load(open(meta_path))
        flat = {}
        for path, info in meta["leaves"].items():
            safe = path.replace("/", "__")
            # the MANIFEST decides the layout (mirrors the save-side
            # rule), so a missing shard is reported as that shard — not
            # misdiagnosed as a missing full object
            ax = info["shard_axis"]
            sharded = ax >= 0 and info["shape"][ax] >= meta["n_shards"]
            if not sharded:
                arr = self._load_object(
                    os.path.join(d, f"{safe}.full.npy"), path, step)
            else:
                pieces = [self._load_object(
                    os.path.join(d, f"{safe}.shard{i}.npy"), path, step)
                    for i in range(meta["n_shards"])]
                arr = np.concatenate(pieces, axis=ax)
            if info["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            flat[path] = arr
        return _unflatten_like(template, flat)

    def restore_resharded(self, template: Any, mesh, pspecs,
                          step: Optional[int] = None) -> Any:
        """Elastic restore: place restored leaves directly onto a (possibly
        different) mesh with the given PartitionSpecs."""
        from jax.sharding import NamedSharding
        host = self.restore(template, step)
        return jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            host, pspecs)

    # -- dataset-catalog snapshot (simulated service restart) ----------------
    def _catalog_path(self, tag: str) -> str:
        return os.path.join(self.root, f"catalog_{tag}.json")

    def save_catalog(self, service, t: float, tag: str = "catalog") -> str:
        """Snapshot a `repro.core.datasvc.StagingService` catalog to JSON.

        What survives a service restart: the engine selection, every
        dataset entry (paths, state, leases, holders, striped placement,
        per-entry counters, history) and the service-wide stats. What
        does NOT: un-flushed dirty result buffers (real arrays living in
        node memory — a restarted service re-learns them from sessions),
        and the node-local replicas themselves, which belong to the
        FABRIC and are re-verified at restore time. Returns the snapshot
        path."""
        from repro.core.api import ENGINES, TopologyConfig
        entry = next((e for e in ENGINES.entries()
                      if e.stage_fn is service._stage_fn), None)
        if entry is None:
            raise CheckpointError(
                "cannot snapshot a service whose staging engine is not in "
                "the process-wide ENGINES registry (register it first)")
        params = {k: (v.to_dict() if isinstance(v, TopologyConfig) else v)
                  for k, v in service._stage_kw.items()}
        snap: Dict[str, Any] = {
            "t": t,
            "budget_bytes": service.budget_bytes,
            "engine": {"name": entry.name, "params": params},
            "stats": {k: v for k, v in vars(service.stats).items()
                      if isinstance(v, (int, float))},
            "entries": [],
        }
        for e in service.catalog:
            snap["entries"].append({
                "name": e.name,
                "paths": list(e.paths),
                "nbytes": e.nbytes,
                "state": e.state.value,
                "t_ready": e.t_ready,
                "t_unleased": e.t_unleased,
                "leases": dict(e.leases),
                "stage_count": e.stage_count,
                "acquires": e.acquires,
                "hits": e.hits,
                "coalesced": e.coalesced,
                "repairs": e.repairs,
                "holders": sorted(e.holders),
                "placement": (None if e.placement is None else {
                    "replication": e.placement.replication,
                    "owners": {str(i): list(own)
                               for i, own in e.placement.owners.items()},
                }),
                "history": [[ht, hs.value] for ht, hs in e.history],
            })
        path = self._catalog_path(tag)
        with open(path, "w") as f:
            json.dump(snap, f)
        return path

    def restore_catalog(self, fabric, tag: str = "catalog",
                        registry=None):
        """Rebuild a :class:`~repro.core.datasvc.StagingService` from a
        catalog snapshot — the simulated SERVICE RESTART.

        The service process died; `fabric` (node-local stores included)
        is whatever survived. Every snapshotted entry's replica coverage
        is RE-VERIFIED against the stores: fully replicated entries whose
        live coverage is intact come back RESIDENT, entries missing
        replicas (a host died or was wiped while the service was down)
        come back DEGRADED with ``holders``/striped owners reflecting
        what is actually there — the next acquire repairs them through
        the normal self-healing path. Live leases are re-pinned on the
        surviving replica keys. Raises :class:`CheckpointError` if no
        snapshot ``tag`` exists."""
        from repro.core.api import ENGINES
        from repro.core.datasvc import (DatasetEntry, DatasetState,
                                        StagingService)
        path = self._catalog_path(tag)
        if not os.path.exists(path):
            raise CheckpointError(
                f"no catalog snapshot {path} — save_catalog was never "
                f"called (or the snapshot was lost)")
        snap = json.load(open(path))
        reg = registry if registry is not None else ENGINES
        engine = reg.config_for(snap["engine"]["name"],
                                **snap["engine"]["params"])
        service = StagingService(fabric, snap["budget_bytes"],
                                 engine=engine, registry=reg)
        for k, v in snap["stats"].items():
            if hasattr(service.stats, k):
                setattr(service.stats, k, v)
        t = snap["t"]
        live = set(fabric.live_ids(t)) if not fabric.faults.trivial else set(
            range(fabric.n_hosts))
        occupied = (DatasetState.RESIDENT, DatasetState.DEGRADED,
                    DatasetState.STAGING)
        for ed in snap["entries"]:
            entry = DatasetEntry(name=ed["name"], paths=list(ed["paths"]),
                                 nbytes=ed["nbytes"])
            entry.t_ready = ed["t_ready"]
            entry.t_unleased = ed["t_unleased"]
            entry.leases = dict(ed["leases"])
            entry.stage_count = ed["stage_count"]
            entry.acquires = ed["acquires"]
            entry.hits = ed["hits"]
            entry.coalesced = ed["coalesced"]
            entry.repairs = ed["repairs"]
            entry.history = [(ht, DatasetState(hs))
                             for ht, hs in ed["history"]]
            state = DatasetState(ed["state"])
            if state in occupied:
                state = self._verify_entry(fabric, entry, ed, live, t)
            entry.state = state
            entry.history.append((t, state))
            service.catalog.add(entry)
            # live leases survive the restart: re-pin each lease depth on
            # the replica keys that actually exist
            for _ in range(entry.lease_count):
                service._pin_once(entry, t)
        return service

    @staticmethod
    def _verify_entry(fabric, entry, ed: Dict[str, Any], live: set,
                      t: float):
        """Audit one snapshotted entry against the fabric's stores:
        returns the verified state and rewrites ``entry.holders`` /
        ``entry.placement`` to match reality."""
        from repro.core.datasvc import DatasetState
        from repro.core.staging import ReplicaPlacement
        n = fabric.n_hosts
        if ed["placement"] is None:
            holders = {h for h in ed["holders"]
                       if h in live and h < n
                       and all(p in fabric.hosts[h].store.data
                               for p in entry.paths)}
            entry.holders = holders
            return (DatasetState.RESIDENT if holders and live <= holders
                    else DatasetState.DEGRADED)
        pl = ed["placement"]
        owners = {}
        intact = True
        for i_str, own in pl["owners"].items():
            i = int(i_str)
            keys = [ReplicaPlacement.stripe_key(p, i) for p in entry.paths]
            alive_own = tuple(
                o for o in own
                if o in live and o < n
                and all(k in fabric.hosts[o].store.data for k in keys))
            owners[i] = alive_own
            if len(alive_own) < len(own):
                intact = False
        entry.placement = ReplicaPlacement(
            replication=pl["replication"], owners=owners)
        entry.holders = set(entry.placement.hosts())
        return (DatasetState.RESIDENT if intact
                else DatasetState.DEGRADED)

"""Serving: prefill + decode steps and a continuous-batching session.

The decode batch has fixed slots; each slot carries its own cache position
(per-slot lengths in every cache type), so requests at different depths decode
together. New requests are prefilled (chunk of their own) and spliced into a
free slot; finished requests free their slot. The request queue is drained by
the many-task engine in examples/serve_lm.py — serving is "many-task over
staged node-local data" in the paper's sense (weights + caches are the staged
data; requests are the tasks).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.layers import embed, rmsnorm


# ---------------------------------------------------------------------------
# jit-able steps
# ---------------------------------------------------------------------------

def prefill_step(params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                 capacity: int, ctx=None):
    """Prefill: inputs -> (last-token logits (B,V), populated caches)."""
    x = M.apply_frontend(params, cfg, inputs).astype(
        jnp.dtype(cfg.compute_dtype))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, caches = tf.stack_prefill(params["stack"], cfg, x, positions,
                                 capacity, ctx=ctx)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = M.head_table(params, cfg)
    logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(jnp.float32),
                        table.astype(jnp.float32))
    if table.shape[0] > cfg.vocab:
        logits = jnp.where(jnp.arange(table.shape[0]) < cfg.vocab, logits,
                           -1e30)
    return logits, caches


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, caches,
                ctx=None):
    """One token for every slot: (B,1) -> (logits (B,V), caches)."""
    return M.decode_step(params, cfg, tokens, caches)


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# continuous batching session (host-side orchestration)
# ---------------------------------------------------------------------------

@dataclass
class Request:
    request_id: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False


class ServeSession:
    """Fixed-slot continuous batching over a single decode batch."""

    def __init__(self, params, cfg: ModelConfig, batch_slots: int,
                 capacity: int, ctx=None):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.capacity = capacity
        self.ctx = ctx
        self.caches = M.init_decode_state(cfg, batch_slots, capacity)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._prefill = jax.jit(functools.partial(
            prefill_step, cfg=cfg, capacity=capacity, ctx=ctx),
            static_argnames=())
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg,
                                                 ctx=ctx))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _splice(self, slot: int, caches_new, token: int, length: int):
        """Insert a prefilled single-request cache into batch slot `slot`."""
        def ins(dst, src):
            return dst.at[:, slot].set(src[:, 0])     # leading dim = layers
        self.caches = jax.tree.map(
            lambda d, s: d.at[tuple([slice(None), slot])].set(s[:, 0])
            if d.ndim >= 2 else d, self.caches, caches_new)
        self.tokens[slot, 0] = token

    def step(self) -> int:
        """One engine step: admit pending requests, then decode all active
        slots. Returns number of active requests."""
        # admit
        while self.queue and self._free_slot() is not None:
            req = self.queue.pop(0)
            slot = self._free_slot()
            inputs = {"tokens": jnp.asarray(req.prompt[None, :])}
            logits, caches_new = self._prefill(self.params, inputs=inputs)
            first = int(greedy_sample(logits)[0])
            req.generated.append(first)
            req.slot = slot
            self.slots[slot] = req
            self._splice(slot, caches_new, first, len(req.prompt))
        if not any(self.slots):
            return 0
        # decode all slots together
        logits, self.caches = self._decode(self.params,
                                           tokens=jnp.asarray(self.tokens),
                                           caches=self.caches)
        nxt = np.asarray(greedy_sample(logits))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.generated.append(tok)
            self.tokens[i, 0] = tok
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
        return sum(r is not None for r in self.slots)

    def run_to_completion(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

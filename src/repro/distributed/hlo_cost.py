"""HLO-text cost analysis with while-loop trip-count scaling.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE —
under scan-over-layers that undercounts FLOPs, bytes, and collective traffic
by ~n_layers x. This module parses the compiled (post-SPMD) HLO text into
computations, determines scan trip counts from the loop condition, and
accumulates a cost model over ops with bodies multiplied by their trip
counts (nested loops compose).

Cost model (per partition — post-SPMD shapes are already per-device):
  dot          2 * prod(result_shape) * contracted_size FLOPs
  elementwise  prod(shape) FLOPs (unit weight)
  reduce       prod(operand shape) FLOPs
  bytes        sum of operand + result bytes for every op (HBM traffic proxy
               — an upper bound that ignores fusion locality; fusion
               computations are costed as one op: operands + outputs only)
  collectives  ring model:
                 all-gather      (P-1)/P * result_bytes
                 reduce-scatter  (P-1)/P * operand_bytes
                 all-reduce      2*(P-1)/P * result_bytes
                 all-to-all      (P-1)/P * operand_bytes
                 collective-permute  operand_bytes
               Split by whether the replica group crosses pods (DCN) or stays
               on-pod (ICI), using the device->pod map.

Validated against XLA cost_analysis on unrolled graphs (tests/test_roofline).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result shape may be a tuple "(s32[], f32[...])" — match non-greedily up to
# the first " opcode(" occurrence
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:to_apply|body|condition|branch_computations|"
                        r"called_computations)=\{?%?([\w.\-]+)")
_REPLICA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_REPLICA_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_REPLICA_IOTA_DIMS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]T\(([\d,]+)\)")


def _op_args(line: str, opname: str) -> Optional[str]:
    """Operand list of ``opname(...)`` with balanced parentheses — typed
    tuple-shaped operands ("(f32[128]{0}, s32[128]{0}) %sort.1") contain
    nested parens that a ``[^)]*`` capture would truncate."""
    i = line.find(opname + "(")
    if i < 0:
        return None
    start = i + len(opname) + 1
    depth = 1
    for j in range(start, len(line)):
        ch = line[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return line[start:j]
    return None


def _split_args(content: str) -> List[str]:
    """Split an op's operand list on top-level commas only. Older XLA dumps
    type every operand inline ("f32[128,128]{1,0} %arg"), so a naive
    split(",") breaks inside the shape brackets."""
    out: List[str] = []
    depth, cur = 0, []
    for ch in content:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [s.strip() for s in out if s.strip()]


def _parse_shape(text: str) -> Tuple[int, int]:
    """Return (elements, bytes) for a shape string like bf16[16,128]{1,0} or
    a tuple shape — tuples summed."""
    total_el, total_by = 0, 0
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        el = 1
        for d in dims.split(","):
            if d:
                el *= int(d)
        total_el += el
        total_by += el * _DTYPE_BYTES[dtype]
    return total_el, total_by


@dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    ici_collective_bytes: float = 0.0
    dcn_collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))

    def scaled(self, k: float) -> "OpCost":
        out = OpCost(self.flops * k, self.bytes * k,
                     self.ici_collective_bytes * k,
                     self.dcn_collective_bytes * k)
        for key, v in self.collective_breakdown.items():
            out.collective_breakdown[key] = v * k
        return out

    def add(self, other: "OpCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.ici_collective_bytes += other.ici_collective_bytes
        self.dcn_collective_bytes += other.dcn_collective_bytes
        for key, v in other.collective_breakdown.items():
            self.collective_breakdown[key] += v


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "not", "xor", "convert", "floor",
    "ceil", "round-nearest-afz", "sign", "cosine", "sine", "logistic",
    "expm1", "log1p", "clamp", "atan2", "remainder",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


class HloModule:
    """Parsed HLO module: computations -> list of op lines."""

    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.op_defs: Dict[str, Dict[str, str]] = {}   # comp -> op -> shape
        cur = None
        for line in text.splitlines():
            stripped = line.strip()
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$",
                         stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                self.computations[cur] = []
                self.op_defs[cur] = {}
                continue
            if stripped.startswith("}"):
                cur = None
                continue
            if cur is not None and "=" in stripped:
                self.computations[cur].append(stripped)
                om = _OP_RE.match(stripped)
                if om:
                    self.op_defs[cur][om.group(1)] = om.group(2)

    # ------------------------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        """Extract the trip count from a scan-style loop condition:
        compare(induction, constant(N)), direction=LT."""
        lines = self.computations.get(cond_comp, [])
        const_vals = {}
        for ln in lines:
            m = re.match(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\w+\[\]\s*"
                         r"constant\((\-?\d+)\)", ln)
            if m:
                const_vals[m.group(1)] = int(m.group(2))
        for ln in lines:
            if "compare(" in ln and "direction=LT" in ln:
                args = re.search(r"compare\(([^)]*)\)", ln)
                if args:
                    names = [a.split()[-1].lstrip("%") for a in
                             _split_args(args.group(1))]
                    for n in names:
                        if n in const_vals:
                            return max(1, const_vals[n])
        return 1

    # ------------------------------------------------------------------
    def _operand_bytes(self, comp: str, line: str, opname: str) -> float:
        """Sum bytes of operands referenced inside op(...)."""
        content = _op_args(line, opname)
        if content is None:
            return 0.0
        total = 0.0
        for arg in _split_args(content):
            if "[" in arg:                   # typed operand: shape inline
                total += _parse_shape(arg)[1]
                continue
            shape = self.op_defs.get(comp, {}).get(arg.lstrip("%"))
            if shape:
                total += _parse_shape(shape)[1]
        return total

    n_pods: int = 1

    def _collective_group_size(self, line: str, n_total: int) -> Tuple[int, bool]:
        """(group size, crosses_pod). Pod boundary: with device ids laid out
        [pod, data, model], a group crosses pods iff its id span >= the pod
        stride (n_total / n_pods). Iota-form groups [G,P]<=[N] have stride
        patterns; we conservatively flag groups containing ids from different
        halves when n_pods=2."""
        if self.n_pods <= 1:
            m = _REPLICA_RE.search(line)
            if m:
                return int(m.group(2)), False
            m = _REPLICA_LIST_RE.search(line)
            if m:
                ids = [x for x in m.group(1).split(",") if x.strip()]
                return max(1, len(ids)), False
            return 1, False
        m = _REPLICA_RE.search(line)
        if m:
            n_groups, gsize = int(m.group(1)), int(m.group(2))
            # iota [G,P]<=[N]: group g = contiguous ids? With transpose form
            # handled below; contiguous groups never cross the pod boundary
            # unless gsize > n_total // n_pods.
            crosses = gsize > n_total // self.n_pods
            mt = _REPLICA_IOTA_DIMS_RE.search(line)
            if mt:
                # transposed iota: ids stride across the leading dim; a group
                # crosses pods iff stride spacing reaches the other pod
                dims = [int(x) for x in mt.group(3).split(",")]
                perm = [int(x) for x in mt.group(4).split(",")]
                # group elements walk the last permuted dim; stride =
                # product of dims after it in original order
                # conservative: crosses if group span >= pod size
                pod = n_total // 2
                span = 1
                strides = []
                acc = 1
                for d in reversed(dims):
                    strides.append(acc)
                    acc *= d
                strides = list(reversed(strides))       # stride per dim
                last_dim = perm[-1]
                span = (dims[last_dim] - 1) * strides[last_dim]
                crosses = span >= pod
            return gsize, crosses
        m = _REPLICA_LIST_RE.search(line)
        if m:
            ids = [int(x) for x in m.group(1).split(",") if x.strip()]
            pod = max(1, n_total // self.n_pods)
            crosses = len({i // pod for i in ids}) > 1 if ids else False
            return max(1, len(ids)), crosses
        return 1, False

    def cost_op(self, comp: str, line: str, n_total: int) -> Optional[OpCost]:
        om = _OP_RE.match(line)
        if not om:
            return None
        name, result_shape, opcode = om.groups()
        res_el, res_by = _parse_shape(result_shape)
        c = OpCost()
        if opcode in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy", "after-all", "custom-call",
                      "partition-id", "iota", "rng-bit-generator"):
            return None
        if opcode == "dot":
            # contracted size from lhs shape and contracting dims
            content = _op_args(line, "dot")
            contracted = 1
            if content:
                lhs_seg = _split_args(content)[0]
                if "[" not in lhs_seg:       # untyped: resolve via op_defs
                    lhs_seg = self.op_defs.get(comp, {}).get(
                        lhs_seg.lstrip("%"), "")
                dm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if dm and lhs_seg:
                    sm = _SHAPE_RE.search(lhs_seg)
                    if sm:
                        dims = [int(x) for x in sm.group(2).split(",") if x]
                        for ci in dm.group(1).split(","):
                            if ci:
                                contracted *= dims[int(ci)]
            c.flops = 2.0 * res_el * contracted
            c.bytes = res_by + self._operand_bytes(comp, line, "dot")
            return c
        if opcode.startswith("fusion"):
            if "dynamic-update-slice" in name or "dynamic_update_slice" in name:
                # in-place update fusion: traffic = the update slice, not the
                # whole aliased buffer (read slice + write slice)
                content = _op_args(line, "fusion")
                small = 0.0
                if content:
                    for arg in _split_args(content):
                        if "[" not in arg:
                            arg = self.op_defs.get(comp, {}).get(
                                arg.lstrip("%"), "")
                        if arg:
                            b = _parse_shape(arg)[1]
                            if b != res_by:
                                small += b
                c.bytes = 2.0 * small
                return c
            c.bytes = res_by + self._operand_bytes(comp, line, "fusion")
            return c
        for coll in _COLLECTIVES:
            if opcode == coll:
                gsize, crosses = self._collective_group_size(line, n_total)
                opnd = self._operand_bytes(comp, line, coll)
                if coll == "all-gather":
                    wire = res_by * (gsize - 1) / max(gsize, 1)
                elif coll == "reduce-scatter":
                    wire = opnd * (gsize - 1) / max(gsize, 1)
                elif coll == "all-reduce":
                    wire = 2.0 * res_by * (gsize - 1) / max(gsize, 1)
                elif coll == "all-to-all":
                    wire = opnd * (gsize - 1) / max(gsize, 1)
                else:  # collective-permute
                    wire = opnd
                if crosses:
                    c.dcn_collective_bytes = wire
                else:
                    c.ici_collective_bytes = wire
                c.collective_breakdown[coll] += wire
                c.bytes = res_by + opnd
                return c
        if opcode == "reduce":
            c.flops = self._operand_bytes(comp, line, "reduce") / 2  # ~els
            c.bytes = res_by + self._operand_bytes(comp, line, "reduce")
            return c
        if opcode == "dynamic-update-slice":
            # in-place on TPU: traffic = read+write of the UPDATE slice, not
            # the whole buffer (scan ys-stacking would otherwise count the
            # full stack once per iteration)
            content = _op_args(line, "dynamic-update-slice")
            upd = 0.0
            if content:
                args = _split_args(content)
                if len(args) >= 2:
                    seg = args[1]
                    if "[" not in seg:
                        seg = self.op_defs.get(comp, {}).get(
                            seg.lstrip("%"), "")
                    if seg:
                        upd = _parse_shape(seg)[1]
            c.bytes = 2.0 * upd if upd else res_by
            return c
        if opcode == "dynamic-slice":
            c.bytes = 2.0 * res_by
            return c
        if opcode in ("gather", "scatter", "slice", "concatenate", "pad",
                      "reshape", "transpose", "broadcast", "reverse", "sort",
                      "reduce-window", "select-and-scatter"):
            c.bytes = res_by + self._operand_bytes(comp, line, opcode)
            c.flops = res_el if opcode in ("scatter", "sort") else 0.0
            return c
        if opcode in _ELEMENTWISE:
            c.flops = float(res_el)
            c.bytes = res_by + self._operand_bytes(comp, line, opcode)
            return c
        # default: count bytes only
        c.bytes = res_by
        return c

    # ------------------------------------------------------------------
    def cost_computation(self, comp: str, n_total: int,
                         memo: Dict[str, OpCost],
                         inside_fusion: bool = False) -> OpCost:
        key = comp + ("@f" if inside_fusion else "")
        if key in memo:
            return memo[key]
        total = OpCost()
        for line in self.computations.get(comp, []):
            om = _OP_RE.match(line)
            if not om:
                continue
            opcode = om.group(3)
            called = _CALLED_RE.findall(line)
            if opcode == "while":
                body_m = re.search(r"body=%?([\w.\-]+)", line)
                cond_m = re.search(r"condition=%?([\w.\-]+)", line)
                if body_m:
                    # XLA annotates scan loops with the known trip count
                    tm = re.search(r'known_trip_count[^\d]*(\d+)', line)
                    if tm:
                        trips = int(tm.group(1))
                    elif cond_m:
                        trips = self.trip_count(cond_m.group(1))
                    else:
                        trips = 1
                    body_cost = self.cost_computation(body_m.group(1),
                                                      n_total, memo)
                    total.add(body_cost.scaled(trips))
                continue
            if opcode in ("call", "conditional"):
                for sub in called:
                    total.add(self.cost_computation(sub, n_total, memo,
                                                    inside_fusion))
                continue
            if opcode == "fusion":
                # recurse for dot FLOPs; bytes count only at the boundary
                fm = re.search(r"calls=%?([\w.\-]+)", line)
                if fm:
                    inner = self.cost_computation(fm.group(1), n_total, memo,
                                                  inside_fusion=True)
                    total.add(inner)
                oc = self.cost_op(comp, line, n_total)
                if oc:
                    total.add(oc)
                continue
            oc = self.cost_op(comp, line, n_total)
            if oc:
                if inside_fusion:
                    oc.bytes = 0.0          # fused ops stay in registers
                total.add(oc)
        memo[key] = total
        return total

    def entry_computation(self) -> str:
        # entry is usually 'main...'; fall back to the largest computation
        for name in self.computations:
            if name.startswith("main"):
                return name
        return max(self.computations, key=lambda k: len(self.computations[k]))

    def total_cost(self, n_total: int, n_pods: int = 1) -> OpCost:
        memo: Dict[str, OpCost] = {}
        self.n_pods = n_pods
        return self.cost_computation(self.entry_computation(), n_total, memo)


def analyze_hlo_text(text: str, n_devices: int, n_pods: int = 1) -> OpCost:
    return HloModule(text).total_cost(n_devices, n_pods)

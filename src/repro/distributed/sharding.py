"""Sharding rules: FSDP x TP x EP (+ optional SP) over the production mesh.

Strategy (MaxText-flavored, adapted per architecture — see DESIGN.md §4):
  * TP ("model" axis): attention heads / FFN hidden / experts / vocab.
  * FSDP ("data" axis): the complementary dim of every large matrix
    (ZeRO-3-style; XLA inserts per-layer all-gathers in forward and
    reduce-scatters on grads). Required to fit 72B optimizer state.
  * DP: batch over ("pod","data") — the "pod" axis carries only gradient
    all-reduce traffic (bulk data stays on-pod: the paper's locality
    principle applied across pods).
  * GQA with n_kv_heads < tp: KV projections REPLICATED over tp (Megatron
    convention); q heads sharded.
  * RWKV6 time-mix: r/k/w replicated over tp; v / state / output sharded on
    the VALUE dim (the recurrence is independent across value channels).
  * Uneven dims (vocab 92553, hubert 504) fall back to replicated.

``param_pspecs(cfg, params)`` walks the param tree by path and returns a
matching tree of PartitionSpec. Rules apply to TRAILING dims; stacked layer
params (leading n_layers dim from scan-over-layers) get None prepended.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class ShardCtx:
    """Mesh context plumbed through model code for activation constraints."""
    mesh: Mesh
    dp_axes: Tuple[str, ...] = ("data",)
    fsdp_axis: Optional[str] = "data"
    tp_axis: Optional[str] = "model"
    sequence_parallel: bool = False

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis] if self.tp_axis else 1

    @property
    def dp_size(self) -> int:
        size = 1
        for a in self.dp_axes:
            size *= self.mesh.shape[a]
        return size

    def constrain(self, x, *spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))


# a rule: (path regex, spec builder). Spec entries are logical axis names
# resolved against the ctx; "tp*" means "tp if divisible else None".
Rule = Tuple[str, Tuple[Optional[str], ...]]

RULES: Sequence[Rule] = (
    (r"embed/table$",                ("tp*", None)),
    (r"^head$",                      ("fsdp*", "tp*")),
    (r"frontend/(fc1|fc2|proj)$",    ("fsdp*", "tp*")),
    # --- attention (GQA) ---
    (r"attn/wq$",                    ("fsdp*", "tp*")),
    (r"attn/w[kv]$",                 ("fsdp*", "kv*")),
    (r"attn/wo$",                    ("tp*", "fsdp*")),
    (r"attn/bq$",                    ("tp*",)),
    (r"attn/b[kv]$",                 ("kv*",)),
    # --- MLA ---
    (r"attn/w_dkv$",                 ("fsdp*", None)),
    (r"attn/w_u[kv]$",               ("fsdp*", "tp*")),
    # --- dense mlp ---
    (r"mlp/w_(gate|up)$",            ("fsdp*", "tp*")),
    (r"mlp/w_down$",                 ("tp*", "fsdp*")),
    # --- moe ---
    (r"moe/router$",                 ("fsdp*", None)),
    (r"moe/w_(gate|up)$",            ("tp*", "fsdp*", None)),
    (r"moe/w_down$",                 ("tp*", None, "fsdp*")),
    (r"moe/shared/w_(gate|up)$",     ("fsdp*", "tp*")),
    (r"moe/shared/w_down$",          ("tp*", "fsdp*")),
    # --- mamba2 (split projections; see models/mamba2.py) ---
    (r"mixer/in_[zx]$",              ("fsdp*", "tp*")),
    (r"mixer/in_[BC]$",              ("fsdp*", None)),
    (r"mixer/in_dt$",                ("fsdp*", "tp*")),
    (r"mixer/conv_x$",               (None, "tp*")),
    (r"mixer/conv_bx$",              ("tp*",)),
    (r"mixer/conv_[BC]$",            (None, None)),
    (r"mixer/(dt_bias|A_log|D)$",    ("tp*",)),
    (r"mixer/norm/scale$",           ("tp*",)),
    (r"mixer/out_proj$",             ("tp*", "fsdp*")),
    # --- rwkv6 ---
    (r"mixer/w[vg]$",                ("fsdp*", "tp*")),
    (r"mixer/w[rk]$",                ("fsdp*", None)),
    (r"mixer/wo$",                   ("tp*", "fsdp*")),
    (r"mixer/(decay_a|mix_a|cm_r)$", ("fsdp*", None)),
    (r"mixer/cm_k$",                 ("fsdp*", "tp*")),
    (r"mixer/cm_v$",                 ("tp*", "fsdp*")),
    # --- zamba site loras ---
    (r"loras/a_[qk]$",               ("fsdp*", None)),
    (r"loras/b_[qk]$",               (None, "tp*")),
)


def _tree_paths(tree: Any, prefix: str = "") -> Dict[str, Any]:
    """Flatten nested dicts/namedtuples to path->leaf."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_tree_paths(v, f"{prefix}/{k}" if prefix else str(k)))
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            v = getattr(tree, k)
            out.update(_tree_paths(v, f"{prefix}/{k}" if prefix else str(k)))
    else:
        out[prefix] = tree
    return out


def _resolve(axis: Optional[str], dim: int, cfg: ModelConfig,
             ctx: ShardCtx) -> Optional[str | Tuple[str, ...]]:
    if axis is None:
        return None
    starred = axis.endswith("*")
    base = axis.rstrip("*")
    if base == "kv":
        # GQA kv projections: shard only if kv heads divide tp
        name = ctx.tp_axis
        if name is None:
            return None
        if cfg.n_kv_heads % ctx.tp_size != 0:
            return None
        base, starred = "tp", True
    name = {"tp": ctx.tp_axis, "fsdp": ctx.fsdp_axis}.get(base, base)
    if name is None:
        return None
    size = ctx.mesh.shape[name]
    if starred and dim % size != 0:
        return None             # uneven dim -> replicate
    return name


def spec_for_path(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                  ctx: ShardCtx) -> P:
    for pattern, logical in RULES:
        if re.search(pattern, path):
            n_extra = len(shape) - len(logical)
            resolved = tuple(
                _resolve(a, shape[n_extra + i], cfg, ctx)
                for i, a in enumerate(logical))
            return P(*((None,) * n_extra + resolved))
    return P()                   # norms, scalars, biases: replicated


def param_pspecs(cfg: ModelConfig, params: Any, ctx: ShardCtx) -> Any:
    """Tree of PartitionSpec matching `params` (arrays or ShapeDtypeStructs)."""
    flat = _tree_paths(params)
    specs = {p: spec_for_path(p, v.shape, cfg, ctx) for p, v in flat.items()}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*(rebuild(getattr(tree, k),
                                        f"{prefix}/{k}" if prefix else str(k))
                                for k in tree._fields))
        return specs[prefix]
    return rebuild(params)


def param_shardings(cfg: ModelConfig, params: Any, ctx: ShardCtx) -> Any:
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s),
                        param_pspecs(cfg, params, ctx),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# input/output specs per shape kind
# ---------------------------------------------------------------------------

def batch_pspec(ctx: ShardCtx) -> P:
    return P(ctx.dp_axes)


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardCtx
                 ) -> Dict[str, P]:
    """PartitionSpecs for the input dict (batch over all dp axes)."""
    b = ctx.dp_axes if shape.global_batch % ctx.dp_size == 0 else (
        ctx.dp_axes[0] if shape.global_batch % ctx.mesh.shape[ctx.dp_axes[0]] == 0
        else None)
    specs: Dict[str, P] = {}
    if cfg.frontend.kind == "audio_frames":
        specs["features"] = P(b, None, None)
        specs["labels"] = P(b, None)
        return specs
    specs["tokens"] = P(b, None)
    if shape.kind == "train":
        specs["labels"] = P(b, None)
    if cfg.frontend.kind == "vision_patches":
        specs["image_embeds"] = P(b, None, None)
    return specs


def cache_pspecs(cfg: ModelConfig, caches: Any, ctx: ShardCtx) -> Any:
    """Decode caches: batch dim over dp; kv-heads/value dims over tp where
    divisible. Cache trees are stacked (leading layer dim). batch=1
    (long_500k) leaves the batch dim unsharded — state/cap dims carry the
    parallelism instead."""
    def leaf_spec(path: str, l) -> P:
        shp = l.shape
        if path.endswith("length"):
            return P(*((None,) * len(shp)))
        # stacked leading layer dim + batch next
        b_axes = ctx.dp_axes if shp[1] % ctx.dp_size == 0 else None
        spec: list = [None, b_axes]
        rest = len(shp) - 2
        trailing: list = [None] * rest
        if ctx.tp_axis is not None and rest >= 1:
            tp = ctx.mesh.shape[ctx.tp_axis]
            if "shared_kv" in path or "/k" in path or "/v" in path:
                # KV cache (layers, B, cap, n_kv, hd): shard kv heads when
                # divisible, else split-KV (cap dim) — bounds per-device
                # cache bytes AND parallelizes decode attention over tp.
                # Very long contexts (>=128k) ALWAYS split-KV: the cap dim is
                # the memory, and cap/tp beats heads/tp when batch is tiny
                # (zamba2 long_500k: 12.2 -> 0.8 GiB/device).
                long_ctx = rest >= 2 and shp[2] >= 131072
                if rest >= 2 and shp[3] % tp == 0 and not long_ctx:
                    trailing[1] = ctx.tp_axis
                elif shp[2] % tp == 0:
                    trailing[0] = ctx.tp_axis
            elif path.endswith("/h"):
                # ssm state (layers,B,G,HG,P,N): shard HG
                if shp[3] % tp == 0:
                    trailing[1] = ctx.tp_axis
            elif path.endswith("/s"):
                # rwkv state (layers,B,H,Nk,Nv): shard value dim
                if shp[-1] % tp == 0:
                    trailing[-1] = ctx.tp_axis
            elif path.endswith("/conv"):
                if shp[-1] % tp == 0:
                    trailing[-1] = ctx.tp_axis
        return P(*(spec + trailing))

    flat = _tree_paths(caches)
    specs = {p: leaf_spec(p, l) for p, l in flat.items()}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*(rebuild(getattr(tree, k),
                                        f"{prefix}/{k}" if prefix else str(k))
                                for k in tree._fields))
        return specs[prefix]
    return rebuild(caches)


def make_ctx(mesh: Mesh, sequence_parallel: bool = False) -> ShardCtx:
    axes = tuple(mesh.axis_names)
    if "pod" in axes:
        dp = ("pod", "data")
    else:
        dp = ("data",)
    return ShardCtx(mesh=mesh, dp_axes=dp, fsdp_axis="data", tp_axis="model",
                    sequence_parallel=sequence_parallel)


# ---------------------------------------------------------------------------
# explicit FSDP weight prefetch
# ---------------------------------------------------------------------------

def fsdp_gather(subtree: Any, cfg: ModelConfig, ctx: Optional[ShardCtx],
                prefix: str = "") -> Any:
    """Constrain every weight in `subtree` to its rule spec with the fsdp
    axis REMOVED (i.e. all-gathered over data at point of use).

    GSPMD's einsum handler sometimes reshards activations (hundreds of MB)
    instead of gathering the much smaller fsdp-sharded weight; this makes the
    ZeRO-3 prefetch explicit: weights arrive via a param-sized all-gather in
    forward (and its transpose reduce-scatters the grads).
    """
    if ctx is None or ctx.fsdp_axis is None:
        return subtree
    no_fsdp = ShardCtx(mesh=ctx.mesh, dp_axes=ctx.dp_axes, fsdp_axis=None,
                       tp_axis=ctx.tp_axis,
                       sequence_parallel=ctx.sequence_parallel)

    def walk(tree, pfx):
        if isinstance(tree, dict):
            return {k: walk(v, f"{pfx}/{k}" if pfx else str(k))
                    for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*(walk(getattr(tree, k),
                                     f"{pfx}/{k}" if pfx else str(k))
                                for k in tree._fields))
        if getattr(tree, "ndim", 0) >= 2:
            spec = spec_for_path(pfx, tree.shape, cfg, no_fsdp)
            return jax.lax.with_sharding_constraint(
                tree, NamedSharding(ctx.mesh, spec))
        return tree
    return walk(subtree, prefix)

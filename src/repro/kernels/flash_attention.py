"""Pallas TPU flash attention: GQA + causal + sliding-window.

TPU-native design (not a CUDA port):
  * Inputs flattened to (B*KV, G, S, hd): one program per (batch x kv-head,
    q-block); the q tile (and its G grouped query heads) live in VMEM.
  * K/V for the program's kv-head are VMEM-resident (S<=32k x hd=128 bf16 =
    8 MB — fits v5e's ~128 MB VMEM alongside tiles), streamed MXU-tile by
    tile with an online-softmax running (max, denom) in fp32 VREGs.
  * Causal/sliding-window masking is applied per kv-tile; fully-masked kv
    tiles are SKIPPED (loop bounds depend on the q-block index), so SWA does
    ~window/S of the full-attention work — the structural saving, not a mask.
  * MXU alignment: block_q x block_k = 128 x 128 (head_dim padded to 128).

Validated in interpret mode against flash_attention_ref.reference (tests/
test_kernels.py sweeps shapes/dtypes/window/causality).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
            window: int, block_k: int, seq_k: int):
    """One (batch*kv_head, q_block) program.

    q_ref: (1, G, block_q, hd) | k_ref/v_ref: (1, seq_k, hd).
    """
    _, G, block_q, hd = q_ref.shape
    q_blk_idx = pl.program_id(1)
    q_start = q_blk_idx * block_q

    q = q_ref[0].astype(jnp.float32) * scale             # (G, bq, hd)

    # kv range this q-block can see
    lo = 0
    if window > 0:
        lo = jnp.maximum(q_start + 1 - window, 0) // block_k
    hi = seq_k // block_k
    if causal:
        hi = jnp.minimum(hi, (q_start + block_q + block_k - 1) // block_k)

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_start = kb * block_k
        # leading dim via a size-1 dslice, not a bare int: older Pallas
        # interpreters reject scalar indices in load index tuples
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(k_start, block_k),
                            slice(None)))[0].astype(jnp.float32)  # (bk, hd)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(k_start, block_k),
                            slice(None)))[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # s: (G, bq, bk) — mask
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                               block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                               block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok[None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)                      # (G, bq)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])                # (G, bq, bk)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return acc, m_new, l_new

    acc0 = jnp.zeros((G, block_q, hd), jnp.float32)
    m0 = jnp.full((G, block_q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G, block_q), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,S,KV,hd); H = KV*G. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0

    # (B,S,H,hd) -> (B*KV, G, S, hd)
    qf = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV, G, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    grid = (B * KV, S // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_k=block_k, seq_k=S),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, S, hd), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, block_q, hd), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, block_q, hd), lambda b, i: (b, 0, i, 0)),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, KV, G, S, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(B, S, H, hd)

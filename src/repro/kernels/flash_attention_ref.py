"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def reference(q, k, v, *, causal: bool = True, window: int = 0,
              scale: Optional[float] = None) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,S,KV,hd). Dense grouped attention."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)

"""Pallas TPU kernel for the chunked RWKV6 WKV recurrence.

Grid: (B*H, num_chunks) with the chunk axis sequential; the (N,N) per-head
state is a VMEM f32 scratch carried across chunks (reset at chunk 0,
emitted at the last chunk).

Per program: r/k/v/w chunk tiles (Q,N) in VMEM. The intra-chunk pairwise
decay tensor (Q,Q,N) is materialized per chunk only (Q=32, N=64 -> 256 KB),
exactly the tile the XLA fallback streams (models/rwkv6.wkv_chunked).
Decay stays in log space until the final exp (stability: all exponents <=0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, s_scratch,
            *, nc: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        s_scratch[...] = jnp.zeros_like(s_scratch)

    r = r_ref[0].astype(jnp.float32)                     # (Q,N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                     # (N,)
    Q, N = r.shape

    lw = jnp.log(jnp.maximum(w, 1e-20))
    lcum = jnp.cumsum(lw, axis=0)                        # (Q,N) inclusive
    lprev = lcum - lw                                    # exclusive

    # intra-chunk: pair[q,j,i] = exp(lprev_q - lcum_j)_i for j < q (<=0: safe)
    diff = lprev[:, None, :] - lcum[None, :, :]          # (Q,Q,N)
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    pair = jnp.where(mask[..., None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("qi,qji,ji->qj", r, pair, k,
                        preferred_element_type=jnp.float32)
    o = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Q,N)
    # current-step bonus
    bonus = jnp.sum(r * u[None, :] * k, axis=1)          # (Q,)
    o = o + bonus[:, None] * v
    # carried state: o += (r * exp(lprev)) @ S
    s = s_scratch[...]
    o = o + jax.lax.dot_general(r * jnp.exp(lprev), s,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update: S' = diag(exp(lcum_Q)) S + (k * decay_to_end)^T @ v
    decay_end = jnp.exp(lcum[-1][None, :] - lcum)        # (Q,N)
    upd = jax.lax.dot_general(k * decay_end, v, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (N,N)
    s_scratch[...] = s * jnp.exp(lcum[-1])[:, None] + upd
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _fin():
        s_out_ref[0] = s_scratch[...]


def rwkv6_wkv(r, k, v, w, u, chunk: int = 32, interpret: bool = True):
    """r/k/v/w: (B,L,H,N); u: (H,N). Returns (out (B,L,H,N), s (B,H,N,N))."""
    B, L, H, N = r.shape
    Q = min(chunk, L)
    while L % Q:
        Q -= 1
    nc = L // Q
    flat = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, L, N)
    uf = jnp.tile(u, (B, 1)).reshape(B * H, N)

    out, s_final = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        out_shape=(jax.ShapeDtypeStruct((B * H, L, N), r.dtype),
                   jax.ShapeDtypeStruct((B * H, N, N), jnp.float32)),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, N), lambda bh, c: (bh, 0)),
        ],
        out_specs=(pl.BlockSpec((1, Q, N), lambda bh, c: (bh, c, 0)),
                   pl.BlockSpec((1, N, N), lambda bh, c: (bh, 0, 0))),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(w), uf)
    return (out.reshape(B, H, L, N).transpose(0, 2, 1, 3),
            s_final.reshape(B, H, N, N))

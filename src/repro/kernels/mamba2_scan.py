"""Pallas TPU kernel for the chunked Mamba2 SSD scan.

Grid: (B*H, num_chunks) — the chunk axis is the minor (sequential) grid
dimension, so the per-head SSM state lives in a VMEM scratch that persists
across chunk iterations (TPU grid revisiting semantics); it is reset at
chunk 0 and written out at the last chunk.

Per program (head h of batch b, chunk c):
  VMEM tiles: x (Q,P), dt (Q,), B/C (Q,N), state (P,N) f32.
  intra-chunk: masked decay-weighted (Q x Q) matmul (MXU);
  inter-chunk:  y += exp(cum) * (C @ h^T); h = exp(cum_Q) h + x^T @ (B.dt.decay)

Defaults Q=128, N=64, P=64: tiles are MXU-aligned (128x64, 64x64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_out_ref, h_scratch,
            *, nc: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)                     # (Q,P)
    dt = dt_ref[0].astype(jnp.float32)                   # (Q,)
    A = a_ref[0].astype(jnp.float32)                     # (1,) scalar
    Bm = b_ref[0].astype(jnp.float32)                    # (Q,N)
    Cm = c_ref[0].astype(jnp.float32)                    # (Q,N)
    Q = x.shape[0]

    a = dt * A                                           # (Q,) log-decay
    cum = jnp.cumsum(a)
    diff = cum[:, None] - cum[None, :]                   # (Q,Q)
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    lmat = jnp.where(mask, jnp.exp(diff), 0.0)
    gmat = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (Q,Q)
    m = gmat * lmat * dt[None, :]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)     # (Q,P)
    # carried-state contribution: (Q,N) @ (N,P)
    h = h_scratch[...]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    # state update: h' = exp(cum_Q) h + x^T @ (B * dt * decay_to_end)
    decay_end = jnp.exp(cum[-1] - cum) * dt              # (Q,)
    bw = Bm * decay_end[:, None]                         # (Q,N)
    upd = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (P,N)
    h_scratch[...] = h * jnp.exp(cum[-1]) + upd
    o_ref[0] = y.astype(o_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _fin():
        h_out_ref[0] = h_scratch[...]


def mamba2_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int = 128, interpret: bool = True):
    """x: (B,L,H,P); dt: (B,L,H); A: (H,); Bm/Cm: (B,L,G,N) with G|H.
    Returns (y (B,L,H,P), h_final (B,H,P,N))."""
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    HG = H // G
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q

    xf = x.transpose(0, 2, 1, 3).reshape(B * H, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, L)
    af = jnp.tile(A, B).reshape(B * H, 1)
    bf = Bm.transpose(0, 2, 1, 3).reshape(B * G, L, N)
    cf = Cm.transpose(0, 2, 1, 3).reshape(B * G, L, N)

    def bc_map(bh, c):
        return ((bh // H) * G + (bh % H) // HG, c, 0)

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        out_shape=(jax.ShapeDtypeStruct((B * H, L, P), x.dtype),
                   jax.ShapeDtypeStruct((B * H, P, N), jnp.float32)),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, 1), lambda bh, c: (bh, 0)),
            pl.BlockSpec((1, Q, N), bc_map),
            pl.BlockSpec((1, Q, N), bc_map),
        ],
        out_specs=(pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
                   pl.BlockSpec((1, P, N), lambda bh, c: (bh, 0, 0))),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)
    return (y.reshape(B, H, L, P).transpose(0, 2, 1, 3),
            h_final.reshape(B, H, P, N))

"""Pure-jnp oracle for the HEDM stage-1 reduction kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _neighborhood(img):
    H, W = img.shape
    padded = jnp.pad(img, 1, mode="edge")
    return jnp.stack([jax.lax.dynamic_slice(padded, (di, dj), (H, W))
                      for di in range(3) for dj in range(3)])


def reference(frames, dark, threshold: float = 100.0):
    """frames: (F,H,W); dark: (H,W). Returns (mask uint8, counts int32)."""
    def one(img):
        img = jnp.maximum(img.astype(jnp.float32) - dark.astype(jnp.float32),
                          0.0)
        med = jnp.median(_neighborhood(img), axis=0)
        n = _neighborhood(med)
        lap = 8.0 * n[4] - (n[0] + n[1] + n[2] + n[3] + n[5] + n[6] + n[7]
                            + n[8])
        mask = (lap > threshold) & (med > threshold * 0.5)
        return mask.astype(jnp.uint8), jnp.sum(mask.astype(jnp.int32))

    masks, counts = jax.vmap(one)(frames)
    return masks, counts

"""Pure-jnp oracle for the Mamba2 SSD kernel (naive recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference(x, dt, A, Bm, Cm):
    """x: (B,L,H,P); dt: (B,L,H); A: (H,); Bm/Cm: (B,L,G,N).
    Returns (y, h_final (B,H,P,N))."""
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    HG = H // G
    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    group = jnp.arange(H) // HG

    def step(h, inp):
        xt, dtt, bt, ct = inp                    # (B,H,P),(B,H),(B,G,N)x2
        bt_h = bt[:, group]                      # (B,H,N)
        ct_h = ct[:, group]
        da = jnp.exp(dtt * A)                    # (B,H)
        h = h * da[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, bt_h)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct_h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h

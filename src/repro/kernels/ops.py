"""Public jit'd wrappers for the Pallas kernels.

Each op auto-selects interpret mode off-TPU (the CPU container) and the
compiled Mosaic path on TPU. The XLA reference implementations in
repro.models remain the dry-run/AOT path (Pallas does not lower on the CPU
backend); these wrappers are the deployment path and the test subject.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import hedm_reduce as _hr
from repro.kernels import mamba2_scan as _ms
from repro.kernels import rwkv6_wkv as _rw


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba2_scan(x, dt, A, Bm, Cm, chunk=128):
    return _ms.mamba2_scan(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_wkv(r, k, v, w, u, chunk=32):
    return _rw.rwkv6_wkv(r, k, v, w, u, chunk=chunk,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("threshold",))
def hedm_reduce(frames, dark, threshold=100.0):
    # interpret auto-selection (compiled Mosaic on TPU, interpreter
    # elsewhere) lives in the kernel itself: interpret=None
    return _hr.hedm_reduce(frames, dark, threshold=threshold)

"""Pure-jnp oracle for the WKV6 kernel (naive per-step recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference(r, k, v, w, u):
    """r/k/v/w: (B,L,H,N); u: (H,N). Returns (out, s_final (B,H,N,N))."""
    B, L, H, N = r.shape
    s0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        o = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, o

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
               for t in (r, k, v, w))
    s, os_ = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(os_, 0, 1).astype(r.dtype), s

"""Pallas TPU kernel for NF-HEDM Stage-1 image reduction (paper §VI-A).

Per-tile pipeline (one detector row tile per program, tile resident in VMEM):
  1. dark-frame (median background) subtraction,
  2. 3x3 median filter (19-exchange min/max sorting network — pure VPU ops,
     no data-dependent control flow),
  3. 3x3 Laplacian (edge/diffraction-spot response),
  4. threshold -> binary spot mask + per-tile signal-pixel count.

The median and Laplacian stages are FUSED: the kernel receives its tile with
a 2-pixel halo (rows gathered by the wrapper, columns edge-padded with it),
computes the median on the 1-halo-extended domain from ONE set of 9 shifted
neighborhoods, and takes the Laplacian directly from static slices of that
extended median — no second round of shifted copies (the unfused version
materialised 18). At interior tile boundaries the halo medians come from
real neighbouring rows; at true frame borders the reference semantics
replicate the COMPUTED median (not the input), so the kernel rebuilds the
median halo ring there by edge-replication — making the fused result
bit-identical to the reference oracle on arbitrary data.

Grid: (F, T) — frames x row tiles. Small frames run as one tile; frames
whose working set exceeds the VMEM budget are row-tiled, each tile carrying
a 2-row halo from its neighbours (halo exchange done as a wrapper-side
gather; on real hardware this is an overlapping DMA). Connected-component
labeling stays on the host (repro.hedm.pipeline) — control-flow-heavy, a
poor fit for the MXU/VPU; the paper runs it on cluster CPUs too.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HALO = 2                       # median (1) + Laplacian (1) support rows


def _median9(vals):
    """Median of 9 same-shape arrays via the classic 19-exchange network."""
    v = list(vals)

    def sort2(i, j):
        lo = jnp.minimum(v[i], v[j])
        hi = jnp.maximum(v[i], v[j])
        v[i], v[j] = lo, hi

    pairs = [(1, 2), (4, 5), (7, 8), (0, 1), (3, 4), (6, 7), (1, 2), (4, 5),
             (7, 8), (0, 3), (5, 8), (4, 7), (3, 6), (1, 4), (2, 5), (4, 7),
             (4, 2), (6, 4), (4, 2)]
    for i, j in pairs:
        sort2(i, j)
    return v[4]


def _window9(ext, h, w):
    """The 3x3 neighborhood of an (h+2, w+2)-padded tile as 9 static slices
    (lax.slice — no materialised shifted copies beyond what the VPU needs)."""
    return [ext[di:di + h, dj:dj + w] for di in range(3) for dj in range(3)]


def _kernel(ext_ref, dark_ref, mask_ref, count_ref, *, threshold: float,
            tile: int, width: int, height: int):
    """Fused subtract -> median -> Laplacian -> threshold on one row tile.

    ext_ref:  (1, 1, tile+4, width+4) frame tile with 2-px halo all around.
    dark_ref: (1, tile+4, width+4) matching dark-frame tile.
    """
    img = ext_ref[0, 0].astype(jnp.float32)
    dark = dark_ref[0].astype(jnp.float32)
    img = jnp.maximum(img - dark, 0.0)                  # background subtract
    # median on the 1-halo-extended domain: rows/cols [-1, tile+1) x
    # [-1, width+1), from ONE set of 9 shifted neighborhoods
    med_ext = _median9(_window9(img, tile + 2, width + 2))
    # At a TRUE frame border the reference replicates the computed median,
    # not the input: a halo median there would see the border row three
    # times (2-px input replication) and differ. Rebuild those medians by
    # replication — the top halo only when this tile is the frame top
    # (interior halos hold real neighbour data), columns always, and every
    # row below global row height-1 (the bottom halo of the last tile AND
    # any padded tail rows when tile does not divide height) clamps to the
    # boundary row's median.
    t = pl.program_id(1)
    top = jnp.where(t == 0, med_ext[1:2], med_ext[0:1])
    med_ext = jnp.concatenate([top, med_ext[1:]], axis=0)
    r_star = height - t * tile        # local med_ext index of frame row H-1
    brow = jax.lax.dynamic_slice(med_ext, (jnp.clip(r_star, 0, tile + 1), 0),
                                 (1, width + 2))
    ridx = jax.lax.broadcasted_iota(jnp.int32, (tile + 2, 1), 0)
    med_ext = jnp.where(ridx > r_star, brow, med_ext)
    med_ext = jnp.concatenate([med_ext[:, 1:2], med_ext[:, 1:-1],
                               med_ext[:, -2:-1]], axis=1)
    # Laplacian straight from slices of the extended median — the fusion:
    # no second neighborhood build
    n = _window9(med_ext, tile, width)
    lap = 8.0 * n[4] - (n[0] + n[1] + n[2] + n[3] + n[5] + n[6] + n[7] + n[8])
    mask = (lap > threshold) & (n[4] > threshold * 0.5)
    mask_ref[0] = mask.astype(jnp.uint8)
    count_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))


def _pick_tile(H: int, W: int, vmem_budget_bytes: int) -> int:
    """Largest power-of-two row tile whose f32 working set (ext tile, 9
    shifted median inputs, extended median, mask — ~12 live (tile+4, W+4)
    buffers) fits the VMEM budget. Interpret mode has no hard limit; the
    budget models the TPU."""
    tile = 1 << max(0, (H - 1).bit_length())         # next pow2 >= H
    while tile > 8 and 12 * (tile + 4) * (W + 4) * 4 > vmem_budget_bytes:
        tile //= 2
    return min(tile, H)


def hedm_reduce(frames: jax.Array, dark: jax.Array, threshold: float = 100.0,
                interpret: Optional[bool] = None,
                tile_rows: Optional[int] = None,
                vmem_budget_bytes: int = 8 << 20):
    """frames: (F,H,W) uint16/f32 detector stack; dark: (H,W) background.
    Returns (mask (F,H,W) uint8, counts (F,) int32).

    interpret=None auto-selects: compiled Mosaic on a real TPU backend,
    interpreter elsewhere (Pallas does not lower on CPU). Frames whose
    working set exceeds ``vmem_budget_bytes`` are row-tiled (grid (F, T))
    with a 2-row halo; ``tile_rows`` forces a tile height for testing.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    F, H, W = frames.shape
    tile = tile_rows if tile_rows is not None else _pick_tile(
        H, W, vmem_budget_bytes)
    tile = max(1, min(tile, H))
    T = (H + tile - 1) // tile
    Hp = T * tile                                  # padded row count

    # halo exchange, wrapper-side: gather each tile's rows plus a 2-row /
    # 2-col edge-replicated halo into (F, T, tile+4, W+4) so the kernel is
    # pure slices + arithmetic (Mosaic-friendly; overlapping DMA on TPU).
    padded = jnp.pad(frames, ((0, 0), (HALO, HALO + Hp - H), (HALO, HALO)),
                     mode="edge")
    rows = (np.arange(T)[:, None] * tile
            + np.arange(tile + 2 * HALO)[None, :])          # (T, tile+4)
    ext = padded[:, rows, :]                                # (F,T,tile+4,W+4)
    dark_ext = jnp.pad(dark, ((HALO, HALO + Hp - H), (HALO, HALO)),
                       mode="edge")[rows, :]                # (T,tile+4,W+4)

    mask, counts = pl.pallas_call(
        functools.partial(_kernel, threshold=threshold, tile=tile, width=W,
                          height=H),
        out_shape=(jax.ShapeDtypeStruct((F, Hp, W), jnp.uint8),
                   jax.ShapeDtypeStruct((F, T), jnp.int32)),
        grid=(F, T),
        in_specs=[
            pl.BlockSpec((1, 1, tile + 2 * HALO, W + 2 * HALO),
                         lambda f, t: (f, t, 0, 0)),
            pl.BlockSpec((1, tile + 2 * HALO, W + 2 * HALO),
                         lambda f, t: (t, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((1, tile, W), lambda f, t: (f, t, 0)),
                   pl.BlockSpec((1, 1), lambda f, t: (f, t))),
        interpret=interpret,
    )(ext, dark_ext)

    if Hp != H:     # padded tail rows carry replicated data: drop & recount
        mask = mask[:, :H]
        counts = jnp.sum(mask.astype(jnp.int32), axis=(1, 2))
    else:
        counts = jnp.sum(counts, axis=1)
    return mask, counts

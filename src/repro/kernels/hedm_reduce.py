"""Pallas TPU kernel for NF-HEDM Stage-1 image reduction (paper §VI-A).

Per-frame pipeline (one detector frame per program, frame resident in VMEM):
  1. dark-frame (median background) subtraction,
  2. 3x3 median filter (19-exchange min/max sorting network — pure VPU ops,
     no data-dependent control flow),
  3. 3x3 Laplacian (edge/diffraction-spot response),
  4. threshold -> binary spot mask + per-frame signal-pixel count.

This is the compute half of the paper's data-reduction step that shrinks
8 MB frames to ~1 MB of signal ("Because of the sparse nature of the data").
Connected-component labeling stays on the host (repro.hedm.stage1) — it is
control-flow-heavy and a poor fit for the MXU/VPU; the paper runs it on
cluster CPUs too.

Grid: (F,) frames; block = full frame tile (detector rows x cols), which for
a 2048x2048 uint16 frame is 8 MB -> fits VMEM as f32 tiles after windowing.
Frames larger than VMEM budget are row-tiled by the ops wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _median9(vals):
    """Median of 9 same-shape arrays via the classic 19-exchange network."""
    v = list(vals)

    def sort2(i, j):
        lo = jnp.minimum(v[i], v[j])
        hi = jnp.maximum(v[i], v[j])
        v[i], v[j] = lo, hi

    pairs = [(1, 2), (4, 5), (7, 8), (0, 1), (3, 4), (6, 7), (1, 2), (4, 5),
             (7, 8), (0, 3), (5, 8), (4, 7), (3, 6), (1, 4), (2, 5), (4, 7),
             (4, 2), (6, 4), (4, 2)]
    for i, j in pairs:
        sort2(i, j)
    return v[4]


def _shifts3x3(img):
    """The 3x3 neighborhood as 9 shifted copies (edge-replicated)."""
    H, W = img.shape
    padded = jnp.pad(img, 1, mode="edge")
    return [jax.lax.dynamic_slice(padded, (di, dj), (H, W))
            for di in range(3) for dj in range(3)]


def _kernel(frame_ref, dark_ref, mask_ref, count_ref, *, threshold: float):
    img = frame_ref[0].astype(jnp.float32)
    dark = dark_ref[...].astype(jnp.float32)
    img = jnp.maximum(img - dark, 0.0)                  # background subtract
    med = _median9(_shifts3x3(img))                     # 3x3 median filter
    n = _shifts3x3(med)
    lap = 8.0 * n[4] - (n[0] + n[1] + n[2] + n[3] + n[5] + n[6] + n[7] + n[8])
    mask = (lap > threshold) & (med > threshold * 0.5)
    mask_ref[0] = mask.astype(jnp.uint8)
    count_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))


def hedm_reduce(frames: jax.Array, dark: jax.Array, threshold: float = 100.0,
                interpret: bool = True):
    """frames: (F,H,W) uint16/f32 detector stack; dark: (H,W) background.
    Returns (mask (F,H,W) uint8, counts (F,) int32)."""
    F, H, W = frames.shape
    mask, counts = pl.pallas_call(
        functools.partial(_kernel, threshold=threshold),
        out_shape=(jax.ShapeDtypeStruct((F, H, W), jnp.uint8),
                   jax.ShapeDtypeStruct((F, 1), jnp.int32)),
        grid=(F,),
        in_specs=[pl.BlockSpec((1, H, W), lambda f: (f, 0, 0)),
                  pl.BlockSpec((H, W), lambda f: (0, 0))],
        out_specs=(pl.BlockSpec((1, H, W), lambda f: (f, 0, 0)),
                   pl.BlockSpec((1, 1), lambda f: (f, 0))),
        interpret=interpret,
    )(frames, dark)
    return mask, counts[:, 0]

"""Mixture-of-experts with capacity-bounded gather dispatch.

Design notes (TPU/SPMD adaptation):
  * Token-choice routing (top-k) with per-expert capacity
    C = ceil(S * top_k / E * capacity_factor). Tokens over capacity are
    dropped (residual passes through) — standard GShard/Switch semantics.
  * Dispatch is a GATHER, not the classic (B,S,E,C) one-hot einsum: at the
    assigned scale (S=4k..32k, E=128) the one-hot dispatch tensor is O(10^13)
    elements, and its einsum FLOPs would poison the roofline. Instead each
    expert top_k-selects (by sequence priority) the indices of tokens routed
    to it -> (B,E,C) index tensor; gather (B,E,C,D); batched expert matmuls
    einsum('becd,edf->becf') carry the *true* MoE FLOPs; combine is a
    scatter-add. Under SPMD (batch on 'data', experts on 'model') XLA lowers
    the gather/scatter to all-to-all-class traffic, mirroring expert-parallel
    dispatch.
  * Router math in fp32; load-balance aux loss returned for training.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.compat import shard_map
from repro.models.layers import Params, dense_init, init_mlp, mlp


INFERENCE_CAPACITY_FACTOR = 4.0   # relaxed at inference (drop ~never)


def expert_capacity(num_tokens: int, moe: MoEConfig,
                    factor: float = None) -> int:
    f = moe.capacity_factor if factor is None else factor
    cap = int(num_tokens * moe.top_k * f / moe.num_experts)
    return min(max(moe.top_k, cap), num_tokens)


def init_moe(key, cfg: ModelConfig) -> Params:
    moe = cfg.moe
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    E, F = moe.num_experts, moe.expert_d_ff
    p: Params = {
        "router": dense_init(k_r, d, E, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, F, dtype))(
            jax.random.split(k_g, E)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, F, dtype))(
            jax.random.split(k_u, E)),
        "w_down": jax.vmap(lambda k: dense_init(k, F, d, dtype))(
            jax.random.split(k_d, E)),
    }
    if moe.num_shared_experts:
        p["shared"] = init_mlp(k_s, d, moe.shared_d_ff, dtype)
    return p


def route(router_w: jax.Array, x: jax.Array, moe: MoEConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router: returns (weights (B,S,E) dense fp32, top-k ids, aux loss)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, moe.top_k)              # (B,S,K)
    if moe.norm_topk_prob:
        top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-9)
    # dense (B,S,E) combine-weight matrix (zero where not routed)
    onehot = jax.nn.one_hot(top_ids, moe.num_experts, dtype=jnp.float32)
    dense_w = jnp.einsum("bsk,bske->bse", top_w, onehot)
    # load-balance aux (Switch): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1)) / moe.top_k
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = moe.num_experts * jnp.sum(frac_tokens * frac_probs)
    return dense_w, top_ids, aux


def moe_ffn(params: Params, cfg: ModelConfig, x: jax.Array, ctx=None,
            inference: bool = False) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. x: (B,S,D) -> (out (B,S,D), aux_loss scalar).

    Training uses the configured capacity factor (tokens over capacity are
    dropped, GShard-style). Inference relaxes capacity (no training signal to
    balance against; dropping answers is not acceptable).

    With a mesh (ctx), dispatch runs through the explicit shard_map
    expert-parallel path (_moe_ffn_shardmap): GSPMD partitions the
    gather/scatter dispatch by REPLICATING the full token batch per device
    (measured: 45 s of collective per step on qwen3-moe train_4k); the
    hand-partitioned form needs one activation all-reduce per layer."""
    if (ctx is not None and ctx.tp_axis
            and cfg.moe.num_experts % ctx.tp_size == 0):
        return _moe_ffn_shardmap(params, cfg, x, ctx, inference)
    moe = cfg.moe
    B, S, D = x.shape
    E = moe.num_experts
    C = expert_capacity(S, moe,
                        INFERENCE_CAPACITY_FACTOR if inference else None)
    dense_w, _, aux = route(params["router"], x, moe)             # (B,S,E)

    # --- expert-side selection of routed tokens (sequence priority) ------
    assigned = dense_w > 0.0                                      # (B,S,E)
    score = jnp.where(assigned.transpose(0, 2, 1),                # (B,E,S)
                      -jnp.arange(S, dtype=jnp.float32)[None, None, :],
                      -jnp.inf)
    top_score, token_idx = jax.lax.top_k(score, C)                # (B,E,C)
    valid = jnp.isfinite(top_score)                               # (B,E,C)

    # --- dispatch: gather tokens into (B,E,C,D) ---------------------------
    b_idx = jnp.arange(B)[:, None, None]
    xin = x[b_idx, token_idx]                                     # (B,E,C,D)
    w_in = dense_w[b_idx, token_idx, jnp.arange(E)[None, :, None]]  # (B,E,C)
    xin = jnp.where(valid[..., None], xin, 0.0)
    if ctx is not None and ctx.tp_axis and E % ctx.tp_size == 0:
        # expert-parallel dispatch: XLA lowers the resharding from batch-
        # sharded tokens to expert-sharded buffers as all-to-all traffic
        xin = ctx.constrain(xin, ctx.dp_axes, ctx.tp_axis, None, None)

    # --- expert compute (true MoE FLOPs) ----------------------------------
    gate = jnp.einsum("becd,edf->becf", xin, params["w_gate"])
    up = jnp.einsum("becd,edf->becf", xin, params["w_up"])
    hidden = jax.nn.silu(gate) * up
    y = jnp.einsum("becf,efd->becd", hidden, params["w_down"])
    y = y * jnp.where(valid, w_in, 0.0).astype(y.dtype)[..., None]

    # --- combine: scatter-add back to (B,S,D) ------------------------------
    out = jnp.zeros((B, S, D), y.dtype)
    out = out.at[b_idx, token_idx].add(y, mode="drop")

    if moe.num_shared_experts:
        out = out + mlp(params["shared"], x)
    return out, aux


# ---------------------------------------------------------------------------
# explicit expert-parallel dispatch (shard_map)
# ---------------------------------------------------------------------------

def _moe_ffn_shardmap(params: Params, cfg: ModelConfig, x: jax.Array, ctx,
                      inference: bool) -> Tuple[jax.Array, jax.Array]:
    """Hand-partitioned MoE: each device runs its LOCAL experts over its
    LOCAL batch rows (token hidden states are tp-replicated between blocks
    anyway), then one psum over tp combines expert contributions. Routing
    semantics are identical to the auto path: router top-k over the FULL
    expert set; per-(row, expert) capacity with sequence priority."""
    moe = cfg.moe
    B, S, D = x.shape
    E = moe.num_experts
    C = expert_capacity(S, moe,
                        INFERENCE_CAPACITY_FACTOR if inference else None)
    mesh = ctx.mesh
    tp = ctx.tp_axis
    n_tp = ctx.tp_size
    E_loc = E // n_tp
    all_axes = tuple(mesh.axis_names)

    fsdp = ctx.fsdp_axis

    def body(xl, router, wg, wu, wd):
        if fsdp is not None:
            # explicit ZeRO-3: weights arrive D-sharded over the data axis,
            # gathered here (param-sized traffic); grads reduce-scatter back
            # through the transpose of the all-gather.
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        dense_w, _, aux = route(router, xl, moe)          # (B_l,S,E) global E
        e0 = jax.lax.axis_index(tp) * E_loc
        dw = jax.lax.dynamic_slice_in_dim(dense_w, e0, E_loc, axis=2)
        assigned = dw > 0.0
        score = jnp.where(assigned.transpose(0, 2, 1),     # (B_l,E_l,S)
                          -jnp.arange(S, dtype=jnp.float32)[None, None, :],
                          -jnp.inf)
        top_score, token_idx = jax.lax.top_k(score, C)     # (B_l,E_l,C)
        valid = jnp.isfinite(top_score)
        xin = jax.vmap(lambda xb, ib: xb[ib])(xl, token_idx)  # local gather
        w_in = jnp.take_along_axis(dw.transpose(0, 2, 1), token_idx, axis=2)
        xin = jnp.where(valid[..., None], xin, 0.0)
        gate = jnp.einsum("becd,edf->becf", xin, wg)
        up = jnp.einsum("becd,edf->becf", xin, wu)
        y = jnp.einsum("becf,efd->becd", jax.nn.silu(gate) * up, wd)
        y = y * jnp.where(valid, w_in, 0.0).astype(y.dtype)[..., None]
        b_idx = jnp.arange(xl.shape[0])[:, None, None]
        out = jnp.zeros(xl.shape, y.dtype).at[b_idx, token_idx].add(
            y, mode="drop")
        out = jax.lax.psum(out, tp)                        # combine experts
        aux = jax.lax.pmean(aux, all_axes)
        return out, aux

    dp_spec = P(ctx.dp_axes, None, None)
    w_in_specs = ((P(tp, ctx.fsdp_axis, None), P(tp, ctx.fsdp_axis, None),
                   P(tp, None, ctx.fsdp_axis)) if ctx.fsdp_axis is not None
                  else (P(tp, None, None),) * 3)
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(dp_spec, P()) + w_in_specs,
        out_specs=(dp_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    if moe.num_shared_experts:
        out = out + mlp(params["shared"], x)
    return out.astype(x.dtype), aux

"""Top-level model: embeddings + stack + head; train/prefill/decode entry
points; modality frontend stubs; analytic parameter counts.

Inputs are dicts (see ``input_specs`` in repro.launch.dryrun):
  LM:      {"tokens": (B,S) i32, "labels": (B,S) i32}
  [vlm]:   + {"image_embeds": (B, P, feat) } — precomputed patch embeddings
  [audio]: {"features": (B,S,feat), "labels": (B,S)} — precomputed frames
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.distributed.sharding import fsdp_gather
from repro.models import transformer as tf
from repro.models.layers import (Params, dense_init, embed, init_embedding,
                                 init_rmsnorm, rmsnorm)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k_e, k_s, k_h, k_f = jax.random.split(key, 4)
    v_pad = padded_vocab(cfg.vocab)
    p: Params = {
        "embed": init_embedding(k_e, v_pad, cfg.d_model, dtype),
        "stack": tf.init_stack(k_s, cfg),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k_h, cfg.d_model, v_pad, dtype)
    fe = cfg.frontend
    if fe.kind == "vision_patches":
        k1, k2 = jax.random.split(k_f)
        p["frontend"] = {
            "norm": init_rmsnorm(fe.feature_dim, dtype),
            "fc1": dense_init(k1, fe.feature_dim, cfg.d_model, dtype),
            "fc2": dense_init(k2, cfg.d_model, cfg.d_model, dtype),
        }
    elif fe.kind == "audio_frames":
        p["frontend"] = {
            "proj": dense_init(k_f, fe.feature_dim, cfg.d_model, dtype),
            "norm": init_rmsnorm(cfg.d_model, dtype),
        }
    return p


# ---------------------------------------------------------------------------
# frontend stubs
# ---------------------------------------------------------------------------

def apply_frontend(params: Params, cfg: ModelConfig,
                   inputs: Dict[str, jax.Array]) -> jax.Array:
    """Produce the (B,S,D) input sequence from the modality inputs."""
    fe = cfg.frontend
    if fe.kind == "vision_patches":
        img = inputs["image_embeds"]                        # (B,P,feat)
        f = params["frontend"]
        h = rmsnorm(f["norm"], img, cfg.norm_eps)
        h = jnp.einsum("bpf,fd->bpd", h, f["fc1"])
        h = jnp.einsum("bpd,de->bpe", jax.nn.gelu(h), f["fc2"])
        txt = embed(params["embed"], inputs["tokens"])      # (B,S_text,D)
        return jnp.concatenate([h.astype(txt.dtype), txt], axis=1)
    if fe.kind == "audio_frames":
        f = params["frontend"]
        h = jnp.einsum("bsf,fd->bsd", inputs["features"], f["proj"])
        return rmsnorm(f["norm"], h, cfg.norm_eps)
    return embed(params["embed"], inputs["tokens"])


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            remat: bool = False, kernel_fn=None, ctx=None,
            inference: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Hidden states after final norm: (B,S,D), plus aux loss."""
    x = apply_frontend(params, cfg, inputs).astype(jnp.dtype(cfg.compute_dtype))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux = tf.stack_forward(params["stack"], cfg, x, positions, remat=remat,
                              kernel_fn=kernel_fn, ctx=ctx,
                              inference=inference)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def head_table(params: Params, cfg: ModelConfig) -> jax.Array:
    """(V, D) unembedding table."""
    if cfg.tie_embeddings:
        return params["embed"]["table"]
    return params["head"].T


def chunked_cross_entropy(x: jax.Array, table: jax.Array, labels: jax.Array,
                          vocab: int, chunk: int = 512) -> jax.Array:
    """Mean next-token CE without materializing (B,S,V) logits.

    x: (B,S,D) hidden; table: (V_padded,D); labels: (B,S) with -100 = ignore.
    Scans over sequence chunks; per-chunk logits are (B,chunk,V). The body is
    rematerialized (jax.checkpoint) so backward recomputes per-chunk logits
    instead of saving all of them. Pad-vocab logits are masked to -inf.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    if S % chunk:                                           # pad to multiple
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
        S = S + pad
    nc = S // chunk
    xc = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    v_pad = table.shape[0]

    @jax.checkpoint
    def body(carry, inp):
        tot, cnt = carry
        xb, lb = inp
        logits = jnp.einsum("bsd,vd->bsv", xb.astype(jnp.float32),
                            table.astype(jnp.float32))
        if v_pad > vocab:
            pad_mask = jnp.arange(v_pad) < vocab
            logits = jnp.where(pad_mask, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        mask = (lb >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((logz - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params: Params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            remat: bool = True, aux_weight: float = 0.01,
            kernel_fn=None, ctx=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Training loss (next-token CE, or frame CE for encoders)."""
    x, aux = forward(params, cfg, inputs, remat=remat, kernel_fn=kernel_fn,
                     ctx=ctx)
    labels = inputs["labels"]
    if cfg.causal:
        if cfg.frontend.kind == "vision_patches":
            # labels cover text positions only; prefix positions are ignored
            P = cfg.frontend.num_prefix_tokens
            ignore = jnp.full(labels.shape[:1] + (P,), -100, labels.dtype)
            labels = jnp.concatenate([ignore, labels], axis=1)
        # next-token shift: predict labels[t] from hidden[t-1]
        x = x[:, :-1]
        labels = labels[:, 1:]
    table = head_table(params, cfg)
    if ctx is not None:
        table = fsdp_gather({"head": table.T}, cfg, ctx)["head"].T
    ce = chunked_cross_entropy(x, table, labels, cfg.vocab)
    total = ce + aux_weight * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, capacity: int):
    return tf.init_caches(cfg, batch, capacity)


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                caches) -> Tuple[jax.Array, Any]:
    """One decode step: tokens (B,1) -> (logits (B,V) fp32, new caches)."""
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))
    x, caches = tf.stack_decode(params["stack"], caches, cfg, x)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = head_table(params, cfg)
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        table.astype(jnp.float32))[:, 0]
    if table.shape[0] > cfg.vocab:
        logits = jnp.where(jnp.arange(table.shape[0]) < cfg.vocab, logits,
                           -1e30)
    return logits, caches


# ---------------------------------------------------------------------------
# analytic parameter counts (for MODEL_FLOPS roofline term)
# ---------------------------------------------------------------------------

def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    d, V = cfg.d_model, cfg.vocab
    hd = cfg.resolved_head_dim
    total = V * d * (1 if cfg.tie_embeddings else 2)        # embed + head

    def attn_params():
        if cfg.attention == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (d * cfg.n_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * cfg.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + cfg.n_heads * m.v_head_dim * d)
        return d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)

    def mlp_params(ff):
        return 3 * d * ff

    def mamba_params():
        s = cfg.ssm
        d_in = s.expand * d
        H = d_in // s.head_dim
        conv_ch = d_in + 2 * s.n_groups * s.d_state
        return (d * (2 * d_in + 2 * s.n_groups * s.d_state + H)
                + s.d_conv * conv_ch + d_in * d)

    def rwkv_params():
        c = cfg.rwkv
        return (5 * d * d                 # r,k,v,g,o projections
                + d * c.mix_lora * 5 * 2  # mixing adapters (approx)
                + d * c.decay_lora * 2
                + 2 * d * cfg.d_ff + d * d)  # channel mix

    if cfg.block_pattern == "zamba_hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        total += cfg.n_layers * mamba_params()
        total += attn_params() + mlp_params(cfg.d_ff)       # shared block
        total += n_sites * 2 * (d * tf.ZAMBA_LORA_RANK
                                + tf.ZAMBA_LORA_RANK * cfg.n_heads * hd)
        return total
    if cfg.block_kind == "mamba2":
        return total + cfg.n_layers * mamba_params()
    if cfg.block_kind == "rwkv6":
        return total + cfg.n_layers * rwkv_params()
    # attention archs
    per_layer = attn_params()
    if cfg.moe is not None:
        m = cfg.moe
        n_moe = cfg.n_layers - m.first_k_dense
        total += m.first_k_dense * (per_layer + mlp_params(m.dense_d_ff))
        router = d * m.num_experts
        if active_only:
            expert = 3 * d * m.expert_d_ff * m.top_k
        else:
            expert = 3 * d * m.expert_d_ff * m.num_experts
        shared = 3 * d * m.shared_d_ff if m.num_shared_experts else 0
        total += n_moe * (per_layer + router + expert + shared)
        return total
    return total + cfg.n_layers * (per_layer + mlp_params(cfg.d_ff))

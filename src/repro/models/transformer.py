"""Block definitions and layer stacks.

Stacks are scan-over-layers (stacked params, lax.scan) for compile-time
sanity at 512 AOT devices. Two patterns:

  * ``uniform``      — one homogeneous scanned stack (plus optional unrolled
                       ``first_k_dense`` prefix for deepseek-style MoE).
  * ``zamba_hybrid`` — outer scan over groups of ``attn_every`` Mamba2 blocks,
                       each group followed by the SHARED attention block
                       (weights shared across sites, per-site LoRA deltas);
                       remainder layers form a tail scan.

Decode mirrors the same structure with stacked per-layer caches/states.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import fsdp_gather
from repro.models import attention as attn_mod
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rw
from repro.models.attention import KVCache
from repro.models.layers import (Params, dense_init, init_mlp, init_rmsnorm,
                                 mlp, rmsnorm)

ZAMBA_LORA_RANK = 64


# ---------------------------------------------------------------------------
# single blocks
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, use_moe: bool) -> Params:
    """One transformer block (attn/mamba/rwkv + ffn/moe)."""
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.block_kind == "mamba2":
        return {"norm": init_rmsnorm(cfg.d_model, dtype),
                "mixer": m2.init_mamba2(k1, cfg)}
    if cfg.block_kind == "rwkv6":
        return {"norm1": init_rmsnorm(cfg.d_model, dtype),
                "norm2": init_rmsnorm(cfg.d_model, dtype),
                "mixer": rw.init_rwkv6(k1, cfg)}
    p: Params = {
        "norm1": init_rmsnorm(cfg.d_model, dtype),
        "norm2": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(k1, cfg),
    }
    if use_moe:
        p["moe"] = moe_mod.init_moe(k2, cfg)
    else:
        d_ff = cfg.moe.dense_d_ff if (cfg.moe and cfg.moe.first_k_dense) else cfg.d_ff
        p["mlp"] = init_mlp(k3, cfg.d_model, d_ff, dtype)
    return p


def block_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, kernel_fn=None, ctx=None,
                  inference: bool = False) -> Tuple[jax.Array, jax.Array]:
    """(x, aux_loss). Full-sequence forward for one block."""
    aux = jnp.zeros((), jnp.float32)
    params = fsdp_gather(params, cfg, ctx)      # explicit ZeRO-3 prefetch
    if ctx is not None and ctx.tp_axis:
        if ctx.sequence_parallel:
            # SP: residual stream sharded (dp, tp) between blocks; XLA forms
            # the Megatron-SP all-gather/reduce-scatter pairs around tp ops
            x = ctx.constrain(x, ctx.dp_axes, ctx.tp_axis, None)
        else:
            # pin the residual replicated over tp: prevents the partitioner
            # from inventing a seq-sharded scan carry that reshards at every
            # head-sharded op (baseline Megatron-TP semantics)
            x = ctx.constrain(x, ctx.dp_axes, None, None)
    if cfg.block_kind == "mamba2":
        x = x + m2.mamba2_block(params["mixer"], cfg,
                                rmsnorm(params["norm"], x, cfg.norm_eps),
                                ctx=ctx)
        return x, aux
    if cfg.block_kind == "rwkv6":
        B, _, D = x.shape
        zeros = jnp.zeros((B, D), x.dtype)
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        tm, _, _ = rw.rwkv6_time_mix(params["mixer"], cfg, h, zeros, ctx=ctx)
        x = x + tm
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        cm, _ = rw.rwkv6_channel_mix(params["mixer"], h, zeros)
        return x + cm, aux
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    x = x + attn_mod.attention(params["attn"], cfg, h, positions,
                               kernel_fn=kernel_fn, ctx=ctx)
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if "moe" in params:
        out, aux = moe_mod.moe_ffn(params["moe"], cfg, h, ctx=ctx,
                                   inference=inference)
        x = x + out
    else:
        x = x + mlp(params["mlp"], h)
    return x, aux


def block_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 cache: Any) -> Tuple[jax.Array, Any]:
    """One-token decode for one block. cache: KVCache | SSMState | RWKVState."""
    if cfg.block_kind == "mamba2":
        h = rmsnorm(params["norm"], x, cfg.norm_eps)
        out, cache = m2.mamba2_decode(params["mixer"], cfg, h, cache)
        return x + out, cache
    if cfg.block_kind == "rwkv6":
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        tm, s_final, x_last = rw.rwkv6_time_mix(
            params["mixer"], cfg, h, cache.x_tm, s0=cache.s, use_chunked=False)
        x = x + tm
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        cm, cm_last = rw.rwkv6_channel_mix(params["mixer"], h, cache.x_cm)
        cache = rw.RWKVState(s_final, x_last, cm_last, cache.length + 1)
        return x + cm, cache
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    out, cache = attn_mod.decode_attention(params["attn"], cfg, h, cache)
    x = x + out
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if "moe" in params:
        out, _ = moe_mod.moe_ffn(params["moe"], cfg, h, inference=True)
        x = x + out
    else:
        x = x + mlp(params["mlp"], h)
    return x, cache


# ---------------------------------------------------------------------------
# zamba shared attention block (+ per-site LoRA)
# ---------------------------------------------------------------------------

def init_shared_attn(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_rmsnorm(cfg.d_model, dtype),
        "norm2": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn_mod.init_attention(k1, cfg),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_site_lora(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "a_q": dense_init(ks[0], d, ZAMBA_LORA_RANK, dtype),
        "b_q": jnp.zeros((ZAMBA_LORA_RANK, cfg.n_heads * hd), dtype),
        "a_k": dense_init(ks[1], d, ZAMBA_LORA_RANK, dtype),
        "b_k": jnp.zeros((ZAMBA_LORA_RANK, cfg.n_kv_heads * hd), dtype),
    }


def _lora_adjusted_attn_params(shared: Params, lora: Params) -> Params:
    """Per-site effective attention params: wq + a_q@b_q (low-rank delta)."""
    p = dict(shared)
    p["wq"] = shared["wq"] + lora["a_q"] @ lora["b_q"]
    p["wk"] = shared["wk"] + lora["a_k"] @ lora["b_k"]
    return p


def shared_attn_forward(shared: Params, lora: Params, cfg: ModelConfig,
                        x: jax.Array, positions: jax.Array,
                        ctx=None) -> jax.Array:
    ap = _lora_adjusted_attn_params(shared["attn"], lora)
    h = rmsnorm(shared["norm1"], x, cfg.norm_eps)
    x = x + attn_mod.attention(ap, cfg, h, positions, ctx=ctx)
    h = rmsnorm(shared["norm2"], x, cfg.norm_eps)
    return x + mlp(shared["mlp"], h)


def shared_attn_decode(shared: Params, lora: Params, cfg: ModelConfig,
                       x: jax.Array, cache: KVCache
                       ) -> Tuple[jax.Array, KVCache]:
    ap = _lora_adjusted_attn_params(shared["attn"], lora)
    h = rmsnorm(shared["norm1"], x, cfg.norm_eps)
    out, cache = attn_mod.decode_attention(ap, cfg, h, cache)
    x = x + out
    h = rmsnorm(shared["norm2"], x, cfg.norm_eps)
    return x + mlp(shared["mlp"], h), cache


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


def init_stack(key, cfg: ModelConfig) -> Params:
    """All block parameters for the configured pattern."""
    if cfg.block_pattern == "zamba_hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        n_tail = cfg.n_layers - n_sites * cfg.attn_every
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "groups": _stack_init(
                k1, n_sites * cfg.attn_every,
                lambda k: init_block(k, cfg, use_moe=False)),
            "shared_attn": init_shared_attn(k2, cfg),
            "loras": _stack_init(k3, n_sites,
                                 lambda k: init_site_lora(k, cfg)),
        }
        if n_tail:
            p["tail"] = _stack_init(
                k4, n_tail, lambda k: init_block(k, cfg, use_moe=False))
        return p
    # uniform
    moe_on = cfg.moe is not None
    k_pre, k_main = jax.random.split(key)
    p = {}
    n_dense = cfg.moe.first_k_dense if moe_on else 0
    if n_dense:
        p["prefix"] = _stack_init(
            k_pre, n_dense, lambda k: init_block(k, cfg, use_moe=False))
    p["layers"] = _stack_init(
        k_main, cfg.n_layers - n_dense,
        lambda k: init_block(k, cfg, use_moe=moe_on))
    return p


def _scan_blocks(stacked: Params, cfg: ModelConfig, x, positions, remat: bool,
                 kernel_fn=None, ctx=None, inference: bool = False):
    """lax.scan over a stacked block group, sqrt-remat when deep.

    With L layers, a flat remat scan saves L carries; nesting the scan as
    (L/g groups) x (g layers) with checkpoint at BOTH levels saves L/g outer
    carries plus one group's g inner carries during backward — O(sqrt(L))
    live residuals (Chen et al. sqrt-remat), which is what lets an 80-layer
    72B train step fit 16 GB HBM.
    """
    def body(carry, layer_params):
        h, aux = carry
        h, a = block_forward(layer_params, cfg, h, positions, kernel_fn, ctx,
                             inference)
        return (h, aux + a), None

    n = jax.tree.leaves(stacked)[0].shape[0]
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
        # pick a group size ~ sqrt(n) that divides n
        g = max(1, int(n ** 0.5))
        while n % g:
            g -= 1
        if g > 1 and n // g > 1:
            groups = jax.tree.map(
                lambda a: a.reshape((n // g, g) + a.shape[1:]), stacked)

            @functools.partial(jax.checkpoint, prevent_cse=False)
            def group_body(carry, group_params):
                out, _ = jax.lax.scan(body, carry, group_params)
                return out, None

            (x, aux), _ = jax.lax.scan(
                group_body, (x, jnp.zeros((), jnp.float32)), groups)
            return x, aux
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


def stack_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, remat: bool = False,
                  kernel_fn=None, ctx=None,
                  inference: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward through all layers. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.block_pattern == "zamba_hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        ge = cfg.attn_every
        # reshape group params to (n_sites, ge, ...)
        groups = jax.tree.map(
            lambda a: a.reshape((n_sites, ge) + a.shape[1:]), params["groups"])

        def group_body(carry, inp):
            h, aux = carry
            g_params, lora = inp
            h, a = _scan_blocks(g_params, cfg, h, positions, remat, ctx=ctx)
            h = shared_attn_forward(params["shared_attn"], lora, cfg, h,
                                    positions, ctx=ctx)
            return (h, aux + a), None
        gb = jax.checkpoint(group_body, prevent_cse=False) if remat else group_body
        (x, aux), _ = jax.lax.scan(gb, (x, aux), (groups, params["loras"]))
        if "tail" in params:
            x, a = _scan_blocks(params["tail"], cfg, x, positions, remat,
                                ctx=ctx)
            aux = aux + a
        return x, aux
    if "prefix" in params:
        x, a = _scan_blocks(params["prefix"], cfg, x, positions, remat,
                            kernel_fn, ctx, inference)
        aux = aux + a
    x, a = _scan_blocks(params["layers"], cfg, x, positions, remat, kernel_fn,
                        ctx, inference)
    return x, aux + a


# ---------------------------------------------------------------------------
# decode stacks (stacked caches)
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, capacity: int) -> Any:
    """Stacked per-layer decode caches matching the stack structure."""
    if cfg.block_pattern == "zamba_hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        n_tail = cfg.n_layers - n_sites * cfg.attn_every
        mk_ssm = lambda n: jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape),
            m2.init_ssm_state(cfg, batch))
        caches = {
            "groups": mk_ssm(n_sites * cfg.attn_every),
            "shared_kv": jax.tree.map(
                lambda l: jnp.broadcast_to(l, (n_sites,) + l.shape),
                attn_mod.init_kv_cache(cfg, batch, capacity)),
        }
        if n_tail:
            caches["tail"] = mk_ssm(n_tail)
        return caches
    n_dense = cfg.moe.first_k_dense if cfg.moe else 0
    n_main = cfg.n_layers - n_dense
    if cfg.block_kind == "mamba2":
        one = m2.init_ssm_state(cfg, batch)
    elif cfg.block_kind == "rwkv6":
        one = rw.init_rwkv_state(cfg, batch)
    else:
        one = attn_mod.init_kv_cache(cfg, batch, capacity)
    stack = lambda n: jax.tree.map(
        lambda l: jnp.broadcast_to(l, (n,) + l.shape), one)
    out = {"layers": stack(n_main)}
    if n_dense:
        out["prefix"] = stack(n_dense)
    return out


def _scan_decode(stacked_p: Params, stacked_c, cfg: ModelConfig, x):
    def body(h, inp):
        lp, lc = inp
        h, new_c = block_decode(lp, cfg, h, lc)
        return h, new_c
    return jax.lax.scan(body, x, (stacked_p, stacked_c))


def stack_decode(params: Params, caches, cfg: ModelConfig, x: jax.Array
                 ) -> Tuple[jax.Array, Any]:
    """One-token decode through all layers. Returns (x, new caches)."""
    if cfg.block_pattern == "zamba_hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        ge = cfg.attn_every
        groups_p = jax.tree.map(
            lambda a: a.reshape((n_sites, ge) + a.shape[1:]), params["groups"])
        groups_c = jax.tree.map(
            lambda a: a.reshape((n_sites, ge) + a.shape[1:]), caches["groups"])

        def site_body(h, inp):
            gp, gc, lora, kv = inp
            h, new_gc = _scan_decode(gp, gc, cfg, h)
            h, new_kv = shared_attn_decode(params["shared_attn"], lora, cfg,
                                           h, kv)
            return h, (new_gc, new_kv)
        x, (new_gc, new_kv) = jax.lax.scan(
            site_body, x, (groups_p, groups_c, params["loras"],
                           caches["shared_kv"]))
        new_caches = {
            "groups": jax.tree.map(
                lambda a: a.reshape((n_sites * ge,) + a.shape[2:]), new_gc),
            "shared_kv": new_kv,
        }
        if "tail" in params:
            x, new_tail = _scan_decode(params["tail"], caches["tail"], cfg, x)
            new_caches["tail"] = new_tail
        return x, new_caches
    new_caches = {}
    if "prefix" in params:
        x, nc = _scan_decode(params["prefix"], caches["prefix"], cfg, x)
        new_caches["prefix"] = nc
    x, nc = _scan_decode(params["layers"], caches["layers"], cfg, x)
    new_caches["layers"] = nc
    return x, new_caches


# ---------------------------------------------------------------------------
# prefill stacks (forward + populate decode caches)
# ---------------------------------------------------------------------------

def block_prefill(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, capacity: int, ctx=None
                  ) -> Tuple[jax.Array, Any]:
    """Forward one block and return its decode cache."""
    params = fsdp_gather(params, cfg, ctx)      # explicit ZeRO-3 prefetch
    if cfg.block_kind == "mamba2":
        h = rmsnorm(params["norm"], x, cfg.norm_eps)
        out, state = m2.mamba2_prefill(params["mixer"], cfg, h, ctx=ctx)
        return x + out, state
    if cfg.block_kind == "rwkv6":
        B, _, D = x.shape
        zeros = jnp.zeros((B, D), x.dtype)
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        tm, s_final, x_tm = rw.rwkv6_time_mix(params["mixer"], cfg, h, zeros,
                                              ctx=ctx)
        x = x + tm
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        cm, x_cm = rw.rwkv6_channel_mix(params["mixer"], h, zeros)
        state = rw.RWKVState(s_final, x_tm, x_cm,
                             jnp.full((B,), x.shape[1], jnp.int32))
        return x + cm, state
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    out, kv = attn_mod.attention_prefill(params["attn"], cfg, h, positions,
                                         capacity, ctx=ctx)
    x = x + out
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if "moe" in params:
        o, _ = moe_mod.moe_ffn(params["moe"], cfg, h, ctx=ctx, inference=True)
        x = x + o
    else:
        x = x + mlp(params["mlp"], h)
    return x, kv


def _scan_prefill(stacked_p: Params, cfg: ModelConfig, x, positions,
                  capacity: int, ctx=None):
    def body(h, lp):
        h, cache = block_prefill(lp, cfg, h, positions, capacity, ctx)
        return h, cache
    return jax.lax.scan(body, x, stacked_p)


def stack_prefill(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, capacity: int, ctx=None
                  ) -> Tuple[jax.Array, Any]:
    """Forward all layers, returning stacked decode caches (same structure
    as init_caches)."""
    if cfg.block_pattern == "zamba_hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        ge = cfg.attn_every
        groups_p = jax.tree.map(
            lambda a: a.reshape((n_sites, ge) + a.shape[1:]), params["groups"])

        def site_body(h, inp):
            gp, lora = inp
            h, gc = _scan_prefill(gp, cfg, h, positions, capacity, ctx)
            ap = _lora_adjusted_attn_params(params["shared_attn"]["attn"], lora)
            hh = rmsnorm(params["shared_attn"]["norm1"], h, cfg.norm_eps)
            out, kv = attn_mod.attention_prefill(ap, cfg, hh, positions,
                                                 capacity, ctx=ctx)
            h = h + out
            hh = rmsnorm(params["shared_attn"]["norm2"], h, cfg.norm_eps)
            h = h + mlp(params["shared_attn"]["mlp"], hh)
            return h, (gc, kv)
        x, (gc, kv) = jax.lax.scan(site_body, x,
                                   (groups_p, params["loras"]))
        caches = {
            "groups": jax.tree.map(
                lambda a: a.reshape((n_sites * ge,) + a.shape[2:]), gc),
            "shared_kv": kv,
        }
        if "tail" in params:
            x, tc = _scan_prefill(params["tail"], cfg, x, positions,
                                  capacity, ctx)
            caches["tail"] = tc
        return x, caches
    caches = {}
    if "prefix" in params:
        x, pc = _scan_prefill(params["prefix"], cfg, x, positions, capacity,
                              ctx)
        caches["prefix"] = pc
    x, lc = _scan_prefill(params["layers"], cfg, x, positions, capacity, ctx)
    caches["layers"] = lc
    return x, caches

"""Attention: GQA (qk-norm, qkv-bias, sliding-window, bidirectional) and MLA.

Two execution paths:
  * XLA reference path (this file): grouped einsum formulation, used for the
    512-device AOT dry-run and CPU smoke tests. Grouped (repeat-free) einsums
    keep HLO FLOPs honest for GQA.
  * Pallas flash kernels (repro.kernels.flash_attention): the TPU deployment
    path, validated in interpret mode against this reference.

Decode uses a fixed-capacity KV cache written with dynamic_update_slice;
MLA decode uses the absorbed-matrix form so the cache holds only the latent
(c_kv, k_rope) — the technique's entire point.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, apply_rope, dense_init, rmsnorm_nohead

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Fixed-capacity decode cache. For MLA, k holds c_kv and v holds k_rope.

    `length` is PER-SLOT (B,) so continuous batching can mix requests at
    different positions in one decode batch."""
    k: jax.Array          # (B, cap, n_kv, head_dim)   | MLA: (B, cap, kv_lora)
    v: jax.Array          # (B, cap, n_kv, v_dim)      | MLA: (B, cap, rope_dim)
    length: jax.Array     # (B,) int32 — tokens currently in each slot


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    if cfg.attention == "mla":
        return _init_mla(key, cfg, dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, d, nh * hd, dtype),
        "wk": dense_init(k2, d, nkv * hd, dtype),
        "wv": dense_init(k3, d, nkv * hd, dtype),
        "wo": dense_init(k4, nh * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _init_mla(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.mla
    d, nh = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "wq": dense_init(k1, d, nh * qk_dim, dtype),
        # joint down-projection: latent kv + shared rope key
        "w_dkv": dense_init(k2, d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_uk": dense_init(k3, m.kv_lora_rank, nh * m.qk_nope_head_dim, dtype),
        "w_uv": dense_init(k4, m.kv_lora_rank, nh * m.v_head_dim, dtype),
        "wo": dense_init(k5, nh * m.v_head_dim, d, dtype),
    }


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def attention_bias(q_len: int, kv_len: int, *, causal: bool,
                   window: int, q_offset: Any = 0) -> jax.Array:
    """(q_len, kv_len) additive bias in fp32. q_offset: absolute position of
    query 0 (scalar or traced int) — used for decode and blocked prefill."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    ok = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# grouped scaled-dot-product attention (GQA, repeat-free)
# ---------------------------------------------------------------------------

def grouped_sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
                 bias: Optional[jax.Array], scale: float) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,KV,hd) with H = KV*G. Returns (B,S,H,hd).

    Grouped einsum avoids materializing repeated K/V heads, so compiled FLOPs
    reflect the true GQA cost.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    # f32-ACCUMULATING dot (not a post-cast): avoids operand converts that
    # XLA hoists out of scan loops as whole-stack f32 copies of the KV cache
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if bias is not None:
        scores = scores + bias          # bias broadcasts over (b,k,g)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# GQA forward (train / prefill / decode)
# ---------------------------------------------------------------------------

def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array, ctx=None):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm_nohead(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm_nohead(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if ctx is not None and ctx.tp_axis:
        if cfg.n_heads % ctx.tp_size == 0:
            q = ctx.constrain(q, ctx.dp_axes, None, ctx.tp_axis, None)
        if cfg.n_kv_heads % ctx.tp_size == 0:
            k = ctx.constrain(k, ctx.dp_axes, None, ctx.tp_axis, None)
            v = ctx.constrain(v, ctx.dp_axes, None, ctx.tp_axis, None)
    return q, k, v


def attention(params: Params, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, *, window: Optional[int] = None,
              kernel_fn=None, ctx=None) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B,S,D)."""
    if cfg.attention == "mla":
        return mla_attention(params, cfg, x, positions, ctx=ctx)
    hd = cfg.resolved_head_dim
    win = cfg.sliding_window if window is None else window
    q, k, v = _project_qkv(params, cfg, x, positions, ctx)
    if (ctx is not None and ctx.tp_axis
            and cfg.n_kv_heads % ctx.tp_size != 0
            and cfg.n_heads % ctx.tp_size == 0):
        # GQA with kv_heads < tp: repeat KV to full heads so the attention
        # computation shards over q-heads (Megatron kv-replication).
        G = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        k = ctx.constrain(k, ctx.dp_axes, None, ctx.tp_axis, None)
        v = ctx.constrain(v, ctx.dp_axes, None, ctx.tp_axis, None)
    scale = hd ** -0.5
    if kernel_fn is not None:
        out = kernel_fn(q, k, v, causal=cfg.causal, window=win, scale=scale)
    elif x.shape[1] >= BLOCKED_THRESHOLD:
        out = blocked_grouped_sdpa(q, k, v, causal=cfg.causal, window=win,
                                   scale=scale)
    else:
        bias = attention_bias(x.shape[1], x.shape[1], causal=cfg.causal,
                              window=win)
        out = grouped_sdpa(q, k, v, bias, scale)
    B, S = x.shape[:2]
    return jnp.einsum("bse,ed->bsd", out.reshape(B, S, cfg.n_heads * hd),
                      params["wo"])


def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int) -> KVCache:
    dtype = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        m = cfg.mla
        return KVCache(
            k=jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
            v=jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
            length=jnp.zeros((batch,), jnp.int32),
        )
    cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
    return KVCache(
        k=jnp.zeros((batch, cap, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((batch, cap, cfg.n_kv_heads, hd), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def decode_attention(params: Params, cfg: ModelConfig, x: jax.Array,
                     cache: KVCache) -> Tuple[jax.Array, KVCache]:
    """One-token decode. x: (B,1,D). Returns (out (B,1,D), new cache).

    Sliding-window archs use a ring buffer of size `window`; full attention
    uses absolute slots. Cache k/v hold *post-rope* keys.
    """
    if cfg.attention == "mla":
        return mla_decode(params, cfg, x, cache)
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = cache.length                                   # (B,) int32
    positions = pos[:, None]                             # (B,1)
    q, k, v = _project_qkv(params, cfg, x, positions)
    cap = cache.k.shape[1]
    slot = pos % cap if cfg.sliding_window else pos      # (B,)
    b_idx = jnp.arange(B)
    new_k = cache.k.at[b_idx, slot].set(k[:, 0].astype(cache.k.dtype))
    new_v = cache.v.at[b_idx, slot].set(v[:, 0].astype(cache.v.dtype))
    # validity mask over cache slots, per batch row
    slots = jnp.arange(cap)[None, :]                     # (1, cap)
    if cfg.sliding_window:
        valid = slots < jnp.minimum(pos + 1, cap)[:, None]  # ring valid count
    else:
        valid = slots <= pos[:, None]
    # (B,cap) -> broadcast over (b, kv, g, q=1, t=cap)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    bias = bias[:, None, None, None, :]
    out = grouped_sdpa(q, new_k.astype(q.dtype), new_v.astype(q.dtype),
                       bias, hd ** -0.5)
    out = jnp.einsum("bse,ed->bsd", out.reshape(B, 1, cfg.n_heads * hd),
                     params["wo"])
    return out, KVCache(new_k, new_v, pos + 1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(params: Params, cfg: ModelConfig, x: jax.Array, positions):
    m = cfg.mla
    B, S, _ = x.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, cfg.n_heads, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(params: Params, cfg: ModelConfig, x: jax.Array, positions):
    """Down-project to (c_kv, k_rope); k_rope is shared across heads."""
    m = cfg.mla
    dkv = jnp.einsum("bsd,de->bse", x, params["w_dkv"])
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm_nohead(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, ctx=None) -> jax.Array:
    """Prefill/train MLA: decompress per-head keys/values (FLOP-favorable for
    long sequences vs absorbed form when S >> ranks)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,re->bse", c_kv, params["w_uk"]) \
        .reshape(B, S, H, m.qk_nope_head_dim)
    v = jnp.einsum("bsr,re->bse", c_kv, params["w_uv"]) \
        .reshape(B, S, H, m.v_head_dim)
    if ctx is not None and ctx.tp_axis and H % ctx.tp_size == 0:
        q_nope = ctx.constrain(q_nope, ctx.dp_axes, None, ctx.tp_axis, None)
        k_nope = ctx.constrain(k_nope, ctx.dp_axes, None, ctx.tp_axis, None)
        v = ctx.constrain(v, ctx.dp_axes, None, ctx.tp_axis, None)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if S >= BLOCKED_THRESHOLD:
        out = blocked_mla_core(q_nope, q_rope, k_nope, k_rope, v, scale)
    else:
        scores = (jnp.einsum("bshe,bthe->bhst", q_nope, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshe,bte->bhst", q_rope, k_rope,
                               preferred_element_type=jnp.float32)) * scale
        bias = attention_bias(S, S, causal=cfg.causal, window=0)
        probs = jax.nn.softmax(scores + bias, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhst,bthe->bshe", probs, v)
    out = out.reshape(B, S, H * m.v_head_dim)
    return jnp.einsum("bse,ed->bsd", out, params["wo"])


def mla_decode(params: Params, cfg: ModelConfig, x: jax.Array,
               cache: KVCache) -> Tuple[jax.Array, KVCache]:
    """Absorbed-form decode: cache holds only (c_kv, k_rope) — (r + rope_dim)
    per token instead of 2*H*hd. Score = (q_nope W_uk) c_kv + q_rope k_rope."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos = cache.length                                          # (B,)
    positions = pos[:, None]
    q_nope, q_rope = _mla_q(params, cfg, x, positions)          # (B,1,H,·)
    c_kv, k_rope = _mla_latent(params, cfg, x, positions)       # (B,1,r),(B,1,rope)
    b_idx = jnp.arange(B)
    new_c = cache.k.at[b_idx, pos].set(c_kv[:, 0].astype(cache.k.dtype))
    new_r = cache.v.at[b_idx, pos].set(k_rope[:, 0].astype(cache.v.dtype))
    cap = new_c.shape[1]
    # absorb W_uk into q:   q_abs (B,H,r)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bhe,rhe->bhr", q_nope[:, 0], w_uk)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bhr,btr->bht", q_abs, new_c.astype(q_abs.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhe,bte->bht", q_rope[:, 0],
                           new_r.astype(q_abs.dtype),
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(cap)[None, :] <= pos[:, None]            # (B,cap)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", probs.astype(new_c.dtype), new_c)  # latent ctx
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhe->bhe", ctx, w_uv).reshape(B, 1, H * m.v_head_dim)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"])
    return out, KVCache(new_c, new_r, pos + 1)


# ---------------------------------------------------------------------------
# prefill: full-sequence attention that also populates a decode cache
# ---------------------------------------------------------------------------

def attention_prefill(params: Params, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array, capacity: int, ctx=None
                      ) -> Tuple[jax.Array, KVCache]:
    """Like attention(), but returns the populated KV cache for decode.
    Handles full, sliding-window (ring layout), and MLA (latent) caches."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.compute_dtype)
    lengths = jnp.full((B,), S, jnp.int32)
    if cfg.attention == "mla":
        m = cfg.mla
        out = mla_attention(params, cfg, x, positions, ctx=ctx)
        c_kv, k_rope = _mla_latent(params, cfg, x, positions)
        ck = jnp.zeros((B, capacity, m.kv_lora_rank), dtype)
        kr = jnp.zeros((B, capacity, m.qk_rope_head_dim), dtype)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, c_kv.astype(dtype), 0, 1)
        kr = jax.lax.dynamic_update_slice_in_dim(kr, k_rope.astype(dtype), 0, 1)
        return out, KVCache(ck, kr, lengths)
    q, k, v = _project_qkv(params, cfg, x, positions, ctx)
    bias = attention_bias(S, S, causal=cfg.causal, window=cfg.sliding_window)
    o = grouped_sdpa(q, k, v, bias, hd ** -0.5)
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, cfg.n_heads * hd),
                     params["wo"])
    if cfg.sliding_window and cfg.sliding_window < max(S, capacity):
        cap = min(capacity, cfg.sliding_window)
        # ring layout: position p lives at slot p % cap
        n_keep = min(S, cap)
        keep = jnp.arange(S - n_keep, S)
        slots = keep % cap
        ck = jnp.zeros((B, cap) + k.shape[2:], dtype)
        cv = jnp.zeros((B, cap) + v.shape[2:], dtype)
        ck = ck.at[:, slots].set(k[:, keep].astype(dtype))
        cv = cv.at[:, slots].set(v[:, keep].astype(dtype))
        return out, KVCache(ck, cv, lengths)
    ck = jnp.zeros((B, capacity) + k.shape[2:], dtype)
    cv = jnp.zeros((B, capacity) + v.shape[2:], dtype)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(dtype), 0, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(dtype), 0, 1)
    return out, KVCache(ck, cv, lengths)


# ---------------------------------------------------------------------------
# blocked (query-chunked) attention — exact, bounded memory for long seqs
# ---------------------------------------------------------------------------

BLOCKED_THRESHOLD = 8192     # use blocked path when S >= this
Q_CHUNK = 1024


def blocked_grouped_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool, window: int, scale: float,
                         q_chunk: int = Q_CHUNK) -> jax.Array:
    """Exact attention computed one query-block at a time (scan), avoiding
    the (S,S) score materialization. For sliding-window attention only the
    (window + q_chunk)-wide key slab is touched per block — the FLOP saving
    of SWA is structural, not just a mask.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qc = min(q_chunk, S)
    while S % qc:
        qc -= 1
    nq = S // qc
    qg = jnp.moveaxis(q.reshape(B, nq, qc, KV, G, hd), 1, 0)  # (nq,B,qc,KV,G,hd)
    idxs = jnp.arange(nq)

    use_slab = window > 0 and (window + qc) < S
    slab = min(S, ((window + qc + 127) // 128) * 128) if use_slab else S

    def body(_, inp):
        q_blk, i = inp
        q0 = i * qc
        if use_slab:
            start = jnp.clip(q0 + qc - slab, 0, S - slab)
            k_blk = jax.lax.dynamic_slice_in_dim(k, start, slab, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, start, slab, axis=1)
            k_pos = start + jnp.arange(slab)
        else:
            k_blk, v_blk = k, v
            k_pos = jnp.arange(S)
        q_pos = q0 + jnp.arange(qc)
        ok = jnp.ones((qc, k_blk.shape[1]), bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            ok &= k_pos[None, :] > q_pos[:, None] - window
        bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
        scores = jnp.einsum("bskgh,btkh->bkgst", q_blk, k_blk,
                            preferred_element_type=jnp.float32) * scale
        probs = jax.nn.softmax(scores + bias, axis=-1).astype(v_blk.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v_blk)
        return None, out

    _, outs = jax.lax.scan(body, None, (qg, idxs))      # (nq,B,qc,KV,G,hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def blocked_mla_core(q_nope, q_rope, k_nope, k_rope, v, scale,
                     q_chunk: int = Q_CHUNK) -> jax.Array:
    """Blocked causal attention for MLA heads (separate nope/rope scores)."""
    B, S, H, _ = q_nope.shape
    qc = min(q_chunk, S)
    while S % qc:
        qc -= 1
    nq = S // qc
    qn = jnp.moveaxis(q_nope.reshape(B, nq, qc, H, -1), 1, 0)
    qr = jnp.moveaxis(q_rope.reshape(B, nq, qc, H, -1), 1, 0)
    idxs = jnp.arange(nq)
    k_pos = jnp.arange(S)

    def body(_, inp):
        qn_b, qr_b, i = inp
        q_pos = i * qc + jnp.arange(qc)
        bias = jnp.where(k_pos[None, :] <= q_pos[:, None], 0.0, NEG_INF
                         ).astype(jnp.float32)
        scores = (jnp.einsum("bshe,bthe->bhst", qn_b, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshe,bte->bhst", qr_b, k_rope,
                               preferred_element_type=jnp.float32)) * scale
        probs = jax.nn.softmax(scores + bias, axis=-1).astype(v.dtype)
        return None, jnp.einsum("bhst,bthe->bshe", probs, v)

    _, outs = jax.lax.scan(body, None, (qn, qr, idxs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, v.shape[-1])

"""Mamba2 (SSD — state-space duality) block, zamba2-style.

Projections are SPLIT (z / x / B / C / dt as separate matrices) so tensor
parallelism is expressible: z, x, dt shard over heads (tp), while B and C —
shared across heads within a group — replicate over tp. A fused in_proj
would interleave tp-sharded and replicated columns in one matrix, which a
single PartitionSpec cannot express.

Implementations:
  * ``ssd_chunked``: chunked algorithm (intra-chunk quadratic term computed
    per chunk inside the scan — bounded temps mirroring the Pallas kernel's
    VMEM tile; inter-chunk state recurrence in the scan carry).
  * ``ssd_naive``: step-by-step linear recurrence — the correctness oracle.
  * ``mamba2_decode``: O(1)-state single-token step (long_500k decode).

Shapes: x (B,L,H,P); B/C (B,L,G,N) with H = G*HG heads per group; state
h (B,G,HG,P,N). log-decay a_t = dt_t * A_h (A negative).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, init_rmsnorm, rmsnorm


class SSMState(NamedTuple):
    h: jax.Array          # (B, G, HG, P, N) ssm state
    conv_x: jax.Array     # (B, d_conv-1, d_inner) conv tail for x
    conv_B: jax.Array     # (B, d_conv-1, G*N)
    conv_C: jax.Array     # (B, d_conv-1, G*N)
    length: jax.Array     # (B,) int32


# ---------------------------------------------------------------------------
# core SSD
# ---------------------------------------------------------------------------

def ssd_naive(x, dt, A, Bm, Cm, h0=None):
    """Oracle: sequential recurrence. x (B,L,G,HG,P), dt (B,L,G,HG),
    A (G,HG), Bm/Cm (B,L,G,N). Returns (y, h_final)."""
    B, L, G, HG, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, G, HG, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # (B,G,HG,P),(B,G,HG),(B,G,N)x2
        da = jnp.exp(dtt * A)                     # (B,G,HG)
        dbx = jnp.einsum("bgh,bghp,bgn->bghpn", dtt, xt, bt)
        h = h * da[..., None, None] + dbx
        y = jnp.einsum("bghpn,bgn->bghp", h, ct)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def ssd_chunked(x, dt, A, Bm, Cm, h0=None, chunk: int = 128):
    """Chunked SSD. Same signature/semantics as ssd_naive."""
    B, L, G, HG, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, f"seq {L} not divisible by chunk {Q}"
    nc = L // Q
    f32 = jnp.float32

    cdt = x.dtype                                        # matmul dtype (bf16 prod)
    xc = jnp.moveaxis(x.reshape(B, nc, Q, G, HG, P), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(B, nc, Q, G, HG), 1, 0).astype(f32)
    Bc = jnp.moveaxis(Bm.reshape(B, nc, Q, G, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(B, nc, Q, G, N), 1, 0)
    mask = jnp.tril(jnp.ones((Q, Q), bool))

    if h0 is None:
        h0 = jnp.zeros((B, G, HG, P, N), f32)

    def step(h, inp):
        x_c, dt_c, B_c, C_c = inp                        # chunk slabs
        a = dt_c * A.astype(f32)                         # (B,Q,G,HG) log-decay
        cum = jnp.cumsum(a, axis=1)                      # inclusive
        # intra-chunk: L[q,k] = exp(cum_q - cum_k), k<=q (segsum, stable);
        # materialized one chunk at a time (mirrors kernel VMEM tile)
        diff = cum[:, :, None] - cum[:, None, :]         # (B,Q,Q,G,HG)
        Lmat = jnp.where(mask[None, :, :, None, None], jnp.exp(diff), 0.0)
        Gmat = jnp.einsum("bqgn,bkgn->bqkg", C_c, B_c)   # (B,Q,Q,G)
        M = (Gmat[..., None].astype(f32) * Lmat
             * dt_c[:, None]).astype(cdt)                # weight at key k
        y = jnp.einsum("bqkgh,bkghp->bqghp", M, x_c).astype(f32)
        # inter-chunk: carried state contribution
        y = y + jnp.einsum("bqgn,bghpn->bqghp", C_c.astype(f32), h) \
            * jnp.exp(cum)[..., None]
        # state update
        decay_to_end = jnp.exp(cum[:, -1:] - cum)        # (B,Q,G,HG)
        S = jnp.einsum("bqgn,bqgh,bqghp->bghpn", B_c.astype(f32),
                       dt_c * decay_to_end, x_c.astype(f32))
        h = h * jnp.exp(cum[:, -1])[..., None, None] + S
        return h, y.astype(cdt)

    # remat the chunk body (see rwkv6.wkv_chunked): avoids saving stacked
    # (Q,Q)-sized intra-chunk intermediates across all chunks for backward
    step = jax.checkpoint(step, prevent_cse=False)
    h_final, ys = jax.lax.scan(step, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1)
    return y.reshape(B, L, G, HG, P).astype(x.dtype), h_final


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def pick_chunk(L: int, chunk: int) -> int:
    """Largest chunk size <= `chunk` that divides L."""
    q = min(chunk, L)
    while L % q:
        q -= 1
    return q


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    G = s.n_groups
    return d_inner, H, G


def init_mamba2(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    dtype = jnp.dtype(cfg.param_dtype)
    d_inner, H, G = _dims(cfg)
    GN = G * s.d_state
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[0], (H,), jnp.float32)
                 * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    conv = lambda k, ch: (jax.random.normal(k, (s.d_conv, ch), jnp.float32)
                          * 0.1).astype(dtype)
    return {
        "in_z": dense_init(ks[1], cfg.d_model, d_inner, dtype),
        "in_x": dense_init(ks[2], cfg.d_model, d_inner, dtype),
        "in_B": dense_init(ks[3], cfg.d_model, GN, dtype),
        "in_C": dense_init(ks[4], cfg.d_model, GN, dtype),
        "in_dt": dense_init(ks[5], cfg.d_model, H, dtype),
        "conv_x": conv(ks[6], d_inner),
        "conv_bx": jnp.zeros((d_inner,), dtype),
        "conv_B": conv(ks[7], GN),
        "conv_bB": jnp.zeros((GN,), dtype),
        "conv_C": conv(jax.random.fold_in(key, 9), GN),
        "conv_bC": jnp.zeros((GN,), dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rmsnorm(d_inner, dtype),
        "out_proj": dense_init(jax.random.fold_in(key, 10), d_inner,
                               cfg.d_model, dtype),
    }


def _causal_conv(xs: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d + silu. xs (B,L,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xs.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mamba2_block(params: Params, cfg: ModelConfig, u: jax.Array,
                 use_chunked: bool = True, ctx=None) -> jax.Array:
    """Full-sequence Mamba2 mixer. u: (B,L,D) -> (B,L,D)."""
    s = cfg.ssm
    B, L, _ = u.shape
    d_inner, H, G = _dims(cfg)
    HG = H // G
    z = jnp.einsum("bld,de->ble", u, params["in_z"])
    x = _causal_conv(jnp.einsum("bld,de->ble", u, params["in_x"]),
                     params["conv_x"], params["conv_bx"])
    Bm = _causal_conv(jnp.einsum("bld,de->ble", u, params["in_B"]),
                      params["conv_B"], params["conv_bB"])
    Cm = _causal_conv(jnp.einsum("bld,de->ble", u, params["in_C"]),
                      params["conv_C"], params["conv_bC"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", u, params["in_dt"]).astype(jnp.float32)
        + params["dt_bias"])
    x = x.reshape(B, L, G, HG, s.head_dim)
    Bm = Bm.reshape(B, L, G, s.d_state)
    Cm = Cm.reshape(B, L, G, s.d_state)
    dt = dt.reshape(B, L, G, HG)
    if ctx is not None and ctx.tp_axis and HG % ctx.tp_size == 0:
        x = ctx.constrain(x, ctx.dp_axes, None, None, ctx.tp_axis, None)
        dt = ctx.constrain(dt, ctx.dp_axes, None, None, ctx.tp_axis)
    A = -jnp.exp(params["A_log"]).reshape(G, HG)
    ssd = ssd_chunked if use_chunked else ssd_naive
    kw = {"chunk": pick_chunk(L, s.chunk)} if use_chunked else {}
    y, _ = ssd(x, dt, A, Bm, Cm, **kw)
    y = y + x * params["D"].reshape(G, HG)[None, None, :, :, None].astype(y.dtype)
    y = y.reshape(B, L, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, params["out_proj"])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    s = cfg.ssm
    d_inner, H, G = _dims(cfg)
    dtype = jnp.dtype(cfg.compute_dtype)
    K = s.d_conv - 1
    return SSMState(
        h=jnp.zeros((batch, G, H // G, s.head_dim, s.d_state), jnp.float32),
        conv_x=jnp.zeros((batch, K, d_inner), dtype),
        conv_B=jnp.zeros((batch, K, G * s.d_state), dtype),
        conv_C=jnp.zeros((batch, K, G * s.d_state), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def _conv_step(tail: jax.Array, cur: jax.Array, w: jax.Array, b: jax.Array):
    """One-token depthwise conv: tail (B,K-1,C), cur (B,C)."""
    window = jnp.concatenate([tail, cur[:, None, :]], axis=1)
    out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w.astype(cur.dtype)) + b)
    return out, window[:, 1:, :]


def mamba2_decode(params: Params, cfg: ModelConfig, u: jax.Array,
                  state: SSMState) -> Tuple[jax.Array, SSMState]:
    """Single-token step. u: (B,1,D)."""
    s = cfg.ssm
    B = u.shape[0]
    d_inner, H, G = _dims(cfg)
    HG = H // G
    u0 = u[:, 0]
    z = jnp.einsum("bd,de->be", u0, params["in_z"])
    x, cx = _conv_step(state.conv_x, jnp.einsum("bd,de->be", u0, params["in_x"]),
                       params["conv_x"], params["conv_bx"])
    Bm, cB = _conv_step(state.conv_B, jnp.einsum("bd,de->be", u0, params["in_B"]),
                        params["conv_B"], params["conv_bB"])
    Cm, cC = _conv_step(state.conv_C, jnp.einsum("bd,de->be", u0, params["in_C"]),
                        params["conv_C"], params["conv_bC"])
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", u0, params["in_dt"]).astype(jnp.float32)
        + params["dt_bias"]).reshape(B, G, HG)
    x = x.reshape(B, G, HG, s.head_dim).astype(jnp.float32)
    Bm = Bm.reshape(B, G, s.d_state).astype(jnp.float32)
    Cm = Cm.reshape(B, G, s.d_state).astype(jnp.float32)
    A = -jnp.exp(params["A_log"]).reshape(G, HG)
    da = jnp.exp(dt * A)
    h = state.h * da[..., None, None] \
        + jnp.einsum("bgh,bghp,bgn->bghpn", dt, x, Bm)
    y = jnp.einsum("bghpn,bgn->bghp", h, Cm)
    y = y + x * params["D"].reshape(G, HG)[None, :, :, None]
    y = y.reshape(B, 1, d_inner).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z[:, None, :]), cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return out, SSMState(h, cx, cB, cC, state.length + 1)


def mamba2_prefill(params: Params, cfg: ModelConfig, u: jax.Array,
                   ctx=None) -> Tuple[jax.Array, SSMState]:
    """Full-sequence forward returning the SSM state for decode handoff."""
    s = cfg.ssm
    B, L, _ = u.shape
    d_inner, H, G = _dims(cfg)
    HG = H // G
    K = s.d_conv - 1
    z = jnp.einsum("bld,de->ble", u, params["in_z"])
    xp = jnp.einsum("bld,de->ble", u, params["in_x"])
    Bp = jnp.einsum("bld,de->ble", u, params["in_B"])
    Cp = jnp.einsum("bld,de->ble", u, params["in_C"])
    x = _causal_conv(xp, params["conv_x"], params["conv_bx"])
    Bm = _causal_conv(Bp, params["conv_B"], params["conv_bB"])
    Cm = _causal_conv(Cp, params["conv_C"], params["conv_bC"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", u, params["in_dt"]).astype(jnp.float32)
        + params["dt_bias"])
    x = x.reshape(B, L, G, HG, s.head_dim)
    Bm = Bm.reshape(B, L, G, s.d_state)
    Cm = Cm.reshape(B, L, G, s.d_state)
    dt = dt.reshape(B, L, G, HG)
    A = -jnp.exp(params["A_log"]).reshape(G, HG)
    y, h_final = ssd_chunked(x, dt, A, Bm, Cm, chunk=pick_chunk(L, s.chunk))
    y = y + x * params["D"].reshape(G, HG)[None, None, :, :, None].astype(y.dtype)
    y = y.reshape(B, L, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    cdt = jnp.dtype(cfg.compute_dtype)
    tail = lambda a: (jnp.pad(a, ((0, 0), (K - a.shape[1], 0), (0, 0)))
                      if a.shape[1] < K else a[:, -K:, :]).astype(cdt)
    state = SSMState(
        h=h_final, conv_x=tail(xp), conv_B=tail(Bp), conv_C=tail(Cp),
        length=jnp.full((B,), L, jnp.int32))
    return out, state

"""Shared model layers: norms, rotary embeddings, SwiGLU MLP, embeddings.

Pure-JAX module style: every layer is an ``init_*`` returning a params pytree
(nested dict) plus an ``apply``-style function. No framework dependency.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dt(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM practice)."""
    std = scale / np.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -3.0, 3.0, (in_dim, out_dim), jnp.float32)
    return (w * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    w = jax.random.truncated_normal(key, -3.0, 3.0, (vocab, dim), jnp.float32)
    return (w * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm: f32 accumulation for the variance REDUCTION only; full-tensor
    ops stay in the input dtype. (A leading full-tensor f32 cast gets hoisted
    by XLA out of the backward scan as a convert of the whole saved-residual
    stack — 2x remat memory for free. Keeping the elementwise path in bf16
    avoids that; the f32 mean preserves the accuracy that matters.)"""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def rmsnorm_nohead(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head qk-norm (qwen3): normalize the trailing head_dim."""
    orig = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(orig)


def init_layernorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype),
            "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the rotary embedding (fp32)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)          # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)                 # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                           # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params: Params, x: jax.Array) -> jax.Array:
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    hidden = jax.nn.silu(gate) * up
    return jnp.einsum("...f,fd->...d", hidden, params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": embed_init(key, vocab, d_model, dtype)}


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(table: jax.Array, x: jax.Array) -> jax.Array:
    """Logits in fp32 (loss stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))

"""RWKV6 "Finch" block: time-mix (WKV6 recurrence with data-dependent
per-channel decay) + channel-mix FFN.

Recurrence per head (key dim N, value dim N):
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
    o_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])
with w_t = exp(-exp(w0 + lora_w(x))) in (0,1), data-dependent.

Implementations:
  * ``wkv_naive``  — per-step scan (oracle).
  * ``wkv_chunked``— chunk-parallel form (intra-chunk pairwise decay products
    + inter-chunk state carry), primary path, mirrored by the Pallas kernel.
  * ``rwkv6_decode`` — O(1) state step.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, init_rmsnorm, rmsnorm


class RWKVState(NamedTuple):
    s: jax.Array            # (B, H, N, N) wkv state
    x_tm: jax.Array         # (B, D) previous token (time-mix shift)
    x_cm: jax.Array         # (B, D) previous token (channel-mix shift)
    length: jax.Array


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------

def wkv_naive(r, k, v, w, u, s0=None):
    """Oracle. r/k/v/w: (B,L,H,N); u: (H,N). Returns (out (B,L,H,N), s)."""
    B, L, H, N = r.shape
    f32 = jnp.float32
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), f32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        o = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, o

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(f32) for t in (r, k, v, w))
    s, os_ = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(os_, 0, 1).astype(r.dtype), s


def wkv_chunked(r, k, v, w, u, s0=None, chunk: int = 16, ctx=None):
    # shrink chunk to a divisor of L
    L0 = r.shape[1]
    q = min(chunk, L0)
    while L0 % q:
        q -= 1
    chunk = q
    """Chunk-parallel WKV6.

    Within a chunk (log-space cumulative decay lcum, inclusive):
      intra: o_q += sum_{j<q} r_q * exp(lcum_{q-1} - lcum_j) k_j  v_j
             (+ current-step bonus u*k_q v_q)
      inter: o_q += (r_q * exp(lcum_{q-1})) @ S_chunkstart
      state: S' = exp(lcum_last) * S + sum_j exp(lcum_last - lcum_j) k_j v_j
    """
    B, L, H, N = r.shape
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q
    f32 = jnp.float32
    cdt = r.dtype

    rc, kc, vc = (jnp.moveaxis(t.reshape(B, nc, Q, H, N), 1, 0)
                  for t in (r, k, v))                   # (nc,B,Q,H,N)
    wc = jnp.moveaxis(w.reshape(B, nc, Q, H, N), 1, 0).astype(f32)
    mask = jnp.tril(jnp.ones((Q, Q), bool), -1)

    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), f32)

    def step(s, inp):
        r_c, k_c, v_c, w_c = inp                        # (B,Q,H,N)
        lw = jnp.log(jnp.maximum(w_c, 1e-20))
        lcum = jnp.cumsum(lw, axis=1)                   # inclusive (B,Q,H,N)
        lprev = lcum - lw                               # exclusive
        # intra-chunk: pair decay exp(lprev_q - lcum_j), j < q  (materialized
        # one chunk at a time — bounded temp, mirrors the kernel's VMEM tile)
        diff = lprev[:, :, None] - lcum[:, None, :]     # (B,Q,Q,H,N)
        pair = jnp.where(mask[None, :, :, None, None], jnp.exp(diff), 0.0)
        if ctx is not None and ctx.tp_axis and pair.shape[-1] % ctx.tp_size == 0:
            # the pair tensor derives from w (tp-replicated); without a
            # constraint every device materializes ALL of it — shard over N
            # and let the scores contraction psum (PERF: rwkv hillclimb #1)
            pair = ctx.constrain(pair, ctx.dp_axes, None, None, None,
                                 ctx.tp_axis)
        scores = jnp.einsum("bqhi,bqjhi,bjhi->bqjh",
                            r_c.astype(f32), pair, k_c.astype(f32))
        o = jnp.einsum("bqjh,bjhn->bqhn", scores.astype(cdt), v_c).astype(f32)
        # current-step bonus
        bonus = jnp.einsum("bqhi,hi,bqhi->bqh", r_c.astype(f32),
                           u.astype(f32), k_c.astype(f32))
        o = o + bonus[..., None] * v_c.astype(f32)
        # inter-chunk: carried state contribution
        rq = r_c.astype(f32) * jnp.exp(lprev)
        o = o + jnp.einsum("bqhi,bhin->bqhn", rq, s)
        # state update
        decay_to_end = jnp.exp(lcum[:, -1:] - lcum)     # (B,Q,H,N)
        Ssum = jnp.einsum("bqhi,bqhn->bhin",
                          k_c.astype(f32) * decay_to_end, v_c.astype(f32))
        s = s * jnp.exp(lcum[:, -1])[..., None] + Ssum
        return s, o.astype(cdt)

    # remat the chunk body: without this the scan saves every chunk's
    # (Q,Q,H,N)-sized intermediates for backward — O(L^2) HBM traffic
    # (PERF: rwkv hillclimb #4). Saved per chunk = just the state carry.
    step = jax.checkpoint(step, prevent_cse=False)
    s_final, outs = jax.lax.scan(step, s0, (rc, kc, vc, wc))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, L, H, N)
    return out, s_final


# ---------------------------------------------------------------------------
# block params
# ---------------------------------------------------------------------------

def init_rwkv6(key, cfg: ModelConfig) -> Params:
    """Time-mix + channel-mix parameters for one block."""
    c = cfg.rwkv
    dtype = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    N = c.head_dim
    H = D // N
    ks = jax.random.split(key, 12)
    lora = c.decay_lora
    return {
        # token-shift lerp bases for r,k,v,w,g (+ low-rank data-dependent part)
        "mu": 0.5 * jnp.ones((5, D), dtype),
        "mix_a": dense_init(ks[0], D, 5 * c.mix_lora, dtype),
        "mix_b": (jax.random.normal(ks[1], (5, c.mix_lora, D), jnp.float32)
                  * 0.01).astype(dtype),
        "wr": dense_init(ks[2], D, D, dtype),
        "wk": dense_init(ks[3], D, D, dtype),
        "wv": dense_init(ks[4], D, D, dtype),
        "wg": dense_init(ks[5], D, D, dtype),
        "wo": dense_init(ks[6], D, D, dtype),
        # data-dependent decay: w = exp(-exp(w0 + b(tanh(a(x)))))
        "w0": jnp.full((D,), -4.0, jnp.float32),
        "decay_a": dense_init(ks[7], D, lora, dtype),
        "decay_b": dense_init(ks[8], lora, D, dtype) * 0.1,
        "u": 0.5 * jnp.ones((H, N), jnp.float32),        # current-step bonus
        "ln_x": {"scale": jnp.ones((D,), dtype)},        # per-head group norm
        # channel-mix
        "cm_mu": 0.5 * jnp.ones((2, D), dtype),
        "cm_k": dense_init(ks[9], D, cfg.d_ff, dtype),
        "cm_v": dense_init(ks[10], cfg.d_ff, D, dtype),
        "cm_r": dense_init(ks[11], D, D, dtype),
    }


def _token_shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """Shifted sequence: position t sees token t-1. x (B,L,D); x_prev (B,D)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix_inputs(params: Params, x: jax.Array, xs: jax.Array):
    """Data-dependent lerp between x and shifted x for r,k,v,w,g.

    PERF (rwkv hillclimb #2): computed one stream at a time — stacking all
    five as (B,L,5,D) forces 5x activation-sized HBM traffic per block; the
    per-stream form fuses into each projection's dot input."""
    delta = xs - x                                       # (B,L,D)
    B, L, D = x.shape
    low = jnp.tanh(jnp.einsum("bld,dr->blr", delta, params["mix_a"]))
    low = low.reshape(B, L, 5, -1)
    out = []
    for i in range(5):
        adj = jnp.einsum("blr,rd->bld", low[:, :, i], params["mix_b"][i])
        out.append(x + delta * (params["mu"][i] + adj))
    return out                                           # r,k,v,w,g inputs


def rwkv6_time_mix(params: Params, cfg: ModelConfig, x: jax.Array,
                   x_prev: jax.Array, s0=None, use_chunked: bool = True,
                   ctx=None):
    """Time-mix. x (B,L,D); x_prev (B,D) last token of previous segment.
    Returns (out, s_final, x_last).

    TP note: the WKV recurrence is independent across VALUE channels, so v,
    the state's value dim, and the output shard over tp while r/k/w stay
    replicated (heads=40 do not divide tp=16; value channels do).
    """
    c = cfg.rwkv
    B, L, D = x.shape
    N = c.head_dim
    H = D // N
    xs = _token_shift(x, x_prev)
    xr, xk, xv, xw, xg = _time_mix_inputs(params, x, xs)
    r = jnp.einsum("bld,de->ble", xr, params["wr"]).reshape(B, L, H, N)
    k = jnp.einsum("bld,de->ble", xk, params["wk"]).reshape(B, L, H, N)
    v = jnp.einsum("bld,de->ble", xv, params["wv"]).reshape(B, L, H, N)
    g = jax.nn.silu(jnp.einsum("bld,de->ble", xg, params["wg"]))
    dlow = jnp.tanh(jnp.einsum("bld,dr->blr", xw, params["decay_a"]))
    dlog = params["w0"] + jnp.einsum("blr,re->ble", dlow,
                                     params["decay_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dlog)).reshape(B, L, H, N)      # (0,1) decay
    if ctx is not None and ctx.tp_axis and N % ctx.tp_size == 0:
        v = ctx.constrain(v, ctx.dp_axes, None, None, ctx.tp_axis)
        # shard the DECAY over N too: the whole lcum/diff/pair chain then
        # propagates N-sharded instead of being computed replicated and
        # resharded at the pair constraint (PERF: rwkv hillclimb #3)
        w = ctx.constrain(w, ctx.dp_axes, None, None, ctx.tp_axis)
        if s0 is None:
            s0 = jnp.zeros((B, H, N, N), jnp.float32)
        s0 = ctx.constrain(s0, ctx.dp_axes, None, None, ctx.tp_axis)
    if use_chunked:
        out, s_final = wkv_chunked(r, k, v, w, params["u"], s0, ctx=ctx)
    else:
        out, s_final = wkv_naive(r, k, v, w, params["u"], s0)
    out = out.reshape(B, L, D)
    out = rmsnorm(params["ln_x"], out, cfg.norm_eps) * g
    out = jnp.einsum("ble,ed->bld", out, params["wo"])
    return out, s_final, x[:, -1, :]


def rwkv6_channel_mix(params: Params, x: jax.Array, x_prev: jax.Array):
    """Channel-mix FFN with token shift. Returns (out, x_last)."""
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * params["cm_mu"][0]
    xr = x + (xs - x) * params["cm_mu"][1]
    k = jnp.einsum("bld,df->blf", xk, params["cm_k"])
    kv = jnp.einsum("blf,fd->bld", jnp.square(jax.nn.relu(k)), params["cm_v"])
    r = jax.nn.sigmoid(jnp.einsum("ble,ed->bld", xr, params["cm_r"]))
    return r * kv, x[:, -1, :]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_rwkv_state(cfg: ModelConfig, batch: int) -> RWKVState:
    c = cfg.rwkv
    D = cfg.d_model
    H = D // c.head_dim
    dtype = jnp.dtype(cfg.compute_dtype)
    return RWKVState(
        s=jnp.zeros((batch, H, c.head_dim, c.head_dim), jnp.float32),
        x_tm=jnp.zeros((batch, D), dtype),
        x_cm=jnp.zeros((batch, D), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def rwkv6_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 state: RWKVState) -> Tuple[jax.Array, RWKVState]:
    """Single-token time-mix + channel-mix step. x: (B,1,D) block input
    (already normed by caller per sublayer); here we run time-mix given
    state and return (tm_out, new_state-without-cm-update). Channel-mix is
    applied by the caller via rwkv6_channel_mix with x_cm."""
    out, s_final, x_last = rwkv6_time_mix(params, cfg, x, state.x_tm,
                                          s0=state.s, use_chunked=False)
    return out, state._replace(s=s_final, x_tm=x_last,
                               length=state.length + 1)

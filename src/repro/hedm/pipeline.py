"""NF/FF-HEDM analysis pipeline (paper §II, §V, §VI).

Stage 0 — detector simulation: synthetic diffraction frames (bright spots on
noise, sparse like real frames) streamed to the shared FS (repro.core.fabric)
exactly as the APS detector writes to NFS/GPFS.

Stage 1 — data reduction (§VI-A): per-frame background subtraction, median
filter, Laplacian edge response, threshold, connected-component labeling ->
peak list. The filter half runs on the hedm_reduce kernel (or its jnp
oracle); labeling runs on host (networkx-free union-find).

Stage 2 — orientation fitting (§V-C, Fig. 8): for every grid point, fit the
crystal orientation (3 Euler-like params) to the observed diffraction
signature by batched Gauss-Newton — the FitOrientation() many-task stage,
vmapped/sharded instead of one C process per point.

Online mode — ``reduce_frames_online`` / ``run_online_hedm`` run stage-1
incrementally per sliding window over a streamed acquisition
(`repro.core.streaming`): results are produced while the detector is still
writing, and are bit-identical to the batch path (``run_batch_hedm``).

Interactive mode — ``run_interactive_hedm`` drives N concurrent analysis
sessions over M scans through the long-lived dataset catalog + staging
service (`repro.core.datasvc`): sessions lease datasets (coalescing
concurrent stages), reduce from the resident replicas, and write their
results back to the shared FS with the collective ``stage_out`` — the
"extended residency, various processing tasks" regime of §VI-B.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.fabric import Fabric


# ---------------------------------------------------------------------------
# stage 0: detector simulation
# ---------------------------------------------------------------------------

def simulate_detector_frames(n_frames: int, size: int = 256,
                             n_spots: int = 12, seed: int = 0
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic diffraction frames: Gaussian spots on Poisson background.
    Returns (frames (F,size,size) float32, dark (size,size)).

    Spot rendering is fully vectorized: an isotropic Gaussian separates into
    a row factor and a column factor, so all F x n_spots spots render as one
    (F,S,H) x (F,S,W) einsum — no per-frame/per-spot Python loops.
    """
    rng = np.random.default_rng(seed)
    dark = rng.poisson(8.0, (size, size)).astype(np.float32)
    frames = rng.poisson(8.0, (n_frames, size, size)).astype(np.float32)
    if n_frames and n_spots:
        cy = rng.uniform(8, size - 8, (n_frames, n_spots, 1))
        cx = rng.uniform(8, size - 8, (n_frames, n_spots, 1))
        amp = rng.uniform(800, 4000, (n_frames, n_spots, 1))
        sig = rng.uniform(1.0, 2.5, (n_frames, n_spots, 1))
        r = np.arange(size, dtype=np.float64)
        gy = amp * np.exp(-((r - cy) ** 2) / (2 * sig ** 2))   # (F,S,H)
        gx = np.exp(-((r - cx) ** 2) / (2 * sig ** 2))         # (F,S,W)
        frames += np.einsum("fsh,fsw->fhw", gy, gx,
                            optimize=True).astype(np.float32)
    return frames, dark


def stream_to_fs(fabric: Fabric, frames: np.ndarray, prefix: str = "scan"
                 ) -> List[str]:
    """Detector -> shared FS, one file per frame (8 MB TIFFs in the paper)."""
    paths = []
    for i, frame in enumerate(frames):
        path = f"{prefix}/frame_{i:05d}.bin"
        fabric.fs.put(path, frame.astype(np.float32).view(np.uint8))
        paths.append(path)
    return paths


# ---------------------------------------------------------------------------
# stage 1: reduction
# ---------------------------------------------------------------------------

def label_components(mask: np.ndarray) -> Tuple[np.ndarray, int]:
    """Vectorized 4-connected component labeling (run-based two-pass).

    Pass 1 finds horizontal runs of the whole mask at once (a sentinel
    column keeps runs from spanning rows) and unions runs that overlap
    between adjacent rows; pass 2 paints final labels with one scatter.
    Work is O(H*W) vectorized + O(#runs) scalar — for sparse diffraction
    masks #runs is ~100x smaller than #pixels, which is what makes stage-1
    labeling faster than the filter kernel it post-processes.

    Label numbering matches ``_union_find_label`` exactly (components
    numbered by first pixel in row-major scan order), so the two are
    interchangeable; tests assert equivalence.
    """
    H, W = mask.shape
    m = np.ascontiguousarray(mask, dtype=bool)
    if not m.any():
        return np.zeros((H, W), np.int32), 0

    # --- pass 1a: horizontal runs over the flattened mask -----------------
    padded = np.zeros((H, W + 1), bool)          # sentinel column: runs
    padded[:, :W] = m                            # never cross a row edge
    flat = padded.ravel()
    d = np.diff(flat.view(np.int8))
    starts = np.flatnonzero(d == 1) + 1
    ends = np.flatnonzero(d == -1) + 1           # every run closes (sentinel)
    if flat[0]:
        starts = np.concatenate(([0], starts))
    rows = starts // (W + 1)
    col_s = starts - rows * (W + 1)
    col_e = ends - rows * (W + 1)
    n_runs = len(starts)

    # --- pass 1b: union runs that overlap between adjacent rows ----------
    # Encode (row, col) into one monotone key so a SINGLE pair of
    # searchsorted calls finds, for every run i in row r, the contiguous
    # range [lo_i, hi_i) of row r-1 runs j with col_s[j] < col_e[i] and
    # col_e[j] > col_s[i] (4-connectivity overlap). Runs in other rows fall
    # outside [lo_i, hi_i) by key construction (row-0 runs get hi <= lo).
    stride = W + 2                               # > any col value
    key_s = rows * stride + col_s
    key_e = rows * stride + col_e
    target = (rows - 1) * stride
    lo = np.searchsorted(key_e, target + col_s, side="right")
    hi = np.searchsorted(key_s, target + col_e, side="left")
    n_ov = np.maximum(hi - lo, 0)
    pair_i = np.repeat(np.arange(n_runs), n_ov)
    off = np.concatenate(([0], n_ov.cumsum()[:-1]))
    pair_j = np.arange(n_ov.sum()) + np.repeat(lo - off, n_ov)

    parent = np.arange(n_runs, dtype=np.int64)

    def find(a: int) -> int:
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for i, j in zip(pair_i.tolist(), pair_j.tolist()):
        ri, rj = find(i), find(j)
        if ri != rj:                         # min-root union keeps scan order
            if rj < ri:
                ri, rj = rj, ri
            parent[rj] = ri
    # full path compression, vectorized (log-depth)
    while True:
        p2 = parent[parent]
        if np.array_equal(p2, parent):
            break
        parent = p2

    # --- pass 2: renumber roots in scan order, paint runs -----------------
    roots = np.unique(parent)                # sorted == first-run order
    run_label = (np.searchsorted(roots, parent) + 1).astype(np.int32)
    lengths = ends - starts
    pos = (np.arange(lengths.sum()) + np.repeat(
        starts - np.concatenate(([0], lengths.cumsum()[:-1])), lengths))
    out = np.zeros(H * (W + 1), np.int32)
    out[pos] = np.repeat(run_label, lengths)
    return out.reshape(H, W + 1)[:, :W], len(roots)


def _union_find_label(mask: np.ndarray) -> Tuple[np.ndarray, int]:
    """Pure-Python pixel-loop 4-connected labeling. Kept as the reference
    oracle for :func:`label_components` (and the benchmark baseline) — the
    hot path uses the vectorized labeler."""
    H, W = mask.shape
    labels = np.zeros((H, W), np.int32)
    parent: List[int] = [0]

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    nxt = 1
    for i in range(H):
        for j in range(W):
            if not mask[i, j]:
                continue
            up = labels[i - 1, j] if i else 0
            left = labels[i, j - 1] if j else 0
            if up and left:
                ru, rl = find(up), find(left)
                labels[i, j] = ru
                if ru != rl:
                    parent[max(ru, rl)] = min(ru, rl)
            elif up or left:
                labels[i, j] = up or left
            else:
                parent.append(nxt)
                labels[i, j] = nxt
                nxt += 1
    remap: Dict[int, int] = {}
    count = 0
    for i in range(H):
        for j in range(W):
            if labels[i, j]:
                r = find(labels[i, j])
                if r not in remap:
                    count += 1
                    remap[r] = count
                labels[i, j] = remap[r]
    return labels, count


@dataclass
class ReducedFrame:
    frame_id: int
    n_signal_pixels: int
    n_spots: int
    peaks: np.ndarray              # (n_spots, 3): y, x, intensity


def reduce_frames(frames: np.ndarray, dark: np.ndarray,
                  threshold: float = 200.0, use_kernel: bool = True
                  ) -> List[ReducedFrame]:
    """Stage-1 reduction of a frame stack (paper: 8 MB -> ~1 MB binary)."""
    if use_kernel:
        from repro.kernels.ops import hedm_reduce
        masks, counts = hedm_reduce(jnp.asarray(frames), jnp.asarray(dark),
                                    threshold=threshold)
    else:
        from repro.kernels.hedm_reduce_ref import reference
        masks, counts = reference(jnp.asarray(frames), jnp.asarray(dark),
                                  threshold=threshold)
    masks = np.asarray(masks)
    counts = np.asarray(counts)
    H, W = frames.shape[1:]
    yy, xx = np.divmod(np.arange(H * W), W)
    out = []
    for f in range(frames.shape[0]):
        labels, n = label_components(masks[f] > 0)
        # intensity-weighted centroids: one bincount pass per moment instead
        # of a per-label nonzero scan over the full frame
        lab = labels.ravel()
        sel = np.flatnonzero(lab)
        l_s, v_s = lab[sel], frames[f].ravel()[sel].astype(np.float64)
        s_i = np.bincount(l_s, weights=v_s, minlength=n + 1)
        s_y = np.bincount(l_s, weights=v_s * yy[sel], minlength=n + 1)
        s_x = np.bincount(l_s, weights=v_s * xx[sel], minlength=n + 1)
        denom = np.maximum(s_i, 1e-9)
        peaks = np.stack([s_y / denom, s_x / denom, s_i],
                         axis=1)[1:].astype(np.float32)
        out.append(ReducedFrame(f, int(counts[f]), n, peaks))
    return out


# ---------------------------------------------------------------------------
# online (streaming) stage-1 mode
# ---------------------------------------------------------------------------

def reduce_frames_online(frames: np.ndarray, dark: np.ndarray,
                         window: int = 8, threshold: float = 200.0,
                         use_kernel: bool = True
                         ) -> Iterator[List[ReducedFrame]]:
    """Incremental stage-1: yield per-window ``ReducedFrame`` lists.

    The filter/label/centroid chain is per-frame independent, so splitting
    the frame axis into windows of `window` is bit-identical to one batch
    ``reduce_frames`` call over the whole stack (tests assert it); frame
    ids are global. This is the compute half of the online mode — the
    simulated-time half (delivery, backpressure, turnaround) lives in
    :func:`run_online_hedm`.
    """
    for w0 in range(0, frames.shape[0], window):
        chunk = reduce_frames(frames[w0:w0 + window], dark,
                              threshold=threshold, use_kernel=use_kernel)
        for r in chunk:
            r.frame_id += w0
        yield chunk


@dataclass
class OnlineHEDMResult:
    """Outcome of a streamed stage-1 run (times in simulated seconds)."""
    reduced: List[ReducedFrame]
    window_done: List[float]       # completion time of each reduce window
    turnaround: float              # last window done = end-to-end latency
    stream: "object"               # StreamReport of the ingest side


def run_online_hedm(fabric: Fabric, frames: np.ndarray, dark: np.ndarray,
                    rate_hz: Optional[float] = 10.0, window: int = 8,
                    threshold: float = 200.0, use_kernel: bool = True,
                    cache_frames: Optional[int] = None,
                    reduce_time_per_frame: Optional[float] = None
                    ) -> OnlineHEDMResult:
    """Online HEDM: ingest a streamed acquisition and reduce per window.

    Frames stream through a :class:`repro.core.streaming.StreamStager`
    (scatter + ring broadcast, sliding window of ``cache_frames`` frames —
    ``None`` keeps the whole scan resident); every full window is reduced
    FROM THE STAGED NODE-LOCAL REPLICA the moment its last frame lands,
    overlapping compute with acquisition. Consumed frames are released
    back to the window (enabling eviction/backpressure).

    ``reduce_time_per_frame`` is the simulated stage-1 cost per frame (s);
    ``None`` charges the measured wall time of the real reduction instead
    (the `ManyTaskEngine` payload idiom). Outputs are bit-identical to
    ``reduce_frames`` over the same stack.
    """
    from repro.core.api import StagingClient, StreamConfig
    from repro.core.streaming import DetectorSource

    if cache_frames is not None and cache_frames < window:
        raise ValueError(
            f"cache_frames ({cache_frames}) must be >= window ({window}): "
            f"frames are only released once a full reduce window has run, "
            f"so a smaller cache wedges the stream")
    # detector emits float32, same cast as the batch path's stream_to_fs —
    # keeps the 4-byte/pixel window accounting and replica decode honest
    frames = np.ascontiguousarray(frames, dtype=np.float32)
    F, H, W = frames.shape
    frame_bytes = H * W * 4
    config = StreamConfig(rate_hz=rate_hz,
                          window_bytes=(cache_frames or F) * frame_bytes)
    src = DetectorSource.from_frames(frames, rate_hz=config.rate_hz)
    stager = StagingClient(fabric).stream_stager(config)

    reduced: List[ReducedFrame] = []
    window_done: List[float] = []
    pending: List = []
    t_done = 0.0
    store = fabric.hosts[0].store
    for fid, path, buf, t_emit in src:
        pending.append(stager.ingest(path, buf, t_emit))
        if len(pending) == window or fid == F - 1:
            stack = np.stack([store.data[r.path].view(np.float32)
                              .reshape(H, W) for r in pending])
            t_wall = _time.perf_counter()
            chunk = reduce_frames(stack, dark, threshold=threshold,
                                  use_kernel=use_kernel)
            wall = _time.perf_counter() - t_wall
            dur = (reduce_time_per_frame * len(pending)
                   if reduce_time_per_frame is not None else wall)
            base = pending[0].frame_id
            for r in chunk:
                r.frame_id += base
            t_start = max(t_done, max(r.t_avail for r in pending))
            t_done = t_start + dur
            for r in pending:
                stager.release(r.path, t_done)
            reduced.extend(chunk)
            window_done.append(t_done)
            pending = []
    return OnlineHEDMResult(reduced=reduced, window_done=window_done,
                            turnaround=t_done, stream=stager.finish())


def run_batch_hedm(fabric: Fabric, frames: np.ndarray, dark: np.ndarray,
                   rate_hz: Optional[float] = 10.0, threshold: float = 200.0,
                   use_kernel: bool = True, mode: str = "collective",
                   reduce_time_per_frame: Optional[float] = None
                   ) -> Tuple[List[ReducedFrame], float, "object"]:
    """Stage-then-process baseline for the same scan as ``run_online_hedm``.

    The detector writes every frame to the shared FS first (acquisition
    completes at ``F / rate_hz`` simulated s; the producer write itself is
    not charged, which favors this baseline), the whole scan is staged with
    the batch engine `mode` through the unified client (concrete paths, no
    glob resolution or pinning — ``resolve=False``), then stage-1 runs
    over the staged node-local replicas in one pass. Returns
    ``(reduced, turnaround, StagingReport)``.
    """
    from repro.core.api import (BroadcastEntry, ENGINES, StagingClient,
                                StagingSpec)
    config = ENGINES.config_for(mode, batch_only=True)

    F, H, W = frames.shape
    paths = stream_to_fs(fabric, frames)
    t_acq = F / rate_hz if rate_hz else 0.0
    spec = StagingSpec([BroadcastEntry(files=tuple(paths), pin=False)])
    crep = StagingClient(fabric).stage(spec, config, t0=t_acq, resolve=False)
    # same arithmetic as the engine's returned completion time (bit-exact)
    rep = crep.reports[0]
    t_staged = t_acq + rep.total_time

    store = fabric.hosts[0].store
    stack = np.stack([store.data[p].view(np.float32).reshape(H, W)
                      for p in paths])
    t_wall = _time.perf_counter()
    reduced = reduce_frames(stack, dark, threshold=threshold,
                            use_kernel=use_kernel)
    wall = _time.perf_counter() - t_wall
    dur = (reduce_time_per_frame * F
           if reduce_time_per_frame is not None else wall)
    return reduced, t_staged + dur, rep


# ---------------------------------------------------------------------------
# interactive (multi-session) mode over the dataset catalog + service
# ---------------------------------------------------------------------------

def pack_reduced(reduced: Sequence[ReducedFrame]) -> np.ndarray:
    """Flat float32 write-back payload for a reduced scan: per frame a
    ``[frame_id, n_signal_pixels, n_spots]`` header followed by the
    ``(n_spots, 3)`` peak rows. Deterministic, so two sessions reducing
    the same staged dataset produce byte-identical buffers — the
    write-back byte-exactness criterion."""
    parts = []
    for r in reduced:
        parts.append(np.array([r.frame_id, r.n_signal_pixels, r.n_spots],
                              np.float32))
        parts.append(np.ascontiguousarray(r.peaks, np.float32).ravel())
    return (np.concatenate(parts) if parts else np.zeros(0, np.float32))


@dataclass
class SessionScript:
    """One tenant's plan: which datasets it reduces, in order, starting at
    ``t_start`` (simulated s). ``reduce_s_per_frame`` is the declared
    stage-1 cost (the ManyTaskEngine duration idiom — keeps multi-session
    schedules deterministic)."""
    name: str
    datasets: List[str]
    t_start: float = 0.0
    reduce_s_per_frame: float = 0.15


@dataclass
class InteractiveHEDMResult:
    """Outcome of a multi-session interactive run (times simulated s)."""
    outputs: Dict[str, Dict[str, np.ndarray]]   # session -> dataset -> packed
    result_paths: Dict[str, Dict[str, str]]     # session -> dataset -> FS path
    session_done: Dict[str, float]              # flush completion per session
    writeback: Dict[str, "object"]              # session -> StagingReport
    service: "object"                           # the StagingService (stats)
    turnaround: float                           # last session flush


def run_interactive_hedm(fabric: Fabric, scans: Dict[str, np.ndarray],
                         dark: np.ndarray,
                         sessions: Sequence[SessionScript],
                         budget_bytes: int, threshold: float = 200.0,
                         use_kernel: bool = False, mode: str = "collective",
                         collective_writeback: bool = True
                         ) -> InteractiveHEDMResult:
    """N concurrent analysis sessions over M scans through the staging
    service — the paper's interactive regime (§VI-B) plus write-back.

    Every scan lands on the shared FS (stage 0) and registers in the
    catalog. Sessions then interleave round-robin: each leases its next
    dataset (concurrent requests COALESCE into one collective stage;
    unleased residents evict under ``budget_bytes`` and re-stage
    transparently on a later miss), reduces stage-1 FROM THE RESIDENT
    NODE-LOCAL REPLICA (charged: replica read at ``local_read_bw`` +
    ``reduce_s_per_frame`` per frame), installs the packed result as a
    dirty replica, and releases the lease. When a session's script is
    done it FLUSHES its results to the shared FS (collective
    ``stage_out`` or the naive baseline).

    Outputs are bit-identical to reducing each scan directly — eviction
    and re-staging never change bytes, only times (tests assert this).
    """
    from contextlib import ExitStack

    from repro.core.api import ENGINES, ServiceConfig, StagingClient

    scans32 = {n: np.ascontiguousarray(f, dtype=np.float32)
               for n, f in scans.items()}
    for name, frames in scans32.items():
        stream_to_fs(fabric, frames, prefix=name)
    client = StagingClient(fabric, service=ServiceConfig(
        budget_bytes=budget_bytes,
        engine=ENGINES.config_for(mode, batch_only=True)))
    svc = client.service
    for name in scans32:
        svc.register(name, patterns=[f"{name}/frame_*.bin"])

    clocks = {s.name: s.t_start for s in sessions}
    outputs: Dict[str, Dict[str, np.ndarray]] = {s.name: {} for s in sessions}
    result_paths: Dict[str, Dict[str, str]] = {s.name: {} for s in sessions}
    c = fabric.constants

    session_done: Dict[str, float] = {}
    writeback: Dict[str, object] = {}
    with ExitStack() as stack:
        # session-scoped campaigns: any lease a tenant still holds when
        # the stack unwinds (including on error) is auto-released
        handles = {s.name: stack.enter_context(client.session(s.name))
                   for s in sessions}
        for step in range(max(len(s.datasets) for s in sessions)):
            for script in sessions:
                if step >= len(script.datasets):
                    continue
                ds = script.datasets[step]
                sess = handles[script.name]
                lease = sess.acquire(ds, clocks[script.name])
                entry = svc.catalog[ds]
                F, H, W = scans32[ds].shape
                store = fabric.hosts[0].store
                stack_ = np.stack([store.data[p].view(np.float32)
                                   .reshape(H, W) for p in entry.paths])
                reduced = reduce_frames(stack_, dark, threshold=threshold,
                                        use_kernel=use_kernel)
                packed = pack_reduced(reduced)
                t_compute = (lease.t_ready
                             + entry.nbytes / c.local_read_bw  # replica read
                             + script.reduce_s_per_frame * F)
                path, t_put = sess.put_result(ds, packed, t_compute)
                sess.release(ds, t_put)
                clocks[script.name] = t_put
                outputs[script.name][ds] = packed
                result_paths[script.name][ds] = path

        for script in sessions:
            rep, t_done = handles[script.name].flush(
                clocks[script.name], collective=collective_writeback)
            writeback[script.name] = rep
            session_done[script.name] = t_done
    return InteractiveHEDMResult(
        outputs=outputs, result_paths=result_paths,
        session_done=session_done, writeback=writeback, service=svc,
        turnaround=max(session_done.values()) if session_done else 0.0)


# ---------------------------------------------------------------------------
# stage 2: orientation fitting (batched Gauss-Newton)
# ---------------------------------------------------------------------------

N_GVEC = 24          # reference reciprocal-lattice directions per point


def _rotation(angles: jax.Array) -> jax.Array:
    """ZYZ Euler rotation matrix from 3 angles."""
    a, b, c = angles[0], angles[1], angles[2]
    ca, sa = jnp.cos(a), jnp.sin(a)
    cb, sb = jnp.cos(b), jnp.sin(b)
    cc, sc = jnp.cos(c), jnp.sin(c)
    rz1 = jnp.array([[ca, -sa, 0], [sa, ca, 0], [0, 0, 1.0]])
    ry = jnp.array([[cb, 0, sb], [0, 1.0, 0], [-sb, 0, cb]])
    rz2 = jnp.array([[cc, -sc, 0], [sc, cc, 0], [0, 0, 1.0]])
    return rz1 @ ry @ rz2


def make_gvectors(seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(N_GVEC, 3))
    return (g / np.linalg.norm(g, axis=1, keepdims=True)).astype(np.float32)


def forward_model(angles: jax.Array, gvec: jax.Array) -> jax.Array:
    """Simulated diffraction signature of an orientation (nonlinear)."""
    R = _rotation(angles)
    rotated = gvec @ R.T                              # (N,3)
    det_normal = jnp.array([0.0, 0.0, 1.0])
    proj = rotated @ det_normal                       # (N,)
    return jnp.concatenate([jnp.sin(3.0 * rotated[:, 0]) * proj,
                            jnp.cos(2.0 * rotated[:, 1]) * proj])


def fit_orientation(y_obs: jax.Array, gvec: jax.Array, theta0: jax.Array,
                    iters: int = 12, damping: float = 1e-3) -> jax.Array:
    """Gauss-Newton (Levenberg-damped) fit of one grid point."""
    def step(theta, _):
        r = forward_model(theta, gvec) - y_obs
        J = jax.jacfwd(lambda t: forward_model(t, gvec))(theta)   # (M,3)
        JtJ = J.T @ J + damping * jnp.eye(3)
        delta = jnp.linalg.solve(JtJ, J.T @ r)
        return theta - delta, jnp.sum(r * r)

    theta, losses = jax.lax.scan(step, theta0, None, length=iters)
    return theta


def fit_grid(y_obs: jax.Array, gvec: jax.Array, theta0: jax.Array,
             iters: int = 12) -> jax.Array:
    """vmapped FitOrientation over all grid points: (Npts, M) -> (Npts, 3).
    Under pjit the point axis shards over the full mesh — the many-task
    structure of Fig. 8 expressed as data parallelism."""
    return jax.vmap(lambda y, t0: fit_orientation(y, gvec, t0, iters))(
        y_obs, theta0)


def synth_grid_observations(n_points: int, gvec: np.ndarray, seed: int = 3,
                            noise: float = 0.01
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Ground-truth orientations + noisy observed signatures."""
    rng = np.random.default_rng(seed)
    truth = rng.uniform(-0.6, 0.6, (n_points, 3)).astype(np.float32)
    obs = jax.vmap(lambda t: forward_model(t, jnp.asarray(gvec)))(
        jnp.asarray(truth))
    obs = np.asarray(obs) + rng.normal(0, noise, obs.shape).astype(np.float32)
    return truth, obs

"""Staged training-data pipeline: the paper's technique as the input path.

A dataset lives on the shared FS as shard files. Per training wave:
  * leaders resolve the shard manifest ONCE (iohook) and collectively stage
    each host's assigned shards into node-local stores (aggregate FS read =
    1x dataset, paper §IV),
  * hosts cut batches from node-local data at RAM speed; repeats (multiple
    epochs / eval reuse) hit the pinned cache at zero FS cost (§VI-B).

`StagedLoader.batches()` yields jnp batches for train_step; the simulated-
time accounting (stage vs naive) feeds the benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.api import (BroadcastEntry, CollectiveConfig, NaiveConfig,
                            StagingClient, StagingSpec)
from repro.core.fabric import Fabric
from repro.core.staging import StagingReport


def write_token_shards(fabric: Fabric, n_shards: int, tokens_per_shard: int,
                       vocab: int, seed: int = 0, prefix: str = "data"
                       ) -> List[str]:
    """Synthesize a token dataset as shard files on the shared FS."""
    rng = np.random.default_rng(seed)
    paths = []
    for i in range(n_shards):
        toks = rng.integers(0, vocab, tokens_per_shard, dtype=np.int32)
        path = f"{prefix}/shard_{i:04d}.bin"
        fabric.fs.put(path, toks.view(np.uint8))
        paths.append(path)
    return paths


@dataclass
class StagedLoader:
    fabric: Fabric
    pattern: str
    batch: int
    seq: int
    host_id: int = 0
    staging_time: float = 0.0
    _data: Optional[np.ndarray] = None

    def stage(self, collective: bool = True, config=None) -> StagingReport:
        """Stage the shard manifest through the unified client; returns the
        staging report (simulated time). `config` is an optional typed
        engine config (`repro.core.api`); the legacy ``collective``
        boolean maps to Collective/NaiveConfig when `config` is None."""
        if config is None:
            config = CollectiveConfig() if collective else NaiveConfig()
        spec = StagingSpec([BroadcastEntry(files=(self.pattern,), pin=True)])
        res = StagingClient(self.fabric).stage(spec, config)
        self.staging_time = res.total_time
        store = self.fabric.hosts[self.host_id].store
        blobs = [store.data[p] for p in sorted(res.resolved_files)]
        self._data = np.concatenate(blobs).view(np.int32)
        return res.reports[0]

    def batches(self, seed: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
        """Yield {tokens, labels} batches from node-local data."""
        if self._data is None:
            raise RuntimeError("call stage() first")
        rng = np.random.default_rng(seed)
        n_tok = self.batch * self.seq
        while True:
            start = int(rng.integers(0, max(1, len(self._data) - n_tok - 1)))
            window = self._data[start:start + n_tok].reshape(self.batch,
                                                             self.seq)
            toks = jnp.asarray(window)
            yield {"tokens": toks, "labels": toks}

"""Fault-tolerant training driver: checkpoint/restart, failure detection,
elastic rescale, straggler accounting.

The driver owns the outer loop. Failures are injected (or detected via the
heartbeat monitor) between steps; recovery = restore from the last complete
checkpoint, optionally onto a smaller mesh (elastic). On real clusters the
same hooks attach to the control plane; here they are exercised by tests
with simulated failures.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore


@dataclass
class HeartbeatMonitor:
    """Tracks worker liveness; a worker missing `timeout` seconds is dead."""
    n_workers: int
    timeout: float = 10.0
    last_seen: Dict[int, float] = field(default_factory=dict)

    def beat(self, worker: int, now: float) -> None:
        self.last_seen[worker] = now

    def dead_workers(self, now: float) -> List[int]:
        return [w for w in range(self.n_workers)
                if now - self.last_seen.get(w, now) > self.timeout]


@dataclass
class DriverReport:
    steps_completed: int = 0
    restarts: int = 0
    rescales: int = 0
    losses: List[float] = field(default_factory=list)
    checkpoints: List[int] = field(default_factory=list)


class TrainDriver:
    """Outer training loop with checkpoint/restart + elastic rescale.

    `build_step(mesh_spec) -> (step_fn, state)` lets the driver rebuild the
    computation after a rescale. `failure_schedule` maps step -> event
    ("fail" = lose a node and restart from checkpoint; "rescale" = shrink).
    """

    def __init__(self, store: CheckpointStore,
                 build_step: Callable[[Dict], Any],
                 checkpoint_every: int = 10,
                 failure_schedule: Optional[Dict[int, str]] = None):
        self.store = store
        self.build_step = build_step
        self.checkpoint_every = checkpoint_every
        self.failure_schedule = failure_schedule or {}
        self.report = DriverReport()

    def run(self, total_steps: int, mesh_spec: Dict) -> DriverReport:
        step_fn, state = self.build_step(mesh_spec)
        start = 0
        # resume if a checkpoint exists
        latest = self.store.latest_step()
        if latest is not None:
            state = self._restore(state, latest)
            start = latest
        step = start
        while step < total_steps:
            event = self.failure_schedule.get(step)
            if event == "fail":
                # node loss mid-step: restart from last complete checkpoint
                self.report.restarts += 1
                del self.failure_schedule[step]
                latest = self.store.latest_step() or 0
                step_fn, state = self.build_step(mesh_spec)
                if self.store.latest_step() is not None:
                    state = self._restore(state, latest)
                step = latest
                continue
            if event == "rescale":
                # elastic: shrink the mesh, reshard from checkpoint
                self.report.rescales += 1
                del self.failure_schedule[step]
                mesh_spec = dict(mesh_spec)
                mesh_spec["n_devices"] = max(1, mesh_spec.get(
                    "n_devices", jax.device_count()) // 2)
                self.store.wait()
                latest = self.store.latest_step() or 0
                step_fn, state = self.build_step(mesh_spec)
                if self.store.latest_step() is not None:
                    state = self._restore(state, latest)
                step = latest
                continue
            state, metrics = step_fn(state)
            self.report.losses.append(float(metrics["loss"]))
            step += 1
            self.report.steps_completed += 1
            if step % self.checkpoint_every == 0:
                self.store.wait()
                self.store.save_async(step, self._snapshot(state))
                self.report.checkpoints.append(step)
        self.store.wait()
        return self.report

    @staticmethod
    def _snapshot(state: Any) -> Any:
        return state

    def _restore(self, template: Any, step: int) -> Any:
        return self.store.restore(template, step)

"""AdamW with f32 master weights + moments, warmup-cosine schedule,
global-norm clipping. Pure JAX (no optax dependency).

Memory layout matters at scale: master/m/v are f32 and inherit the param
sharding (FSDP x TP), so qwen2-72b optimizer state (~864 GB) spreads over
all 256 chips/pod (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(opt: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, opt.warmup_steps)
    decay_steps = jnp.maximum(1.0, opt.total_steps - opt.warmup_steps)
    frac = jnp.clip((step - opt.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = opt.min_lr_ratio + (1 - opt.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return opt.peak_lr * jnp.where(step < opt.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 opt: OptConfig) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    """One AdamW step. grads in f32 (already clipped). Returns
    (bf16-or-param-dtype params, new state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(opt, step)
    b1t = 1 - opt.b1 ** step.astype(jnp.float32)
    b2t = 1 - opt.b2 ** step.astype(jnp.float32)

    def upd(m, v, g, master):
        m = opt.b1 * m + (1 - opt.b1) * g
        v = opt.b2 * v + (1 - opt.b2) * jnp.square(g)
        mhat = m / b1t
        vhat = v / b2t
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + opt.eps)
                                    + opt.weight_decay * master)
        return m, v, new_master

    flat_m, tdef = jax.tree.flatten(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_g = jax.tree.leaves(grads)
    flat_master = jax.tree.leaves(state["master"])
    new_m, new_v, new_master = [], [], []
    for m, v, g, ms in zip(flat_m, flat_v, flat_g, flat_master):
        a, b, c = upd(m, v, g.astype(jnp.float32), ms)
        new_m.append(a); new_v.append(b); new_master.append(c)
    new_state = {
        "step": step,
        "master": jax.tree.unflatten(tdef, new_master),
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
    }
    new_params = jax.tree.map(lambda ms, p: ms.astype(p.dtype),
                              new_state["master"], params)
    return new_params, new_state, {"lr": lr}

"""Training step factory: grad accumulation over microbatches, remat,
AdamW, optional int8 error-feedback compression of the cross-pod (DCN)
gradient reduction.

Two lowering modes:
  * plain pjit — XLA auto-partitions everything; gradient reduction over
    ("pod","data") is inserted by the partitioner (baseline).
  * pod-manual — shard_map manual on the "pod" axis, auto on (data, model):
    grads come out per-pod; the pod hop is an explicit int8-compressed
    all-reduce (4x fewer DCN bytes), with error feedback carried in the
    optimizer state. This is the beyond-paper distributed-optimization trick
    (DESIGN.md §8) applied to the paper's locality principle.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardCtx
from repro.models import model as M
from repro.train import compression as comp
from repro.train.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state)


def _split_microbatches(batch: Dict[str, jax.Array], n_mb: int):
    def split(x):
        return jnp.moveaxis(
            x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]), 0, 0)
    return jax.tree.map(split, batch)


def grads_and_loss(params, cfg: ModelConfig, batch, shape: ShapeConfig,
                   ctx: Optional[ShardCtx], kernel_fn=None):
    """Mean grads over the (possibly microbatched) global batch, in f32."""
    def lf(p, mb):
        loss, metrics = M.loss_fn(p, cfg, mb, remat=shape.remat,
                                  kernel_fn=kernel_fn, ctx=ctx)
        return loss, metrics

    if shape.num_microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            params, batch)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return grads, loss, metrics

    n_mb = shape.num_microbatches
    mbs = _split_microbatches(batch, n_mb)

    def body(carry, mb):
        g_acc, l_acc = carry
        (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(params, mb)
        g_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / n_mb, g_acc, grads)
        return (g_acc, l_acc + loss / n_mb), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                    mbs)
    return grads, loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}


def make_train_step(cfg: ModelConfig, shape: ShapeConfig, opt: OptConfig,
                    ctx: Optional[ShardCtx] = None, kernel_fn=None,
                    compress_dcn: bool = False
                    ) -> Callable[..., Tuple[Any, Any, Dict[str, jax.Array]]]:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). When compress_dcn and the mesh has a 'pod' axis, the pod-axis
    gradient hop is int8-compressed with error feedback."""

    if not compress_dcn or ctx is None or "pod" not in ctx.mesh.axis_names:
        def train_step(params, opt_state, batch):
            grads, loss, metrics = grads_and_loss(params, cfg, batch, shape,
                                                  ctx, kernel_fn)
            grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
            params, opt_state, om = adamw_update(params, grads, opt_state, opt)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm, **om}
        return train_step

    mesh = ctx.mesh
    inner_ctx = ShardCtx(mesh=mesh, dp_axes=("data",),
                         fsdp_axis=ctx.fsdp_axis, tp_axis=ctx.tp_axis,
                         sequence_parallel=ctx.sequence_parallel)

    def train_step(params, opt_state, batch):
        def pod_body(params, opt_state, batch):
            # per-pod grads (auto-partitioned over data/model inside)
            grads, loss, metrics = grads_and_loss(
                params, cfg, batch, shape, inner_ctx, kernel_fn)
            # explicit compressed DCN hop with error feedback
            errs = opt_state["dcn_error"]
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = jax.tree.leaves(errs)
            new_g, new_e = [], []
            for g, e in zip(flat_g, flat_e):
                tgt = g + e
                q, scale = comp.quantize_int8(tgt)
                new_e.append(tgt - comp.dequantize_int8(q, scale))
                qs = jax.lax.all_gather(q, "pod")          # int8 on the wire
                ss = jax.lax.all_gather(scale, "pod")
                red = jnp.tensordot(ss, qs.astype(jnp.float32), axes=(0, 0))
                new_g.append(red / mesh.shape["pod"])
            grads = jax.tree.unflatten(tdef, new_g)
            grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
            params, new_state, om = adamw_update(params, grads, opt_state, opt)
            # adamw_update builds a fresh state dict: re-attach the error-
            # feedback residuals
            new_state["dcn_error"] = jax.tree.unflatten(tdef, new_e)
            loss = jax.lax.pmean(loss, "pod")
            return params, new_state, {"loss": loss, "grad_norm": gnorm, **om}

        pspec = P()            # params replicated w.r.t. pod (sharded inside)
        batch_spec = jax.tree.map(lambda _: P("pod"), batch)
        fn = shard_map(
            pod_body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: pspec, params),
                      jax.tree.map(lambda _: pspec, opt_state),
                      batch_spec),
            out_specs=(jax.tree.map(lambda _: pspec, params),
                       jax.tree.map(lambda _: pspec, opt_state),
                       {"loss": P(), "grad_norm": P(), "lr": P()}),
            check_vma=False,
            axis_names={"pod"})      # manual over pod; data/model stay auto
        return fn(params, opt_state, batch)

    return train_step


def init_train_state(key, cfg: ModelConfig, opt: OptConfig,
                     compress_dcn: bool = False):
    params = M.init_model(key, cfg)
    opt_state = init_opt_state(params)
    if compress_dcn:
        opt_state["dcn_error"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, opt_state

"""Gradient compression for cross-pod (DCN) reduction: int8 quantization with
error feedback.

Rationale: intra-pod gradient reduce-scatter rides ICI (cheap); the POD-axis
all-reduce crosses the data-center network. Quantizing that hop to int8 cuts
DCN bytes 4x; error feedback keeps the scheme convergent (the quantization
residual is carried into the next step's gradient).

Implemented with shard_map over the pod axis: per-tensor symmetric int8
quantization -> all_gather of (int8 payload, f32 scale) -> local dequant-sum.
all_gather of int8 moves exactly the compressed bytes on the wire.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_residual(g: jax.Array, err: jax.Array
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback: quantize (g + carried error); return (q, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum(x: jax.Array, mesh: Mesh, axis: str = "pod") -> jax.Array:
    """int8-compressed all-reduce over `axis` (mean is NOT applied).

    x must be identically sharded on the non-`axis` mesh axes; inside the
    shard_map body each participant quantizes its local block, all-gathers
    the int8 payloads + scales over `axis`, and dequant-sums locally.
    """
    n = mesh.shape[axis]
    other = tuple(a for a in mesh.axis_names if a != axis)

    def body(local):
        q, scale = quantize_int8(local)
        qs = jax.lax.all_gather(q, axis)                 # (n, ...) int8 wire
        ss = jax.lax.all_gather(scale, axis)             # (n,) f32
        return jnp.tensordot(ss, qs.astype(jnp.float32), axes=(0, 0))

    spec = P(*([None] * x.ndim))
    fn = shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                   check_vma=False)
    return fn(x)


def compressed_grad_allreduce(grads: Any, errors: Any, mesh: Mesh,
                              axis: str = "pod") -> Tuple[Any, Any]:
    """Error-feedback int8 all-reduce of a grad pytree over the pod axis.
    Returns (reduced grads [mean], new error state)."""
    n = mesh.shape[axis]

    def one(g, e):
        tgt = g.astype(jnp.float32) + e
        q, scale = quantize_int8(tgt)
        new_e = tgt - dequantize_int8(q, scale)
        red = compressed_psum(dequantize_int8(q, scale), mesh, axis) / n
        return red, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return red, new_err


def init_error_state(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)

"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE.

27L d_model=2048 16H (kv via MLA latent) d_ff(expert)=1408 vocab=102400,
MoE 64 routed experts top-6 + 2 shared, MLA kv_lora=512
[arXiv:2405.04434; hf].

NOTE on the assignment line "2 shared+160 routed top-6": 160 routed is the
full DeepSeek-V2 config; V2-LITE has 64 routed experts (matching the
assignment's own "MoE 64e top-6"). We follow 64 routed + 2 shared, top-6.
First layer uses a dense FFN (d_ff 10944), per the published config.
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,             # MLA: all heads share the latent KV
    d_ff=1408,                 # per-expert hidden size (assigned d_ff)
    vocab=102400,
    head_dim=192,              # qk_nope(128) + qk_rope(64)
    attention="mla",
    causal=True,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                  num_shared_experts=2, shared_d_ff=2816,
                  norm_topk_prob=False, capacity_factor=1.25,
                  first_k_dense=1, dense_d_ff=10944),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434; hf",
)

"""internvl2-2b [vlm] — InternViT frontend (STUB) + InternLM2-1.8B backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 [arXiv:2404.16821; hf].
The vision tower is a modality frontend STUB: input_specs() provides
precomputed patch embeddings (InternViT-300M output dim 1024), projected by
the mlp1 connector and prepended to the text sequence.
"""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    head_dim=128,
    attention="gqa",
    causal=True,
    rope_theta=1e6,
    frontend=FrontendConfig(kind="vision_patches", feature_dim=1024,
                            num_prefix_tokens=256),
    source="arXiv:2404.16821; hf",
)

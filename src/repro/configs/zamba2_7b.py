"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified]. Zamba2 applies a SHARED transformer block
(full-rank weights shared across call sites, per-site LoRA deltas) every
`attn_every` Mamba2 blocks; we reproduce that pattern (attn_every=6 ->
14 shared-attn call sites over 81 mamba layers).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,          # MHA in the shared block (kv=32)
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    attention="gqa",
    causal=True,
    block_pattern="zamba_hybrid",
    block_kind="mamba2",
    attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    source="arXiv:2411.15242; unverified",
)

"""Architecture registry: maps --arch ids to ModelConfigs + shape cells."""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, smoke_variant

ARCH_IDS = [
    "internvl2_2b",
    "zamba2_7b",
    "qwen2_72b",
    "h2o_danube3_4b",
    "internlm2_20b",
    "qwen3_32b",
    "hubert_xlarge",
    "qwen3_moe_30b_a3b",
    "deepseek_v2_lite_16b",
    "rwkv6_3b",
]

# canonical external ids (with dashes) also accepted on the CLI
_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES["h2o-danube-3-4b"] = "h2o_danube3_4b"  # assigned spelling


def canonical(arch: str) -> str:
    """Resolve dashed/underscored arch spellings to the canonical id."""
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return arch


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return smoke_variant(get_config(arch))


def supported_shapes(cfg: ModelConfig) -> List[str]:
    """Which assigned shape cells are runnable for this arch.

    Skip rules (documented in DESIGN.md §Arch-applicability):
      - encoder-only (causal=False): no decode step -> skip decode_32k, long_500k
      - long_500k needs sub-quadratic context: run for ssm / hybrid /
        sliding-window archs only.
    """
    shapes = ["train_4k", "prefill_32k"]
    if cfg.causal:
        shapes.append("decode_32k")
        sub_quadratic = (
            cfg.block_kind in ("mamba2", "rwkv6")
            or cfg.block_pattern == "zamba_hybrid"
            or cfg.sliding_window > 0
        )
        if sub_quadratic:
            shapes.append("long_500k")
    return shapes


def all_cells() -> List[Tuple[str, str]]:
    """All runnable (arch, shape) cells."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in supported_shapes(cfg):
            cells.append((arch, s))
    return cells


def skipped_cells() -> List[Tuple[str, str, str]]:
    """(arch, shape, reason) for every documented skip."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        have = set(supported_shapes(cfg))
        for s in SHAPES:
            if s in have:
                continue
            if not cfg.causal:
                out.append((arch, s, "encoder-only: no decode step"))
            else:
                out.append((arch, s, "full attention: long_500k needs sub-quadratic context"))
    return out

"""rwkv6-3b [ssm] — "Finch": attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892; hf].
Time-mix (WKV6 recurrence, 40 heads of 64) + channel-mix FFN. O(1)-state
decode makes long_500k runnable.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                # d_model / head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    head_dim=64,
    attention="none",
    causal=True,
    block_kind="rwkv6",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, gate_lora=64),
    source="arXiv:2404.05892; hf",
)

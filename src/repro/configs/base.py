"""Architecture / run configuration schema.

Every assigned architecture is expressed as a ``ModelConfig``. The config is a
plain frozen dataclass (hashable, usable as a jit static argument) and fully
describes the model: block pattern (dense / moe / mamba2 / rwkv6 / hybrid),
attention flavor (GQA / MLA / SWA / bidirectional), and modality frontend.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    num_experts: int = 0               # routed experts
    top_k: int = 0
    expert_d_ff: int = 0               # per-expert FFN hidden size
    num_shared_experts: int = 0        # always-on shared experts (deepseek style)
    shared_d_ff: int = 0               # hidden size of the shared expert(s), total
    capacity_factor: float = 1.25      # dispatch capacity (GSPMD-style dense dispatch)
    norm_topk_prob: bool = True        # renormalize top-k router weights
    router_dtype: str = "float32"      # router math dtype (stability)
    first_k_dense: int = 0             # first k layers use a dense FFN instead (deepseek)
    dense_d_ff: int = 0                # d_ff of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0               # 0 => full-rank q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1                  # groups for B/C projections
    chunk: int = 128                   # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 "Finch" block configuration."""
    head_dim: int = 64
    decay_lora: int = 64               # low-rank data-dependent decay adapter
    mix_lora: int = 32                 # token-shift mixing adapter rank
    gate_lora: int = 64


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB ([vlm]/[audio]): precomputed embeddings in."""
    kind: str = "none"                 # none | vision_patches | audio_frames
    feature_dim: int = 0               # incoming precomputed embedding dim
    num_prefix_tokens: int = 0         # vision: image tokens prepended to text


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 => d_model // n_heads
    # --- attention flavor ---
    attention: str = "gqa"             # gqa | mla | none
    causal: bool = True                # False => encoder-only (bidirectional)
    sliding_window: int = 0            # 0 => full attention; >0 => SWA window
    qk_norm: bool = False              # qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False             # qwen2-style bias on q,k,v projections
    rope_theta: float = 1e6
    # --- block pattern ---
    block_pattern: str = "uniform"     # uniform | zamba_hybrid
    attn_every: int = 0                # zamba: shared attn block every k mamba blocks
    block_kind: str = "attn_mlp"       # attn_mlp | mamba2 | rwkv6
    # --- sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- notes ---
    source: str = ""                   # provenance tag from the assignment table

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        """Active (per-token) params: MoE counts shared + top_k experts only."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                          # train | prefill | decode
    seq_len: int
    global_batch: int
    # training-only knobs
    num_microbatches: int = 1          # grad-accumulation microbatches
    remat: bool = True


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def with_overrides(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, **kw)


VOCAB_PAD_MULTIPLE = 256


def padded_vocab(vocab: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    """Megatron-style vocab padding: embedding/head tables are padded to a
    multiple of 256 so the vocab dim shards cleanly over tp; pad logits are
    masked to -inf in the loss/sampler."""
    return ((vocab + multiple - 1) // multiple) * multiple


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests.

    Small layers/width/experts/vocab as the instructions require; preserves the
    structural features (GQA ratio, MLA ranks scaled, MoE routing, hybrid
    pattern) so the smoke test exercises the same code paths.
    """
    n_heads = max(4, min(cfg.n_heads, 4))
    ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    n_kv = max(1, n_heads // ratio)
    kw = dict(
        n_layers=min(cfg.n_layers, 4) if cfg.block_pattern == "uniform" else 7,
        d_model=128,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=256,
        vocab=512,
        head_dim=32,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.sliding_window:
        kw["sliding_window"] = 16
    if cfg.attn_every:
        kw["attn_every"] = 3
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=64,
            shared_d_ff=64 if cfg.moe.num_shared_experts else 0,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            dense_d_ff=128 if cfg.moe.first_k_dense else 0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            kv_lora_rank=32, q_lora_rank=0,
            qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32, chunk=32)
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_dim=32, decay_lora=16, mix_lora=8, gate_lora=16)
    if cfg.frontend.kind != "none":
        kw["frontend"] = dataclasses.replace(
            cfg.frontend, feature_dim=64,
            num_prefix_tokens=min(cfg.frontend.num_prefix_tokens, 8) or 0,
        )
    return with_overrides(cfg, **kw)

"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768
vocab=151936, 128 experts top-8, qk_norm [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                 # per-expert hidden size (assigned d_ff)
    vocab=151936,
    head_dim=128,
    attention="gqa",
    qk_norm=True,
    causal=True,
    rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768,
                  norm_topk_prob=True, capacity_factor=1.25),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

"""hubert-xlarge [audio] — encoder-only transformer backbone (w2v2 arch).

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 [arXiv:2106.07447;
unverified]. The CNN waveform feature extractor is a modality frontend STUB:
input_specs() provides precomputed frame features (dim 512) which the stub
projection maps to d_model. Training objective: masked-prediction over 504
cluster targets. Encoder-only => no decode shapes.
"""
from repro.configs.base import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    attention="gqa",
    causal=False,              # bidirectional encoder
    frontend=FrontendConfig(kind="audio_frames", feature_dim=512),
    source="arXiv:2106.07447; unverified",
)

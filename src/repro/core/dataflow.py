"""Swift-style implicitly-parallel dataflow (paper §III, Figs. 4/5).

Futures + deferred task graph. Building blocks:
  * ``Dataflow.task(fn, *deps)``  -> Future (a node in the DAG)
  * ``Dataflow.foreach(fn, xs)``  -> list of Futures (the map phase)
  * ``Dataflow.merge_pairwise``   -> recursive pairwise reduction (Fig. 4's
    merge(), including the no-barrier property: merges become eligible as
    soon as their two inputs are ready, while other maps still run)
  * ``Dataflow.frame_task(fn, record)`` -> a node keyed to a streamed
    detector frame (`repro.core.streaming.FrameRecord`): it becomes
    eligible the moment the frame lands on the node-local stores
    (``record.t_avail``), while acquisition is still in flight.
  * ``Dataflow(fabric, stage=...)`` -> the graph declares its input
    dataset ONCE (a `repro.core.api.StagingSpec`, a glob pattern, or a
    pattern list, with an optional typed engine config via
    ``stage_config``); :meth:`Dataflow.run` has the unified
    `repro.core.api.StagingClient` stage it before execution, and no
    task starts before the staged replicas are resident (the I/O-hook
    discipline, expressed at graph level).

Execution is delegated to the ManyTaskEngine (simulated time + optional real
payloads), preserving dataflow ordering.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.fabric import Fabric
from repro.core.manytask import EngineStats, ManyTaskEngine, Task


@dataclass
class Future:
    """A dataflow value: closed over by downstream tasks."""
    task_id: int
    graph: "Dataflow"

    def result(self) -> Any:
        if not self.graph.executed:
            raise RuntimeError("graph not executed yet")
        return self.graph._results[self.task_id]


class Dataflow:
    def __init__(self, fabric: Fabric, stage: Any = None,
                 stage_config: Any = None, **engine_kw):
        self.fabric = fabric
        self.engine_kw = engine_kw
        self._tasks: List[Task] = []
        self._fns: Dict[int, Callable] = {}
        self._results: Dict[int, Any] = {}
        self.executed = False
        # declared-once staged inputs: spec/pattern(s) + typed engine config
        self._stage = stage
        self._stage_config = stage_config
        self.stage_report = None     # repro.core.api.Report after run()

    # -- graph construction -------------------------------------------------
    def task(self, fn: Callable[..., Any], *args: Any,
             duration: Optional[float] = None,
             inputs: Sequence[str] = (),
             not_before: float = 0.0) -> Future:
        """Add a node. `args` may contain Futures (become dependencies).
        `not_before` (simulated s) delays eligibility — the frame-future
        hook: a task keyed to a streamed frame passes its ``t_avail``."""
        tid = len(self._tasks)
        deps = tuple(a.task_id for a in args if isinstance(a, Future))

        def thunk(tid=tid, fn=fn, args=args):
            concrete = [self._results[a.task_id] if isinstance(a, Future)
                        else a for a in args]
            out = fn(*concrete)
            self._results[tid] = out
            return out

        self._tasks.append(Task(task_id=tid, fn=thunk, duration=duration,
                                deps=deps, inputs=tuple(inputs),
                                not_before=not_before))
        return Future(tid, self)

    def frame_task(self, fn: Callable[..., Any], frame: Any, *args: Any,
                   duration: Optional[float] = None) -> Future:
        """Node keyed to a streamed frame future (`FrameRecord`-shaped:
        needs ``.path`` and ``.t_avail``): eligible the moment the frame is
        resident on the node-local stores, with the frame file as its
        locality input. ``fn`` receives the record as its first argument."""
        return self.task(fn, frame, *args, duration=duration,
                         inputs=(frame.path,), not_before=frame.t_avail)

    def foreach(self, fn: Callable[[Any], Any], xs: Sequence[Any],
                durations: Optional[Sequence[float]] = None,
                inputs_of: Optional[Callable[[Any], Sequence[str]]] = None,
                not_befores: Optional[Sequence[float]] = None
                ) -> List[Future]:
        """Swift `foreach`: independent, concurrent, load-balanced.
        `not_befores` optionally staggers eligibility per element
        (frame-future streaming of the map phase)."""
        futs = []
        for i, x in enumerate(xs):
            d = durations[i] if durations is not None else None
            ins = tuple(inputs_of(x)) if inputs_of else ()
            nb = not_befores[i] if not_befores is not None else 0.0
            futs.append(self.task(fn, x, duration=d, inputs=ins,
                                  not_before=nb))
        return futs

    def merge_pairwise(self, merge_fn: Callable[[Any, Any], Any],
                       futures: Sequence[Future],
                       duration: Optional[float] = None) -> Future:
        """Fig. 4's recursive pairwise merge — no barrier with the map phase:
        each merge depends only on its two inputs."""
        level = list(futures)
        if not level:
            raise ValueError("nothing to merge")
        while len(level) > 1:
            nxt = []
            for i in range(0, len(level) - 1, 2):
                nxt.append(self.task(merge_fn, level[i], level[i + 1],
                                     duration=duration))
            if len(level) % 2:
                nxt.append(level[-1])
            level = nxt
        return level[0]

    # -- execution -----------------------------------------------------------
    def run(self, n_workers: Optional[int] = None) -> EngineStats:
        if self._stage is not None and self.stage_report is None:
            from repro.core.api import StagingClient
            self.stage_report = StagingClient(self.fabric).stage(
                self._stage, self._stage_config)
            # staged inputs gate the whole graph: nothing starts before
            # the replicas are resident on the node-local stores
            t_staged = self.stage_report.total_time
            for task in self._tasks:
                task.not_before = max(task.not_before, t_staged)
        engine = ManyTaskEngine(self.fabric, n_workers=n_workers,
                                **self.engine_kw)
        stats = engine.run(self._tasks)
        self.executed = True
        return stats

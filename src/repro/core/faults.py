"""Deterministic fault injection for the simulated fabric.

The paper's residency claim — datasets live in compute-node memory "for
extended periods" — only matters if residency survives the failures a real
machine throws at it over those periods.  This module is the single source
of truth for *what goes wrong and when*: a seeded, deterministic
:class:`FaultSchedule` of host deaths, host recoveries, and link-tier
degradation windows.  Nothing in here moves bytes or advances time; the
schedule is a pure queryable timeline that the rest of the stack consults:

- `repro.core.fabric.Fabric.advance_faults` applies state-changing events
  (a host death wipes that host's node-local store, pins included);
- `repro.core.fabric.Interconnect` plans collectives at time ``t`` over the
  *live* host set and under per-tier degraded bandwidth
  (`repro.core.topology.Topology.degraded`);
- `repro.core.datasvc.StagingService.sync_faults` turns host deaths into
  catalog DEGRADED transitions and drives repair.

Everything is reproducible: the same seed and parameters always produce the
same schedule, and an empty schedule (``FaultSchedule()``) is *trivial* —
every consumer short-circuits to the exact PR 5 code path, keeping the
zero-fault byte and time accounting bit-exact.
"""
from __future__ import annotations

import bisect
import enum
import math
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class FaultKind(str, enum.Enum):
    """What kind of fault an event injects."""
    HOST_DEATH = "host_death"        # node-local memory wiped at t
    HOST_RECOVERY = "host_recovery"  # host rejoins (blank store) at t
    LINK_DEGRADE = "link_degrade"    # tier bandwidth scaled on [t, t_end)


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One point (or window) on the fault timeline.

    ``host`` is required for death/recovery; ``tier``/``t_end``/``factor``
    describe a degradation window: the named link tier runs at
    ``factor * bandwidth`` for ``t <= now < t_end``.  ``factor == 0`` is a
    partition (the tier carries no traffic; plans over it diverge)."""
    t: float
    kind: FaultKind = field(compare=False)
    host: Optional[int] = field(default=None, compare=False)
    tier: Optional[str] = field(default=None, compare=False)
    t_end: float = field(default=math.inf, compare=False)
    factor: float = field(default=1.0, compare=False)

    def __post_init__(self) -> None:
        if self.t < 0:
            raise ValueError(f"fault time must be >= 0, got {self.t}")
        if self.kind in (FaultKind.HOST_DEATH, FaultKind.HOST_RECOVERY):
            if self.host is None or self.host < 0:
                raise ValueError(f"{self.kind.value} needs a host id >= 0")
        elif self.kind is FaultKind.LINK_DEGRADE:
            if not self.tier:
                raise ValueError("link_degrade needs a tier name")
            if not 0.0 <= self.factor <= 1.0:
                raise ValueError(
                    f"degradation factor must be in [0, 1], got {self.factor}")
            if self.t_end <= self.t:
                raise ValueError("degradation window must have t_end > t")


@dataclass
class FaultSchedule:
    """A sorted, queryable timeline of :class:`FaultEvent`.

    Queries are pure functions of (events, t): :meth:`dead_hosts` is the set
    of hosts dead *at* ``t`` (death at or before ``t`` with no later
    recovery at or before ``t``); :meth:`tier_factor` is the product of all
    degradation windows covering ``t`` for a tier.  :meth:`inject` keeps the
    timeline sorted so mid-run injection (``client.inject``) composes with a
    pre-built schedule."""
    events: List[FaultEvent] = field(default_factory=list)

    def __post_init__(self) -> None:
        self.events = sorted(self.events)

    @property
    def trivial(self) -> bool:
        """True when the schedule can never perturb anything — consumers
        use this to take the exact pre-fault (PR 5) code path."""
        return not self.events

    def inject(self, event: FaultEvent) -> FaultEvent:
        """Insert `event` keeping the timeline sorted; returns it."""
        bisect.insort(self.events, event)
        return event

    # -- queries ---------------------------------------------------------
    def dead_hosts(self, t: float) -> FrozenSet[int]:
        """Hosts dead at simulated time `t`."""
        dead: set = set()
        for ev in self.events:
            if ev.t > t:
                break
            if ev.kind is FaultKind.HOST_DEATH:
                dead.add(ev.host)
            elif ev.kind is FaultKind.HOST_RECOVERY:
                dead.discard(ev.host)
        return frozenset(dead)

    def n_dead(self, t: float, n_hosts: Optional[int] = None) -> int:
        """Count of dead hosts at `t`, optionally only those < n_hosts."""
        dead = self.dead_hosts(t)
        if n_hosts is not None:
            return sum(1 for h in dead if h < n_hosts)
        return len(dead)

    def is_dead(self, host: int, t: float) -> bool:
        return host in self.dead_hosts(t)

    def tier_factor(self, tier: str, t: float) -> float:
        """Bandwidth multiplier for `tier` at `t` (1.0 = healthy).

        Overlapping windows compound multiplicatively — two independent
        half-rate brownouts leave a quarter of the bandwidth."""
        f = 1.0
        for ev in self.events:
            if ev.t > t:
                break
            if (ev.kind is FaultKind.LINK_DEGRADE and ev.tier == tier
                    and t < ev.t_end):
                f *= ev.factor
        return f

    def tier_factors(self, tiers: Iterable[str], t: float
                     ) -> Dict[str, float]:
        """Non-trivial (!= 1.0) multipliers at `t`, keyed by tier name."""
        out: Dict[str, float] = {}
        for name in tiers:
            f = self.tier_factor(name, t)
            if f != 1.0:
                out[name] = f
        return out

    # -- constructors ----------------------------------------------------
    @classmethod
    def random(cls, seed: int, n_hosts: int, horizon: float, *,
               n_deaths: int = 1, recover_after: Optional[float] = None,
               n_degradations: int = 0,
               tiers: Sequence[str] = ("intra",),
               factor_range: Tuple[float, float] = (0.25, 0.75),
               window: Optional[float] = None) -> "FaultSchedule":
        """Seeded random schedule — same arguments, same timeline, always.

        Draws `n_deaths` distinct victims with death times uniform on
        (0, horizon); each recovers ``recover_after`` later when set.
        Draws `n_degradations` windows of length ``window`` (default
        horizon/4) on round-robin tiers with factors uniform in
        `factor_range`."""
        if n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        victims = rng.choice(n_hosts, size=min(n_deaths, n_hosts),
                             replace=False)
        for h in victims:
            t = float(rng.uniform(0.0, horizon))
            events.append(FaultEvent(t, FaultKind.HOST_DEATH, host=int(h)))
            if recover_after is not None:
                events.append(FaultEvent(t + recover_after,
                                         FaultKind.HOST_RECOVERY,
                                         host=int(h)))
        win = horizon / 4.0 if window is None else window
        for i in range(n_degradations):
            t0 = float(rng.uniform(0.0, max(horizon - win, 0.0) or horizon))
            f = float(rng.uniform(*factor_range))
            events.append(FaultEvent(t0, FaultKind.LINK_DEGRADE,
                                     tier=tiers[i % len(tiers)],
                                     t_end=t0 + win, factor=f))
        return cls(events)

    @classmethod
    def wan_jitter(cls, seed: int, horizon: float, *, tier: str = "wan",
                   n_windows: int = 8,
                   factor_range: Tuple[float, float] = (0.3, 0.9),
                   window: Optional[float] = None) -> "FaultSchedule":
        """Seeded WAN weather: transient degradation windows on one tier.

        Models the bandwidth jitter a cross-facility ingest link sees —
        `n_windows` short brownouts with start times uniform on
        ``(0, horizon - window)`` and factors uniform in `factor_range`,
        all on the named `tier` (default ``"wan"``, the
        ``wan_beamline`` ingest tier).  Window length defaults to
        ``horizon / (2 * n_windows)`` so roughly half the horizon is
        degraded; overlapping windows compound multiplicatively like any
        other degradation (:meth:`tier_factor`).

        Jitter is *weather*, not an outage: `factor_range` must stay
        strictly above 0 — a zero factor is a partition
        (`repro.core.collectives.LinkPartitionedError`) and must be
        injected explicitly, never drawn by accident from a seed.
        Same arguments, same timeline, always."""
        if horizon <= 0:
            raise ValueError(f"horizon must be > 0, got {horizon}")
        if n_windows < 1:
            raise ValueError(f"n_windows must be >= 1, got {n_windows}")
        lo, hi = factor_range
        if not 0.0 < lo <= hi <= 1.0:
            raise ValueError(
                "jitter factor_range must satisfy 0 < lo <= hi <= 1 "
                f"(0 is a partition, not jitter), got {factor_range}")
        win = horizon / (2.0 * n_windows) if window is None else window
        if win <= 0:
            raise ValueError(f"window must be > 0, got {win}")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for _ in range(n_windows):
            t0 = float(rng.uniform(0.0, max(horizon - win, 0.0) or horizon))
            f = float(rng.uniform(lo, hi))
            events.append(FaultEvent(t0, FaultKind.LINK_DEGRADE, tier=tier,
                                     t_end=t0 + win, factor=f))
        return cls(events)

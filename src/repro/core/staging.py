"""Collective data staging — the paper's key contribution, both fabrics.

Host-level (``stage_collective`` / ``stage_naive``): the MPI-IO
``MPI_File_read_all`` two-phase pattern over the simulated fabric. Leaders
read disjoint 1/P stripes (aggregate FS traffic = 1x the dataset, at the
coordinated sequential rate), then a ring all-gather replicates stripes to
every node-local store. The naive baseline has every host read the full
dataset uncoordinated — the paper's measured 21 GB/s vs 101 GB/s regime.

Device-level (``device_replicate`` / ``device_shard``): the same algorithm
expressed on the JAX mesh with shard_map + lax.all_gather. Each process
contributes its 1/P shard; the all-gather rides ICI. Used by checkpoint
restore and the input pipeline; testable on CPU fake devices.

Both byte-exact: tests assert staged replicas equal the source.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fabric import Fabric


@dataclass
class StagingReport:
    """Timing/traffic accounting for one staging operation (one dataset)."""
    n_hosts: int
    total_bytes: int              # dataset bytes (pre-replication)
    stage_time: float = 0.0       # FS read phase (simulated s)
    comm_time: float = 0.0        # interconnect replication phase
    write_time: float = 0.0       # node-local write phase
    fs_bytes: int = 0             # bytes actually read from shared FS
    net_bytes: int = 0            # bytes moved on the interconnect

    @property
    def total_time(self) -> float:
        return self.stage_time + self.comm_time + self.write_time

    @property
    def delivered_bandwidth(self) -> float:
        """Aggregate delivery rate: replicated bytes / time (Fig. 10 metric)."""
        if self.total_time == 0:
            return 0.0
        return self.n_hosts * self.total_bytes / self.total_time


def _stripes(total: int, parts: int) -> List[Tuple[int, int]]:
    """Contiguous (offset, size) stripes covering [0, total)."""
    base, rem = divmod(total, parts)
    out, off = [], 0
    for i in range(parts):
        sz = base + (1 if i < rem else 0)
        out.append((off, sz))
        off += sz
    return out


# ---------------------------------------------------------------------------
# host-level staging (fabric)
# ---------------------------------------------------------------------------

def stage_collective(fabric: Fabric, paths: Sequence[str],
                     t0: float = 0.0) -> Tuple[StagingReport, float]:
    """MPI_File_read_all-style staging of `paths` to every node-local store.

    Phase 1 (Staging): leaders read disjoint stripes — coordinated.
    Phase 2 (Write):   ring all-gather + local write -> full replica per node.
    Returns (report, completion time).
    """
    P_ = fabric.n_hosts
    c = fabric.constants
    fs0 = fabric.fs.bytes_read
    net0 = fabric.net.bytes_moved
    total = sum(fabric.fs.size(p) for p in paths)
    rep = StagingReport(n_hosts=P_, total_bytes=total)

    # per-file MPI_File_read_all sync overhead grows ~log2(P)
    coll_overhead = c.coll_latency_base + c.coll_latency_log * max(
        0.0, math.log2(max(P_, 2)))
    t_read_done = t0
    for path in paths:
        size = fabric.fs.size(path)
        t_file = t0
        for i, (off, sz) in enumerate(_stripes(size, P_)):
            # stripes are issued concurrently; FS serializes bandwidth only
            _, t_done = fabric.fs.read(path, off, sz, t0, coordinated=True)
            t_file = max(t_file, t_done)
        t_read_done = max(t_read_done, t_file) + coll_overhead
    rep.stage_time = t_read_done - t0

    # phase 2: ring all-gather of the (max) stripe, all hosts in parallel
    stripe_bytes = max(1, (total + P_ - 1) // P_)
    t_comm = fabric.net.ring_allgather_time(stripe_bytes, P_)
    rep.comm_time = t_comm

    # reassemble and write replicas (hosts write in parallel -> max time)
    t_write = 0.0
    for path in paths:
        size = fabric.fs.size(path)
        blob = np.concatenate([fabric.fs.files[path][off:off + sz]
                               for off, sz in _stripes(size, P_)]) \
            if P_ > 1 else fabric.fs.files[path]
        for host in fabric.hosts:
            t_end = host.store.write(path, blob, 0.0)
            t_write = max(t_write, t_end)
    rep.write_time = t_write
    rep.fs_bytes = fabric.fs.bytes_read - fs0
    rep.net_bytes = fabric.net.bytes_moved - net0
    return rep, t0 + rep.total_time


def stage_naive(fabric: Fabric, paths: Sequence[str],
                t0: float = 0.0) -> Tuple[StagingReport, float]:
    """Baseline: every host independently reads each full file from the
    shared FS (uncoordinated — the congested regime), then writes locally."""
    P_ = fabric.n_hosts
    fs0 = fabric.fs.bytes_read
    total = sum(fabric.fs.size(p) for p in paths)
    rep = StagingReport(n_hosts=P_, total_bytes=total)
    t_done = t0
    for path in paths:
        size = fabric.fs.size(path)
        for host in fabric.hosts:
            # concurrent uncoordinated reads: bandwidth serializes on the
            # shared FS, per-request latency overlaps across hosts
            data, t_r = fabric.fs.read(path, 0, size, t0, coordinated=False)
            host.store.write(path, data, 0.0)
            t_done = max(t_done, t_r)
    rep.stage_time = t_done - t0
    rep.write_time = total / fabric.constants.local_bw
    rep.fs_bytes = fabric.fs.bytes_read - fs0
    return rep, t0 + rep.total_time


# ---------------------------------------------------------------------------
# device-level staging (JAX mesh) — shard + all-gather over ICI
# ---------------------------------------------------------------------------

def device_replicate(mesh: Mesh, x: jax.Array, axis: str = "data"
                     ) -> jax.Array:
    """Replicate `x` across `axis` given each participant holds 1/P of it.

    Input: x sharded P(axis) on its leading dim. Output: fully replicated.
    This is the staging all-gather: read-shards once, replicate over ICI —
    instead of every participant fetching the full buffer from storage.
    """
    axes = tuple(mesh.axis_names)
    spec_in = P(axis)
    spec_out = P()

    def body(shard):
        return jax.lax.all_gather(shard, axis, tiled=True)

    from jax import shard_map
    fn = shard_map(body, mesh=mesh, in_specs=(spec_in,), out_specs=spec_out,
                   check_vma=False)
    return jax.jit(fn)(x)


def device_shard(mesh: Mesh, x: jax.Array, spec: P) -> jax.Array:
    """Lay out a host buffer onto the mesh with the given PartitionSpec
    (the 'distribute' half of staging, for non-replicated targets)."""
    return jax.device_put(x, NamedSharding(mesh, spec))


def staged_restore(mesh: Mesh, shards: Dict[int, np.ndarray],
                   axis: str = "data") -> jax.Array:
    """Checkpoint-restore staging: process i contributes shard i (1/P of the
    array, leading dim); result is the replicated full array, assembled by
    all-gather rather than P full reads. Single-process simulation: shards
    are placed per-device then gathered."""
    order = sorted(shards)
    full = np.concatenate([shards[i] for i in order], axis=0)
    per_dev = jax.device_put(full, NamedSharding(mesh, P(axis)))
    return device_replicate(mesh, per_dev, axis)

"""Collective data staging — the paper's key contribution, both fabrics.

Host-level (``stage_collective`` / ``stage_pipelined`` / ``stage_naive``):
the MPI-IO ``MPI_File_read_all`` two-phase pattern over the simulated fabric.
Leaders read disjoint 1/P stripes (aggregate FS traffic = 1x the dataset, at
the coordinated sequential rate), then a planned all-gather (algorithm
selected by the fabric topology's `repro.core.collectives` planner — the
legacy ring on the FLAT machine) replicates stripes to every node-local
store. The naive baseline has every host read the full dataset
uncoordinated — the paper's measured 21 GB/s vs 101 GB/s regime. Every
engine takes ``topology=`` (any `repro.core.topology` spelling) to rebind
the machine model for that call; reports carry per-tier wire traffic.
``stage_pipelined`` chunks the two phases and overlaps stripe reads with
all-gather segments (double-buffered two-phase I/O), hiding most of the FS
read time behind the interconnect.

Replica delivery is zero-copy: a staged file's stripes are contiguous, so
the assembled replica IS the source buffer — every ``NodeLocalStore``
receives one shared read-only view instead of P concatenated copies. The
simulated-time accounting (per-host write bandwidth) is unchanged; only the
real memory traffic of the simulator goes away.

Device-level (``device_replicate`` / ``device_shard``): the same algorithm
expressed on the JAX mesh with shard_map + lax.all_gather. Each process
contributes its 1/P shard; the all-gather rides ICI. Used by checkpoint
restore and the input pipeline; testable on CPU fake devices.

All modes byte-exact: tests assert staged replicas equal the source.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.compression import CompressionLike, CompressionStats
from repro.core.fabric import Fabric
from repro.core.topology import TopologyLike


@dataclass
class StagingReport:
    """Timing/traffic accounting for one staging operation (one dataset)."""
    n_hosts: int
    total_bytes: int              # dataset bytes (pre-replication)
    stage_time: float = 0.0       # FS read phase (simulated s)
    comm_time: float = 0.0        # interconnect replication phase (exposed)
    write_time: float = 0.0       # node-local write phase
    broadcast_time: float = 0.0   # leader metadata-broadcast (on_root) phase
    fs_bytes: int = 0             # bytes actually read from shared FS
    fs_write_bytes: int = 0       # bytes written BACK to shared FS (stage_out)
    net_bytes: int = 0            # WIRE bytes moved on the interconnect
    # interconnect WIRE bytes per topology tier (e.g. {"torus": ...,
    # "optical": ...}; FLAT reports everything under "link") — sums to
    # net_bytes. With an active codec the wire count on elected tiers is
    # the COMPRESSED traffic; `comp` carries the payload-vs-wire split
    # (total_bytes/delivered bytes stay logical — payload — quantities).
    tier_bytes: Dict[str, int] = field(default_factory=dict)
    mode: str = "collective"      # collective|pipelined|naive|stream|stage_out
    n_chunks: int = 0             # pipelined: total all-gather segments
    overlap_saved: float = 0.0    # pipelined: phase time hidden by overlap
    # replicated engine / repair collectives: where the stripes live
    placement: Optional["ReplicaPlacement"] = None
    # codec accounting over the plans this stage executed (zero when no
    # codec was bound or no tier elected compression)
    comp: CompressionStats = field(default_factory=CompressionStats)

    @property
    def total_time(self) -> float:
        return (self.stage_time + self.comm_time + self.write_time
                + self.broadcast_time)

    @property
    def delivered_bandwidth(self) -> float:
        """Aggregate delivery rate: replicated bytes / time (Fig. 10 metric)."""
        if self.total_time == 0:
            return 0.0
        return self.n_hosts * self.total_bytes / self.total_time


def _stripes(total: int, parts: int) -> List[Tuple[int, int]]:
    """Contiguous (offset, size) stripes covering [0, total)."""
    base, rem = divmod(total, parts)
    out, off = [], 0
    for i in range(parts):
        sz = base + (1 if i < rem else 0)
        out.append((off, sz))
        off += sz
    return out


class ReplicaLossError(RuntimeError):
    """Repair cannot proceed from surviving replicas alone (a full
    re-stage from the shared FS is the only way back to RESIDENT)."""


class LostStripesError(ReplicaLossError):
    """Every owner of at least one stripe is dead — the striped dataset
    has no complete copy left on the nodes."""


@dataclass
class ReplicaPlacement:
    """Which hosts own which stripe of a striped, R-way replicated
    dataset (the ``stage_replicated`` engine).

    Stripe ``i`` of every file lives on ``owners[i]`` under the store key
    :meth:`stripe_key`. The default layout is chained declustering
    (:meth:`chained`): stripe ``i`` on hosts ``i .. i+R-1`` (mod P), so
    any single host death leaves R-1 surviving owners per affected
    stripe. Mutable on purpose — ``re_replicate`` reassigns ownership
    when it copies a lost stripe to a new host."""
    replication: int
    owners: Dict[int, Tuple[int, ...]]    # stripe index -> owner hosts

    @classmethod
    def chained(cls, hosts: Sequence[int], replication: int
                ) -> "ReplicaPlacement":
        """Chained-declustering layout over `hosts` (one stripe each)."""
        L = len(hosts)
        if not 1 <= replication <= L:
            raise ValueError(
                f"replication must be in [1, n_hosts={L}], "
                f"got {replication}")
        return cls(replication=replication,
                   owners={i: tuple(hosts[(i + r) % L]
                                    for r in range(replication))
                           for i in range(L)})

    @staticmethod
    def stripe_key(path: str, stripe: int) -> str:
        """Node-local store key of one stripe of `path`."""
        return f"{path}::s{stripe}"

    @property
    def n_stripes(self) -> int:
        return len(self.owners)

    def hosts(self) -> Tuple[int, ...]:
        """Every host owning at least one stripe, sorted."""
        return tuple(sorted({o for own in self.owners.values()
                             for o in own}))

    def stripes_on(self, host: int) -> List[int]:
        return [i for i, own in self.owners.items() if host in own]

    def lost(self, live: Sequence[int]) -> List[int]:
        """Stripes with NO surviving owner among `live` (unrepairable
        from node memory)."""
        alive = set(live)
        return [i for i, own in sorted(self.owners.items())
                if not any(o in alive for o in own)]

    def degraded(self, live: Sequence[int]) -> List[int]:
        """Stripes that lost at least one (but not every) owner."""
        alive = set(live)
        return [i for i, own in sorted(self.owners.items())
                if any(o not in alive for o in own)
                and any(o in alive for o in own)]

    def covered_by(self, holders: Sequence[int]) -> bool:
        """True when every stripe has ALL its owners in `holders` —
        full R-way redundancy intact."""
        hold = set(holders)
        return all(all(o in hold for o in own)
                   for own in self.owners.values())


def readonly_view(data: np.ndarray) -> np.ndarray:
    """Zero-copy read-only view of ``data`` — the replica-delivery discipline.

    Every consumer (node-local store, streamed-frame cache) receives a view
    of ONE shared buffer instead of a copy; the write guard keeps a store
    from mutating the source through it. Shared by the batch staging engines
    here and the streaming ingest path (`repro.core.streaming`).
    """
    view = data.view()
    view.setflags(write=False)
    return view


def _replica_view(fabric: Fabric, path: str) -> np.ndarray:
    """The assembled replica of a staged file, zero-copy.

    The P stripes of a file are contiguous and cover it exactly, so the
    reassembled replica is byte-identical to the source buffer: hand out one
    read-only view instead of materialising P (or even 1) concatenated
    copies. Read-only so a store cannot mutate the shared FS through it.
    """
    return readonly_view(fabric.fs.files[path])


def _deliver_replicas(fabric: Fabric, paths: Sequence[str],
                      t: Optional[float] = None) -> float:
    """Write one shared replica view per file to every LIVE node-local
    store (`t` is the delivery time consulted against the fault schedule;
    the trivial schedule delivers to every host — the pre-fault path).

    Hosts write in parallel (max across hosts); a host's files serialize on
    its local-store bandwidth (times ACCUMULATE across files — the seed took
    a max, undercounting multi-file staging).
    """
    replicas = {p: _replica_view(fabric, p) for p in paths}
    hosts = (fabric.hosts if fabric.faults.trivial
             else fabric.live_hosts(t))
    t_write = 0.0
    for host in hosts:
        t_write = max(t_write, host.store.write_many(replicas, 0.0))
    return t_write


# ---------------------------------------------------------------------------
# host-level staging (fabric)
# ---------------------------------------------------------------------------

def _coll_overhead(fabric: Fabric) -> float:
    """Per-file MPI_File_read_all sync overhead; grows ~log2(P)."""
    c = fabric.constants
    return c.coll_latency_base + c.coll_latency_log * max(
        0.0, math.log2(max(fabric.n_hosts, 2)))


def _close_stage_span(fabric: Fabric, sp, rep: StagingReport,
                      t0: float) -> None:
    """Finalize the engine-level telemetry span opened around one staging
    operation: sequential phase children partition ``[t0, t0+total_time)``
    exactly per the report's accounting identity (stage/comm/write/
    broadcast — so the flight recorder's critical-path breakdown sums to
    ``total_time`` by construction), report fields become span
    attributes, and the stage duration lands in the shared histogram.
    No-op on the disabled tracer; never changes the report."""
    tr = fabric.tracer
    if not tr.enabled:
        return
    read_phase = ("fs_write" if rep.mode.startswith("stage_out")
                  else "fs_read")
    t = t0
    for phase, dt in ((read_phase, rep.stage_time),
                      ("comm", rep.comm_time),
                      ("deliver", rep.write_time),
                      ("broadcast", rep.broadcast_time)):
        if dt > 0:
            tr.span(f"phase.{phase}", t, t + dt, track="engine", parent=sp)
        t += dt
    sp.t_end = t
    sp.attrs.update(n_hosts=rep.n_hosts, total_bytes=rep.total_bytes,
                    fs_bytes=rep.fs_bytes, fs_write_bytes=rep.fs_write_bytes,
                    net_bytes=rep.net_bytes, tier_bytes=dict(rep.tier_bytes))
    if rep.mode == "pipelined":
        sp.attrs.update(n_chunks=rep.n_chunks,
                        overlap_saved=rep.overlap_saved)
    tr.metrics.histogram("stage.total_s").observe(rep.total_time)
    tr.metrics.counter(f"stage.{rep.mode}").inc()


def stage_collective(fabric: Fabric, paths: Sequence[str], t0: float = 0.0,
                     topology: TopologyLike = None,
                     compression: CompressionLike = None
                     ) -> Tuple[StagingReport, float]:
    """MPI_File_read_all-style staging of `paths` to every node-local store.

    Phase 1 (Staging): leaders read disjoint stripes — coordinated.
    Phase 2 (Write):   planned all-gather + local write -> full replica per
    node (the algorithm comes from the fabric topology's collective
    planner; `topology` rebinds it for this call; `compression` binds a
    codec the planner may elect per tier). Returns (report, completion
    time).
    """
    with fabric.net.scoped_topology(topology), \
            fabric.net.scoped_codec(compression), \
            fabric.tracer.region("stage.collective", t0,
                                 track="engine") as tsp:
        P_ = fabric.n_hosts
        fs0 = fabric.fs.bytes_read
        net0 = fabric.net.bytes_moved
        tier0 = fabric.net.tier_snapshot()
        comp0 = fabric.net.comp_snapshot()
        total = sum(fabric.fs.size(p) for p in paths)
        rep = StagingReport(n_hosts=P_, total_bytes=total, mode="collective")

        coll_overhead = _coll_overhead(fabric)
        t_read_done = t0
        for path in paths:
            size = fabric.fs.size(path)
            # stripes are issued concurrently; FS serializes bandwidth only
            _, t_file = fabric.fs.read_striped(path, _stripes(size, P_), t0,
                                               coordinated=True)
            t_read_done = max(t_read_done, t_file) + coll_overhead
        rep.stage_time = t_read_done - t0

        # phase 2: all-gather of the (max) stripe, all hosts in parallel
        stripe_bytes = max(1, (total + P_ - 1) // P_)
        rep.comm_time = fabric.net.allgather(stripe_bytes, P_,
                                             t=t_read_done)

        rep.write_time = _deliver_replicas(fabric, paths,
                                           t=t_read_done + rep.comm_time)
        rep.fs_bytes = fabric.fs.bytes_read - fs0
        rep.net_bytes = fabric.net.bytes_moved - net0
        rep.tier_bytes = fabric.net.tier_delta(tier0)
        rep.comp = fabric.net.comp_delta(comp0)
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + rep.total_time


def stage_pipelined(fabric: Fabric, paths: Sequence[str], t0: float = 0.0,
                    chunk_bytes: int = 8 << 20,
                    topology: TopologyLike = None,
                    compression: CompressionLike = None
                    ) -> Tuple[StagingReport, float]:
    """Two-phase collective staging with chunked read/all-gather overlap.

    Each file's striped read is split into segments of ~``chunk_bytes`` per
    host; the all-gather of segment k (algorithm planned over the fabric
    topology, or `topology` for this call) runs while the leaders read
    segment k+1 (double-buffered two-phase I/O). The critical path is

        t_comm[k] = max(t_comm[k-1], t_read[k]) + allgather(seg_k)

    so all but the first segment's FS time hides behind the interconnect
    (or vice versa, whichever is slower). ``overlap_saved`` reports the
    serial-phase time hidden. Delivered replicas and FS byte accounting are
    identical to ``stage_collective``; ``net_bytes`` can exceed it by up to
    P * n_chunks bytes of per-segment ceil-rounding in the stripe sizes.
    """
    with fabric.net.scoped_topology(topology), \
            fabric.net.scoped_codec(compression), \
            fabric.tracer.region("stage.pipelined", t0,
                                 track="engine") as tsp:
        P_ = fabric.n_hosts
        fs0 = fabric.fs.bytes_read
        net0 = fabric.net.bytes_moved
        tier0 = fabric.net.tier_snapshot()
        comp0 = fabric.net.comp_snapshot()
        total = sum(fabric.fs.size(p) for p in paths)
        rep = StagingReport(n_hosts=P_, total_bytes=total, mode="pipelined")

        coll_overhead = _coll_overhead(fabric)
        t_read_done = t0     # leader read stream completion (incl. sync)
        t_comm = t0          # all-gather stream
        comm_total = 0.0
        for path in paths:
            size = fabric.fs.size(path)
            per_host = max(1, (size + P_ - 1) // P_)
            n_seg = max(1, (per_host + chunk_bytes - 1) // chunk_bytes)
            t_seg = t0
            for off, seg in _stripes(size, n_seg):   # file-range segments
                # all reads issue at t0: fs.busy_until serializes the
                # bandwidth and per-request latencies overlap, exactly as
                # in stage_collective — per-file sync overheads accumulate
                # in t_read_done OUTSIDE the busy stream, so stage_time
                # matches the collective engine for the same paths
                _, t_seg = fabric.fs.read_striped(
                    path, [(off + o, s) for o, s in _stripes(seg, P_)],
                    t0, coordinated=True)
                seg_stripe = max(1, (seg + P_ - 1) // P_)
                dt = fabric.net.allgather(seg_stripe, P_,
                                          t=max(t_comm, t_seg))
                comm_total += dt
                t_comm = max(t_comm, t_seg) + dt     # gather rides behind
                rep.n_chunks += 1
            t_read_done = max(t_read_done, t_seg) + coll_overhead
        rep.stage_time = t_read_done - t0
        rep.comm_time = max(0.0, t_comm - t_read_done)   # exposed (unhidden)
        rep.overlap_saved = comm_total - rep.comm_time

        rep.write_time = _deliver_replicas(fabric, paths, t=t_comm)
        rep.fs_bytes = fabric.fs.bytes_read - fs0
        rep.net_bytes = fabric.net.bytes_moved - net0
        rep.tier_bytes = fabric.net.tier_delta(tier0)
        rep.comp = fabric.net.comp_delta(comp0)
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + rep.total_time


def stage_naive(fabric: Fabric, paths: Sequence[str], t0: float = 0.0,
                topology: TopologyLike = None,
                compression: CompressionLike = None
                ) -> Tuple[StagingReport, float]:
    """Baseline: every host independently reads each full file from the
    shared FS (uncoordinated — the congested regime), then writes locally.
    `topology` and `compression` are accepted for engine-protocol
    uniformity only: the naive path never touches the interconnect, so no
    collective is planned, nothing can elect a codec, and the report's
    tier accounting stays empty."""
    del topology, compression       # no collective to plan on this path
    with fabric.tracer.region("stage.naive", t0, track="engine") as tsp:
        P_ = fabric.n_hosts
        fs0 = fabric.fs.bytes_read
        total = sum(fabric.fs.size(p) for p in paths)
        rep = StagingReport(n_hosts=P_, total_bytes=total, mode="naive")
        t_done = t0
        for path in paths:
            size = fabric.fs.size(path)
            for host in fabric.hosts:
                # concurrent uncoordinated reads: bandwidth serializes on
                # the shared FS, per-request latency overlaps across hosts
                data, t_r = fabric.fs.read(path, 0, size, t0,
                                           coordinated=False)
                # fs.read returns a view of the source buffer: same
                # read-only guard as the collective paths, so no store can
                # mutate the FS
                replica = data.view()
                replica.setflags(write=False)
                host.store.write(path, replica, 0.0)
                t_done = max(t_done, t_r)
        rep.stage_time = t_done - t0
        rep.write_time = total / fabric.constants.local_bw
        rep.fs_bytes = fabric.fs.bytes_read - fs0
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + rep.total_time


# ---------------------------------------------------------------------------
# replica-aware staging + repair collectives (fault tolerance)
# ---------------------------------------------------------------------------

def stage_replicated(fabric: Fabric, paths: Sequence[str], t0: float = 0.0,
                     replication: int = 2, topology: TopologyLike = None,
                     compression: CompressionLike = None
                     ) -> Tuple[StagingReport, float]:
    """R-way stripe-replicated staging: the fault-tolerant middle ground
    between ``stage_collective`` (R=P, every host a full replica) and
    bare striping (R=1, any death loses data).

    Phase 1 is the identical coordinated disjoint-stripe read (aggregate
    FS traffic = 1x the dataset). Phase 2 replaces the all-gather with
    R-1 rounds of chained stripe forwarding
    (:meth:`~repro.core.collectives.CollectivePlanner.plan_replichain`):
    stripe ``i`` ends up on hosts ``i .. i+R-1`` (mod P) under the store
    key ``path::s{i}`` — interconnect traffic is (R-1)/(P-1) of the full
    all-gather, node memory R/P of a full replica per host. The returned
    report carries the :class:`ReplicaPlacement`; ``re_replicate`` uses
    it to restore redundancy after a host death at a cost proportional to
    the LOST stripes, not the dataset.

    Hosts dead at `t0` (non-trivial fault schedule only) are excluded
    from the stripe geometry entirely."""
    with fabric.net.scoped_topology(topology), \
            fabric.net.scoped_codec(compression), \
            fabric.tracer.region("stage.replicated", t0, track="engine",
                                 replication=replication) as tsp:
        live = (list(range(fabric.n_hosts)) if fabric.faults.trivial
                else fabric.live_ids(t0))
        L = len(live)
        fs0 = fabric.fs.bytes_read
        net0 = fabric.net.bytes_moved
        tier0 = fabric.net.tier_snapshot()
        comp0 = fabric.net.comp_snapshot()
        total = sum(fabric.fs.size(p) for p in paths)
        rep = StagingReport(n_hosts=L, total_bytes=total, mode="replicated",
                            placement=ReplicaPlacement.chained(live,
                                                               replication))

        coll_overhead = _coll_overhead(fabric)
        t_read_done = t0
        for path in paths:
            size = fabric.fs.size(path)
            _, t_file = fabric.fs.read_striped(path, _stripes(size, L), t0,
                                               coordinated=True)
            t_read_done = max(t_read_done, t_file) + coll_overhead
        rep.stage_time = t_read_done - t0

        stripe_bytes = max(1, (total + L - 1) // L)
        rep.comm_time = fabric.net.replichain(stripe_bytes, L, replication,
                                              t=t_read_done)

        # deliver each stripe view to its R owners; a host's writes
        # serialize on its local-store bandwidth, hosts run in parallel
        t_host: Dict[int, float] = {}
        for path in paths:
            size = fabric.fs.size(path)
            for i, (off, sz) in enumerate(_stripes(size, L)):
                view = readonly_view(fabric.fs.files[path][off:off + sz])
                key = ReplicaPlacement.stripe_key(path, i)
                for o in rep.placement.owners[i]:
                    t_host[o] = fabric.hosts[o].store.write(
                        key, view, t_host.get(o, 0.0))
        rep.write_time = max(t_host.values(), default=0.0)

        rep.fs_bytes = fabric.fs.bytes_read - fs0
        rep.net_bytes = fabric.net.bytes_moved - net0
        rep.tier_bytes = fabric.net.tier_delta(tier0)
        rep.comp = fabric.net.comp_delta(comp0)
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + rep.total_time


def re_replicate(fabric: Fabric, paths: Sequence[str],
                 placement: ReplicaPlacement, t0: float = 0.0,
                 live: Optional[Sequence[int]] = None,
                 topology: TopologyLike = None
                 ) -> Tuple[StagingReport, float]:
    """Restore R-way redundancy of a striped dataset after host loss.

    For every stripe with dead owners, a surviving owner sends the stripe
    to a replacement live host (explicit point-to-point schedule via
    :meth:`~repro.core.collectives.CollectivePlanner.plan_repair`; the
    shared FS is never touched). Cost is proportional to the LOST
    stripes — roughly ``lost/P`` of the dataset per dead owner slot —
    which is what makes repair beat a full re-stage at large P.
    `placement` is updated in place (ownership moves to the replacement
    hosts). Raises :class:`LostStripesError` when some stripe has no
    surviving owner (caller must fall back to a full re-stage)."""
    with fabric.net.scoped_topology(topology), \
            fabric.tracer.region("stage.re_replicate", t0,
                                 track="engine") as tsp:
        if live is None:
            live = fabric.live_ids(t0)
        alive = set(live)
        lost = placement.lost(live)
        if lost:
            raise LostStripesError(
                f"stripes {lost} have no surviving owner among live hosts "
                f"{sorted(alive)}; repair impossible — full re-stage "
                f"required")
        net0 = fabric.net.bytes_moved
        tier0 = fabric.net.tier_snapshot()
        L = placement.n_stripes
        # per-stripe byte size summed over files (one repair transfer
        # per replaced owner slot covers every file's stripe i)
        stripe_sizes = [0] * L
        views: List[List[Tuple[str, np.ndarray]]] = [[] for _ in range(L)]
        for path in paths:
            size = fabric.fs.size(path)
            for i, (off, sz) in enumerate(_stripes(size, L)):
                stripe_sizes[i] += sz
                views[i].append(
                    (ReplicaPlacement.stripe_key(path, i),
                     readonly_view(fabric.fs.files[path][off:off + sz])))
        transfers: List[Tuple[int, int, int]] = []
        t_host: Dict[int, float] = {}
        repaired = 0
        for i in sorted(placement.owners):
            owners = placement.owners[i]
            survivors = [o for o in owners if o in alive]
            n_dead = len(owners) - len(survivors)
            if not n_dead:
                continue
            new_owners = list(survivors)
            for j in range(n_dead):
                cands = [h for h in live if h not in new_owners]
                if not cands:
                    break            # fewer live hosts than R: degrade R
                dst = cands[(i + j) % len(cands)]
                src = survivors[j % len(survivors)]
                transfers.append((src, dst, stripe_sizes[i]))
                repaired += stripe_sizes[i]
                for key, view in views[i]:
                    t_host[dst] = fabric.hosts[dst].store.write(
                        key, view, t_host.get(dst, 0.0))
                new_owners.append(dst)
            placement.owners[i] = tuple(new_owners)
        rep = StagingReport(n_hosts=len(live), total_bytes=repaired,
                            mode="re_replicate", placement=placement)
        rep.comm_time = fabric.net.repair(transfers, fabric.n_hosts, t=t0)
        rep.write_time = max(t_host.values(), default=0.0)
        rep.net_bytes = fabric.net.bytes_moved - net0
        rep.tier_bytes = fabric.net.tier_delta(tier0)
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + rep.total_time


def re_replicate_full(fabric: Fabric, paths: Sequence[str],
                      targets: Sequence[int], t0: float = 0.0,
                      sources: Optional[Sequence[int]] = None,
                      topology: TopologyLike = None
                      ) -> Tuple[StagingReport, float]:
    """Restore FULL replicas on `targets` (hosts missing the dataset —
    recovered-blank or newly grown) from surviving holders, without
    touching the shared FS.

    `sources` defaults to the hosts whose node-local stores hold every
    path. Targets round-robin across sources; each target receives the
    whole dataset in one point-to-point schedule (receiver NICs
    serialize). Raises :class:`ReplicaLossError` when no complete live
    copy exists (full re-stage required)."""
    with fabric.net.scoped_topology(topology), \
            fabric.tracer.region("stage.re_replicate_full", t0,
                                 track="engine") as tsp:
        want = set(targets)
        if sources is None:
            sources = [h.host_id for h in fabric.hosts
                       if h.host_id not in want
                       and all(p in h.store.data for p in paths)]
        if not sources:
            raise ReplicaLossError(
                f"no live host holds a complete replica of {list(paths)}; "
                f"repair impossible — full re-stage required")
        net0 = fabric.net.bytes_moved
        tier0 = fabric.net.tier_snapshot()
        total = sum(fabric.fs.size(p) for p in paths)
        replicas = {p: _replica_view(fabric, p) for p in paths}
        transfers = [(sources[k % len(sources)], dst, total)
                     for k, dst in enumerate(sorted(want))]
        rep = StagingReport(n_hosts=len(want), total_bytes=total,
                            mode="re_replicate")
        rep.comm_time = fabric.net.repair(transfers, fabric.n_hosts, t=t0)
        t_write = 0.0
        for dst in sorted(want):
            t_write = max(t_write,
                          fabric.hosts[dst].store.write_many(replicas, 0.0))
        rep.write_time = t_write
        rep.net_bytes = fabric.net.bytes_moved - net0
        rep.tier_bytes = fabric.net.tier_delta(tier0)
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + rep.total_time


# ---------------------------------------------------------------------------
# write-back: staging OUT — dirty results flushed to the shared FS
# ---------------------------------------------------------------------------

def _as_uint8(outputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    return {p: np.ascontiguousarray(d).view(np.uint8).ravel()
            for p, d in outputs.items()}


def stage_out(fabric: Fabric, outputs: Dict[str, np.ndarray],
              t0: float = 0.0, topology: TopologyLike = None,
              compression: CompressionLike = None
              ) -> Tuple[StagingReport, float]:
    """Collective write-back: ``MPI_File_write_all`` over the fabric.

    `outputs` maps shared-FS destination paths to result buffers (any
    dtype; flattened to uint8). Each file is written as P disjoint 1/P
    stripes by the leader group through
    :meth:`repro.core.fabric.SharedFilesystem.write_gather` — aggregate
    FS traffic is 1x the result bytes at the coordinated sequential rate,
    plus the per-file collective sync overhead, exactly mirroring
    ``stage_collective`` on the read side. Analysis results are
    REPLICATED on the nodes (every host holds the full buffer), so the
    data-gather half of the two-phase write moves no interconnect bytes —
    each leader already owns its stripe.

    Returns ``(report, completion time)``; the report's ``stage_time`` is
    the FS write phase and ``fs_write_bytes`` the bytes landed.
    `topology` and `compression` are accepted for engine-protocol
    uniformity only: each leader already owns its stripe, so no
    collective is planned (nothing can elect a codec) and the tier
    accounting stays empty.
    """
    del topology, compression       # no collective to plan on this path
    with fabric.tracer.region("stage.stage_out", t0, track="engine") as tsp:
        P_ = fabric.n_hosts
        w0 = fabric.fs.bytes_written
        bufs = _as_uint8(outputs)
        total = sum(b.size for b in bufs.values())
        rep = StagingReport(n_hosts=P_, total_bytes=total, mode="stage_out")

        coll_overhead = _coll_overhead(fabric)
        t_done = t0
        for path, buf in bufs.items():
            # stripes issue concurrently; the FS serializes bandwidth only
            t_file = fabric.fs.write_gather(path, buf,
                                            _stripes(buf.size, P_),
                                            t0, coordinated=True)
            t_done = max(t_done, t_file) + coll_overhead
        rep.stage_time = t_done - t0
        rep.fs_write_bytes = fabric.fs.bytes_written - w0
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + rep.total_time


def stage_out_naive(fabric: Fabric, outputs: Dict[str, np.ndarray],
                    t0: float = 0.0, topology: TopologyLike = None,
                    compression: CompressionLike = None
                    ) -> Tuple[StagingReport, float]:
    """Baseline write-back: every host writes each FULL result file to the
    shared FS, uncoordinated (the congested regime — P x the bytes at
    ``fs_rand_bw``). Final file contents are identical to ``stage_out``;
    only the traffic and time differ, which is the comparison the
    write-back benchmark measures. `topology` and `compression` are
    accepted for engine-protocol uniformity (no interconnect traffic
    either way)."""
    del topology, compression       # no collective to plan on this path
    with fabric.tracer.region("stage.stage_out_naive", t0,
                              track="engine") as tsp:
        P_ = fabric.n_hosts
        w0 = fabric.fs.bytes_written
        bufs = _as_uint8(outputs)
        total = sum(b.size for b in bufs.values())
        rep = StagingReport(n_hosts=P_, total_bytes=total,
                            mode="stage_out_naive")
        t_done = t0
        for path, buf in bufs.items():
            for _ in range(P_):
                # concurrent uncoordinated writes: bandwidth serializes on
                # the shared FS, per-request latency overlaps across hosts
                t_w = fabric.fs.write(path, buf, t0, coordinated=False)
                t_done = max(t_done, t_w)
        rep.stage_time = t_done - t0
        rep.fs_write_bytes = fabric.fs.bytes_written - w0
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + rep.total_time


# The mode -> engine mapping lives in the pluggable registry
# `repro.core.api.ENGINES` (this module's engines register there under
# "collective"/"pipelined"/"naive"; the streaming engine under "stream").
# The I/O hook, the StagingClient, the dataset service and the HEDM
# runners all resolve engines through it — new engines register once with
# a typed config instead of editing per-consumer tables.


# ---------------------------------------------------------------------------
# device-level staging (JAX mesh) — shard + all-gather over ICI
# ---------------------------------------------------------------------------

def device_replicate(mesh: Mesh, x: jax.Array, axis: str = "data"
                     ) -> jax.Array:
    """Replicate `x` across `axis` given each participant holds 1/P of it.

    Input: x sharded P(axis) on its leading dim. Output: fully replicated.
    This is the staging all-gather: read-shards once, replicate over ICI —
    instead of every participant fetching the full buffer from storage.
    """
    spec_in = P(axis)
    spec_out = P()

    def body(shard):
        return jax.lax.all_gather(shard, axis, tiled=True)

    fn = shard_map(body, mesh=mesh, in_specs=(spec_in,), out_specs=spec_out,
                   check_vma=False)
    return jax.jit(fn)(x)


def device_shard(mesh: Mesh, x: jax.Array, spec: P) -> jax.Array:
    """Lay out a host buffer onto the mesh with the given PartitionSpec
    (the 'distribute' half of staging, for non-replicated targets)."""
    return jax.device_put(x, NamedSharding(mesh, spec))


def staged_restore(mesh: Mesh, shards: Dict[int, np.ndarray],
                   axis: str = "data") -> jax.Array:
    """Checkpoint-restore staging: process i contributes shard i (1/P of the
    array, leading dim); result is the replicated full array, assembled by
    all-gather rather than P full reads. Single-process simulation: shards
    are placed per-device then gathered."""
    order = sorted(shards)
    full = np.concatenate([shards[i] for i in order], axis=0)
    per_dev = jax.device_put(full, NamedSharding(mesh, P(axis)))
    return device_replicate(mesh, per_dev, axis)

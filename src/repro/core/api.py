"""Unified staging client API: typed engine configs, a pluggable engine
registry, and session-scoped campaigns.

The paper exposes staging to scientists through ONE declarative surface
(the Swift I/O hook of Fig. 6 over the MPI-IO staging library). After the
one-shot engines (`repro.core.staging`), streamed ingestion
(`repro.core.streaming`) and the multi-tenant catalog
(`repro.core.datasvc`) grew their own entrypoints, that surface had
fractured into mode strings, untyped ``stage_kw`` dicts, a legacy
``collective`` boolean and a module-level engine table duplicated across
consumers. This module re-unifies it — the shape the streaming-pipeline
literature converges on (openPMD/ADIOS2 engine-agnostic APIs with
pluggable transports selected by typed config; the Perlmutter
detector-streaming client hiding batch-vs-stream delivery):

  * **Typed engine configs** — :class:`CollectiveConfig`,
    :class:`PipelinedConfig`, :class:`NaiveConfig`,
    :class:`ReplicatedConfig`, :class:`StreamConfig`,
    :class:`WanStreamConfig` and
    :class:`ServiceConfig`: one frozen dataclass per engine, validated
    in ``__post_init__`` (no more silently-ignored ``stage_kw`` typos).
    Each carries an optional :class:`FaultConfig` — a what-if fault
    timeline scoped to that stage; live faults go through
    :meth:`StagingClient.inject` (see `repro.core.faults`).
  * **EngineRegistry** — name -> (config type, stage fn). The single
    source of truth for the mode -> engine mapping (replaces the old
    ``BATCH_STAGE_FNS`` table that was consumed by ``staging``/``iohook``/
    ``hedm`` separately). Adding an engine is ONE ``register`` call — the
    hook, the client, the dataset service and the HEDM runners all pick it
    up from here.
  * **StagingClient** — the facade: ``client.stage(spec_or_patterns,
    config)`` drives any one-shot engine, streamed delivery
    (:meth:`StagingClient.stream_stager`) or catalog-backed acquisition
    (a :class:`ServiceConfig` / an attached
    :class:`~repro.core.datasvc.StagingService`) and always returns one
    unified :class:`Report`.
  * **Session-scoped campaigns** — ``with client.session(name) as s:``
    auto-releases every lease the session still holds on exit (even under
    an exception), killing the forgotten-``service.release(...)`` wedge
    footgun of the raw catalog API.
  * **Topology-aware transport** — every built-in engine config carries a
    ``topology`` field (a typed `repro.core.topology.TopologyConfig`,
    JSON round-trippable): the stage's collectives are planned over that
    machine model by the `repro.core.collectives.CollectivePlanner`
    (exposed as :attr:`StagingClient.planner`), with per-tier wire
    traffic in the report's ``tier_bytes``.

`repro.core.iohook.run_io_hook` remains as a thin deprecation shim over
the client (``mode``/``collective``/``stage_kw`` honored), and
:class:`StagingSpec`/:class:`BroadcastEntry` live here now (re-exported
from ``iohook`` for compatibility). All times are SIMULATED seconds (see
`repro.core.fabric`); replicas move real bytes and stay byte-exact.
"""
from __future__ import annotations

import json
import math
import os
import warnings
from dataclasses import dataclass, field, fields
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

from repro.core.collectives import CollectivePlan, CollectivePlanner  # noqa: F401 (re-export)
from repro.core.compression import (CODECS, Codec,  # noqa: F401 (re-export)
                                    CompressionConfig, CompressionStats,
                                    resolve_codec)
from repro.core.fabric import Fabric
from repro.core.faults import FaultEvent, FaultKind, FaultSchedule
from repro.core.staging import (StagingReport, stage_collective, stage_naive,
                                stage_pipelined, stage_replicated)
from repro.core.streaming import StreamStager, stage_stream
from repro.core.wan import stage_wan
from repro.core.telemetry import (NULL_TRACER, Tracer,  # noqa: F401
                                  TracerLike, flight_recorder,
                                  write_chrome_trace)
from repro.core.topology import (BGQ_TORUS, FLAT, TOPOLOGIES,  # noqa: F401
                                 TPU_POD_ICI_DCN, Topology, TopologyConfig,
                                 resolve_topology)


# ---------------------------------------------------------------------------
# typed engine configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultConfig:
    """Typed, JSON-serializable fault-injection selector for engine
    configs (`repro.core.faults`).

    Explicit events — ``host_deaths``/``host_recoveries`` are
    ``(t, host)`` pairs, ``degradations`` are ``(tier, t, t_end, factor)``
    brownout windows — plus an optional seeded random layer (``seed``
    with ``random_deaths`` deaths drawn over ``[0, horizon)`` by
    `repro.core.faults.FaultSchedule.random`). :meth:`build` materializes
    the concrete :class:`~repro.core.faults.FaultSchedule` for a fabric
    of ``n_hosts``.

    A config-level schedule is a WHAT-IF timing overlay scoped to one
    stage call (bound via ``Interconnect.scoped_faults``): collectives
    re-route around the dead, degraded tiers slow the wire, deliveries
    skip dead hosts — but no node-local store is wiped. State-changing
    live injection is the :meth:`StagingClient.inject` /
    ``Fabric.kill_host`` path. The default (no events, no seed) builds
    the trivial schedule — bit-exact zero-fault accounting."""
    host_deaths: Tuple[Tuple[float, int], ...] = ()
    host_recoveries: Tuple[Tuple[float, int], ...] = ()
    degradations: Tuple[Tuple[str, float, float, float], ...] = ()
    seed: Optional[int] = None
    random_deaths: int = 0
    horizon: float = 60.0

    def __post_init__(self) -> None:
        object.__setattr__(self, "host_deaths", tuple(
            (float(t), int(h)) for t, h in self.host_deaths))
        object.__setattr__(self, "host_recoveries", tuple(
            (float(t), int(h)) for t, h in self.host_recoveries))
        object.__setattr__(self, "degradations", tuple(
            (str(tier), float(t), float(t_end), float(f))
            for tier, t, t_end, f in self.degradations))
        if (self.seed is None) != (self.random_deaths == 0):
            raise ValueError(
                "seed and random_deaths select the seeded random fault "
                "layer together: give both (seed=..., random_deaths>=1) "
                "or neither")
        if self.random_deaths < 0:
            raise ValueError(
                f"random_deaths must be >= 0, got {self.random_deaths}")
        if self.horizon <= 0:
            raise ValueError(
                f"horizon must be a positive window in simulated seconds, "
                f"got {self.horizon}")

    def build(self, n_hosts: int) -> FaultSchedule:
        """The concrete fault timeline for a fabric of `n_hosts` hosts
        (validation of hosts/windows happens in ``FaultEvent``)."""
        events = [FaultEvent(t, FaultKind.HOST_DEATH, host=h)
                  for t, h in self.host_deaths]
        events += [FaultEvent(t, FaultKind.HOST_RECOVERY, host=h)
                   for t, h in self.host_recoveries]
        events += [FaultEvent(t, FaultKind.LINK_DEGRADE, tier=tier,
                              t_end=t_end, factor=f)
                   for tier, t, t_end, f in self.degradations]
        sched = FaultSchedule(events)
        if self.seed is not None:
            for ev in FaultSchedule.random(self.seed, n_hosts, self.horizon,
                                           n_deaths=self.random_deaths
                                           ).events:
                sched.inject(ev)
        return sched

    def to_dict(self) -> Dict[str, Any]:
        """Primitive dict for JSON round-trips (drops empty layers)."""
        out: Dict[str, Any] = {}
        if self.host_deaths:
            out["host_deaths"] = [list(p) for p in self.host_deaths]
        if self.host_recoveries:
            out["host_recoveries"] = [list(p) for p in self.host_recoveries]
        if self.degradations:
            out["degradations"] = [list(d) for d in self.degradations]
        if self.seed is not None:
            out["seed"] = self.seed
            out["random_deaths"] = self.random_deaths
            out["horizon"] = self.horizon
        return out

    @classmethod
    def coerce(cls, value: Union["FaultConfig", Mapping]) -> "FaultConfig":
        """Normalize a loose faults spelling (a config passes through, a
        JSON dict builds one) — the ``topology``-field pattern."""
        if isinstance(value, FaultConfig):
            return value
        if isinstance(value, Mapping):
            return cls(**value)
        raise TypeError(
            f"cannot coerce {type(value).__name__} to a FaultConfig "
            f"(expected a FaultConfig or a dict)")


@dataclass(frozen=True)
class EngineConfig:
    """Base class for one-shot staging engine configs.

    Subclasses are frozen dataclasses: one field per engine parameter,
    validated in ``__post_init__`` with a clear message — the typed
    replacement for the old untyped ``stage_kw`` dict. ``to_kw()`` maps
    the fields onto the engine function's keyword arguments.

    A subclass that declares a ``topology`` field gets loose spellings
    (a canned name, a JSON dict, a registered
    `repro.core.topology.Topology`) coerced to a typed
    :class:`~repro.core.topology.TopologyConfig` here, a ``faults``
    field likewise to a :class:`FaultConfig`, and a ``compression``
    field (a codec name, mapping, or `repro.core.compression.Codec`) to
    a typed :class:`~repro.core.compression.CompressionConfig` —
    subclasses with their own ``__post_init__`` must call
    ``super().__post_init__()``. ``faults``
    is EXCLUDED from ``to_kw()``: it configures the fabric-side scope
    the stage runs under (``Interconnect.scoped_faults``), not an engine
    function parameter.
    """

    def __post_init__(self) -> None:
        topo = getattr(self, "topology", None)
        if topo is not None and not isinstance(topo, TopologyConfig):
            object.__setattr__(self, "topology", TopologyConfig.coerce(topo))
        flt = getattr(self, "faults", None)
        if flt is not None and not isinstance(flt, FaultConfig):
            object.__setattr__(self, "faults", FaultConfig.coerce(flt))
        comp = getattr(self, "compression", None)
        if comp is not None and not isinstance(comp, CompressionConfig):
            object.__setattr__(self, "compression",
                               CompressionConfig.coerce(comp))

    def to_kw(self) -> Dict[str, Any]:
        return {f.name: getattr(self, f.name) for f in fields(self)
                if f.name != "faults"}


@dataclass(frozen=True)
class CollectiveConfig(EngineConfig):
    """Two-phase ``MPI_File_read_all`` staging (leader stripes + planned
    all-gather) — `repro.core.staging.stage_collective`. ``topology``
    selects the machine model the collectives are planned over for this
    stage (``None``: whatever the fabric runs — FLAT by default);
    ``faults`` optionally overlays a what-if :class:`FaultConfig` for
    this stage only; ``compression`` selects a codec for per-tier
    compress-at-source election (``None``: ship raw — bit-exact legacy
    path)."""
    topology: Optional[TopologyConfig] = None
    faults: Optional[FaultConfig] = None
    compression: Optional[CompressionConfig] = None


@dataclass(frozen=True)
class PipelinedConfig(EngineConfig):
    """Chunked two-phase staging with read/all-gather overlap
    (`repro.core.staging.stage_pipelined`). ``chunk_bytes`` is the
    per-host segment size: smaller chunks overlap finer but round more;
    ``topology``/``faults``/``compression`` as on
    :class:`CollectiveConfig`."""
    chunk_bytes: int = 8 << 20
    topology: Optional[TopologyConfig] = None
    faults: Optional[FaultConfig] = None
    compression: Optional[CompressionConfig] = None

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.chunk_bytes <= 0:
            raise ValueError(
                f"chunk_bytes must be a positive per-host segment size in "
                f"bytes, got {self.chunk_bytes}")


@dataclass(frozen=True)
class NaiveConfig(EngineConfig):
    """Uncoordinated per-host full reads — the paper's congested baseline
    (`repro.core.staging.stage_naive`). ``topology`` and ``compression``
    are accepted for engine-protocol uniformity (the naive path never
    touches the interconnect, so neither changes anything); ``faults``
    as on :class:`CollectiveConfig`."""
    topology: Optional[TopologyConfig] = None
    faults: Optional[FaultConfig] = None
    compression: Optional[CompressionConfig] = None


@dataclass(frozen=True)
class ReplicatedConfig(EngineConfig):
    """R-way stripe-replicated staging with chained declustering
    (`repro.core.staging.stage_replicated`): instead of every host
    holding a full replica, stripe ``i`` lands on hosts ``i..i+R-1``
    (mod P), so a host death loses no data while R-1 neighbors survive
    and repair (`repro.core.staging.re_replicate`) moves only the lost
    stripes. ``replication`` is R (1 = no redundancy: a pure striped
    scatter); ``topology``/``faults``/``compression`` as on
    :class:`CollectiveConfig`."""
    replication: int = 2
    topology: Optional[TopologyConfig] = None
    faults: Optional[FaultConfig] = None
    compression: Optional[CompressionConfig] = None

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.replication < 1:
            raise ValueError(
                f"replication must be a replica count >= 1, got "
                f"{self.replication}")


@dataclass(frozen=True)
class StreamConfig(EngineConfig):
    """Detector-push streamed ingestion (`repro.core.streaming`): the
    shared FS is never read back. ``rate_hz`` is the acquisition rate in
    frames per simulated second (``None`` = replay as fast as the fabric
    delivers); ``window_bytes`` bounds the per-node sliding cache
    (``None`` = the whole set stays resident); ``topology`` as on
    :class:`CollectiveConfig` (the per-frame detector ingest hop is
    charged to its ingest tier and each delivery broadcast planned over
    it); ``faults`` overlays a what-if fault schedule on the stream
    (degraded ingest: deliveries skip hosts dead at delivery time);
    ``compression`` as on :class:`CollectiveConfig` (the WAN ingest hop
    is where compress-at-source pays most — see docs/compression.md)."""
    rate_hz: Optional[float] = None
    window_bytes: Optional[int] = None
    # paths pinned AT INGEST (exempt from window eviction) in addition to
    # whatever the broadcast entry's ``pin`` directive pins — the typed
    # home of the legacy ``stage_kw={"pin_paths": [...]}`` escape hatch
    pin_paths: Tuple[str, ...] = ()
    topology: Optional[TopologyConfig] = None
    faults: Optional[FaultConfig] = None
    compression: Optional[CompressionConfig] = None

    def __post_init__(self) -> None:
        super().__post_init__()
        object.__setattr__(self, "pin_paths", tuple(self.pin_paths))
        if self.rate_hz is not None and self.rate_hz <= 0:
            raise ValueError(
                f"rate_hz must be a positive acquisition rate in frames "
                f"per simulated second (or None for replay), got "
                f"{self.rate_hz}")
        if self.window_bytes is not None and self.window_bytes <= 0:
            raise ValueError(
                f"window_bytes must be a positive per-node cache budget in "
                f"bytes (or None to keep the whole set resident), got "
                f"{self.window_bytes}")


@dataclass(frozen=True)
class WanStreamConfig(StreamConfig):
    """Cross-facility WAN ingest (`repro.core.wan.stage_wan`): the
    detector sits across a wide-area ingest tier (pair with
    ``topology="wan_beamline"``), pushes only while it holds a send
    credit, and ONE WAN stream fans out to ``subscribers`` consumer
    campaigns (frames cross the WAN once; retention follows the slowest
    subscriber's watermark).

    On top of :class:`StreamConfig`: ``credit_window`` caps unconsumed
    in-flight frames (``None`` derives the largest window the node cache
    can absorb — it never binds on an unbounded cache); ``buffer_frames``
    bounds the detector's DAQ buffer (``None`` = unbounded, no drops;
    overflow overwrites the OLDEST frame, accounted in
    ``report.wan.frames_dropped``); ``consume_hz`` is the per-subscriber
    processing rate (scalar for all, a tuple per subscriber, ``None`` for
    instant acks); ``loss_rate``/``loss_seed`` drive seeded stop-and-wait
    retransmission on the WAN hop; ``jitter_seed``/``jitter_windows``/
    ``jitter_window_s``/``jitter_factors`` overlay seeded transient
    brownouts on the ingest tier
    (`repro.core.faults.FaultSchedule.wan_jitter`), composed with any
    ``faults`` overlay.  All defaults off: the default WAN stage is
    byte- and time-exact vs :class:`StreamConfig` (the regression
    anchor)."""
    credit_window: Optional[int] = None
    buffer_frames: Optional[int] = None
    subscribers: int = 1
    consume_hz: Union[None, float, Tuple[float, ...]] = None
    loss_rate: float = 0.0
    loss_seed: int = 0
    jitter_seed: Optional[int] = None
    jitter_windows: int = 0
    jitter_window_s: Optional[float] = None
    jitter_factors: Tuple[float, float] = (0.3, 0.9)

    def __post_init__(self) -> None:
        super().__post_init__()
        if isinstance(self.consume_hz, (list, tuple)):
            object.__setattr__(self, "consume_hz",
                               tuple(float(r) for r in self.consume_hz))
        object.__setattr__(self, "jitter_factors",
                           tuple(float(f) for f in self.jitter_factors))
        if self.credit_window is not None and self.credit_window < 1:
            raise ValueError(
                f"credit_window must be >= 1 in-flight frames (or None "
                f"to derive it), got {self.credit_window}")
        if self.buffer_frames is not None and self.buffer_frames < 1:
            raise ValueError(
                f"buffer_frames must be >= 1 (or None for an unbounded "
                f"DAQ buffer), got {self.buffer_frames}")
        if self.subscribers < 1:
            raise ValueError(
                f"subscribers must be >= 1 consumer campaigns, got "
                f"{self.subscribers}")
        if isinstance(self.consume_hz, tuple) \
                and len(self.consume_hz) != self.subscribers:
            raise ValueError(
                f"consume_hz lists {len(self.consume_hz)} rates for "
                f"{self.subscribers} subscribers")
        if not 0.0 <= self.loss_rate < 1.0:
            raise ValueError(
                f"loss_rate must be in [0, 1) — a rate of 1 never "
                f"delivers, got {self.loss_rate}")
        if self.jitter_windows < 0:
            raise ValueError(
                f"jitter_windows must be >= 0, got {self.jitter_windows}")
        jf = self.jitter_factors
        if len(jf) != 2 or not 0.0 < jf[0] <= jf[1] <= 1.0:
            raise ValueError(
                f"jitter_factors must be (lo, hi) with 0 < lo <= hi <= 1 "
                f"(0 is a partition, not jitter), got {jf}")
        if self.jitter_window_s is not None and self.jitter_window_s <= 0:
            raise ValueError(
                f"jitter_window_s must be a positive brownout length in "
                f"simulated seconds (or None to derive it), got "
                f"{self.jitter_window_s}")


@dataclass(frozen=True)
class ServiceConfig:
    """Catalog-backed acquisition through a long-lived
    :class:`~repro.core.datasvc.StagingService`: datasets register in the
    catalog, concurrent requests coalesce, residents evict under
    ``budget_bytes`` (per-node), and leases pin replicas until released.
    ``engine`` is the typed config of the batch engine the service stages
    with."""
    budget_bytes: int
    engine: EngineConfig = field(default_factory=CollectiveConfig)

    def __post_init__(self) -> None:
        if self.budget_bytes <= 0:
            raise ValueError(
                f"budget_bytes must be a positive per-node memory budget "
                f"in bytes, got {self.budget_bytes}")
        # fail fast on a KNOWN non-batch engine (the service re-stages on
        # demand); configs only a custom registry knows are validated when
        # the service is built against that registry
        entry = ENGINES.lookup(self.engine)
        if entry is not None and not entry.batch:
            raise ValueError(
                f"ServiceConfig.engine must be a batch engine (the "
                f"service re-stages on demand); "
                f"{type(self.engine).__name__} drives the non-batch "
                f"{entry.name!r} engine")


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EngineEntry:
    """One registered staging engine."""
    name: str
    config_type: type
    stage_fn: Callable[..., Tuple[StagingReport, float]]
    batch: bool = True          # False: streamed delivery (no FS read-back)


class EngineRegistry:
    """Name -> (config type, stage fn) — the pluggable engine table.

    Engines register ONCE here; `repro.core.iohook.run_io_hook`,
    :class:`StagingClient`, `repro.core.datasvc.StagingService` and the
    HEDM runners all resolve modes through the same registry, so adding
    an engine is a one-file change (define config + stage fn, register).
    Stage functions follow the engine protocol
    ``fn(fabric, paths, t0, **config_kw) -> (StagingReport, t_done)``.
    """

    def __init__(self) -> None:
        self._by_name: Dict[str, EngineEntry] = {}
        self._by_config: Dict[type, EngineEntry] = {}

    @classmethod
    def default(cls) -> "EngineRegistry":
        """A fresh registry holding the six built-in engines."""
        reg = cls()
        reg.register("collective", CollectiveConfig, stage_collective)
        reg.register("pipelined", PipelinedConfig, stage_pipelined)
        reg.register("naive", NaiveConfig, stage_naive)
        reg.register("replicated", ReplicatedConfig, stage_replicated)
        reg.register("stream", StreamConfig, stage_stream, batch=False)
        reg.register("wan", WanStreamConfig, stage_wan, batch=False)
        return reg

    def register(self, name: str, config_type: type,
                 stage_fn: Callable[..., Tuple[StagingReport, float]],
                 batch: bool = True) -> EngineEntry:
        if name in self._by_name:
            raise ValueError(f"engine {name!r} is already registered")
        if config_type in self._by_config:
            raise ValueError(
                f"config type {config_type.__name__} is already registered "
                f"(to engine {self._by_config[config_type].name!r})")
        entry = EngineEntry(name=name, config_type=config_type,
                            stage_fn=stage_fn, batch=batch)
        self._by_name[name] = entry
        self._by_config[config_type] = entry
        return entry

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def entries(self) -> List[EngineEntry]:
        return list(self._by_name.values())

    def names(self, batch_only: bool = False) -> List[str]:
        return sorted(n for n, e in self._by_name.items()
                      if e.batch or not batch_only)

    def entry(self, name: str, batch_only: bool = False) -> EngineEntry:
        e = self._by_name.get(name)
        if e is None:
            raise ValueError(
                f"unknown staging mode {name!r}; registered engines: "
                f"{', '.join(self.names())}")
        if batch_only and not e.batch:
            raise ValueError(
                f"staging mode {name!r} is registered but not "
                f"batch-capable (this path needs a re-runnable one-shot "
                f"engine); expected one of: "
                f"{', '.join(self.names(batch_only=True))}")
        return e

    def lookup(self, config: EngineConfig) -> Optional[EngineEntry]:
        """The entry for `config`'s type, or None if unregistered here."""
        return self._by_config.get(type(config))

    def entry_for(self, config: EngineConfig) -> EngineEntry:
        e = self._by_config.get(type(config))
        if e is None:
            raise ValueError(
                f"no engine registered for config type "
                f"{type(config).__name__}; registered engines: "
                f"{', '.join(self.names())}")
        return e

    def name_of(self, config: EngineConfig) -> str:
        return self.entry_for(config).name

    def stage_fn(self, name: str) -> Callable[..., Tuple[StagingReport, float]]:
        return self.entry(name).stage_fn

    def config_for(self, name: str, batch_only: bool = False,
                   **params: Any) -> EngineConfig:
        """Build the typed config for engine `name` from loose params —
        the bridge from the legacy ``mode=...,(stage_kw={...})`` surface.
        Unknown engine names and unknown parameters both raise
        ``ValueError`` with the registered alternatives spelled out."""
        entry = self.entry(name, batch_only=batch_only)
        known = {f.name for f in fields(entry.config_type)}
        bogus = sorted(set(params) - known)
        if bogus:
            raise ValueError(
                f"unknown parameter(s) {', '.join(bogus)} for engine "
                f"{name!r}; {entry.config_type.__name__} accepts: "
                f"{', '.join(sorted(known)) or '(no parameters)'}")
        return entry.config_type(**params)


# The process-wide registry. Engines defined elsewhere plug in with
# ``ENGINES.register(name, ConfigType, stage_fn)``.
ENGINES = EngineRegistry.default()


# ---------------------------------------------------------------------------
# declarative staging spec (paper Fig. 6) — moved here from iohook
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BroadcastEntry:
    """One broadcast directive: glob patterns -> node-local destination."""
    files: Tuple[str, ...]
    dest: str = "/tmp"
    pin: bool = True


@dataclass
class StagingSpec:
    """Fig. 6 analogue. JSON-serializable so it can ride an env var.

    ``config`` optionally embeds the typed engine config in the spec
    itself, so a declarative spec fully selects its transport — the
    engine name and parameters round-trip through ``to_json``/
    ``from_json`` via the :data:`ENGINES` registry."""
    broadcasts: List[BroadcastEntry] = field(default_factory=list)
    config: Optional[EngineConfig] = None

    @classmethod
    def from_json(cls, text: str,
                  registry: Optional["EngineRegistry"] = None
                  ) -> "StagingSpec":
        raw = json.loads(text)
        config = None
        if raw.get("engine"):
            reg = registry if registry is not None else ENGINES
            config = reg.config_for(raw["engine"]["name"],
                                    **raw["engine"].get("params", {}))
        return cls(broadcasts=[
            BroadcastEntry(files=tuple(b["files"]), dest=b.get("dest", "/tmp"),
                           pin=b.get("pin", True))
            for b in raw.get("broadcasts", [])], config=config)

    def to_json(self, registry: Optional["EngineRegistry"] = None) -> str:
        out: Dict[str, Any] = {"broadcasts": [
            {"files": list(b.files), "dest": b.dest, "pin": b.pin}
            for b in self.broadcasts]}
        if self.config is not None:
            reg = registry if registry is not None else ENGINES
            # serialize every config field (not to_kw(), which excludes
            # the fabric-scoped `faults` field from engine kwargs)
            params = {f.name: (v.to_dict()
                               if isinstance(v, (TopologyConfig,
                                                 FaultConfig,
                                                 CompressionConfig)) else v)
                      for f in fields(self.config)
                      for v in (getattr(self.config, f.name),)}
            out["engine"] = {"name": reg.name_of(self.config),
                             "params": params}
        return json.dumps(out)

    @classmethod
    def from_env(cls, env: str = "REPRO_IO_HOOK") -> Optional["StagingSpec"]:
        text = os.environ.get(env)
        return cls.from_json(text) if text else None


Stageable = Union[StagingSpec, str, Sequence[str]]


def as_spec(what: Stageable, pin: bool = True) -> StagingSpec:
    """Normalize ``client.stage``'s first argument to a :class:`StagingSpec`:
    a spec passes through, a pattern string or a sequence of patterns
    becomes a single broadcast entry."""
    if isinstance(what, StagingSpec):
        return what
    if isinstance(what, str):
        return StagingSpec([BroadcastEntry(files=(what,), pin=pin)])
    return StagingSpec([BroadcastEntry(files=tuple(what), pin=pin)])


# ---------------------------------------------------------------------------
# unified report
# ---------------------------------------------------------------------------

@dataclass
class Report:
    """One staging operation's unified accounting, whatever the path.

    Reconciles the per-engine :class:`~repro.core.staging.StagingReport`
    rows (streamed delivery folds its ``StreamReport`` into one), the old
    ``HookResult`` fields, and — on the catalog path — the service's
    shared accounting, behind one protocol. All times are simulated
    seconds.

    Documented invariants (asserted by ``tests/test_api.py``):

      * direct engines: ``total_time == metadata_time +
        sum(r.total_time for r in reports)`` — the old ``HookResult``
        identity, per-report ``total_time == stage + comm + write +
        broadcast``;
      * ``delivered_bytes == n_hosts * total_bytes`` (every node receives
        a full replica) — delivered bytes are LOGICAL payload and never
        shrink under compression;
      * ``net_bytes``/``tier_bytes`` are WIRE bytes (compressed where a
        codec elected a tier); per report the tier map sums to the net
        total, and ``payload_net_bytes == net_bytes + comp.saved_bytes``
        recovers the logical traffic;
      * ``fs_bytes`` is 1x the dataset for collective/pipelined, P x for
        naive, and **0** for stream (the FS is never read back).

    On the catalog path (``engine == "service"``) the per-dataset reports
    are SHARED across coalesced acquisitions, so the sum identity does
    not apply; ``total_time`` is the wall span until every lease is ready
    and the service-wide counters live in ``service.stats``.
    """
    engine: str
    n_hosts: int
    resolved_files: List[str]
    reports: List[StagingReport]
    metadata_time: float = 0.0
    total_time: float = 0.0
    leases: List = field(default_factory=list)
    service: Optional[object] = None     # StagingService on the catalog path

    # -- unified byte accounting -------------------------------------------
    @property
    def total_bytes(self) -> int:
        """Dataset bytes (pre-replication), summed over entries."""
        return sum(r.total_bytes for r in self.reports)

    @property
    def staged_bytes(self) -> int:       # HookResult-compatible alias
        return self.total_bytes

    @property
    def delivered_bytes(self) -> int:
        """Bytes landed on node-local stores: every host gets a replica.
        Logical payload — a staging codec compresses the WIRE traffic
        (``net_bytes``), never what lands in node memory."""
        return self.n_hosts * self.total_bytes

    @property
    def fs_bytes(self) -> int:
        return sum(r.fs_bytes for r in self.reports)

    @property
    def fs_write_bytes(self) -> int:
        return sum(r.fs_write_bytes for r in self.reports)

    @property
    def net_bytes(self) -> int:
        """Interconnect WIRE bytes (compressed where a codec elected)."""
        return sum(r.net_bytes for r in self.reports)

    # -- compression reconciliation (wire vs payload) ----------------------
    @property
    def comp(self) -> CompressionStats:
        """Aggregated codec accounting over every entry's report."""
        total = CompressionStats()
        for r in self.reports:
            total.add(r.comp)
        return total

    @property
    def wire_bytes(self) -> int:
        """Alias of :attr:`net_bytes` making the wire semantics explicit."""
        return self.net_bytes

    @property
    def payload_net_bytes(self) -> int:
        """Logical bytes behind the wire traffic: what the interconnect
        would have moved with no codec (``net_bytes + comp.saved_bytes``)."""
        return self.net_bytes + self.comp.saved_bytes

    @property
    def bytes_saved(self) -> int:
        """Wire bytes a staging codec avoided moving (0 without one)."""
        return self.comp.saved_bytes

    # -- unified time accounting -------------------------------------------
    @property
    def broadcast_time(self) -> float:
        return sum(r.broadcast_time for r in self.reports)

    @property
    def stage_time(self) -> float:
        return sum(r.stage_time for r in self.reports)

    @property
    def comm_time(self) -> float:
        return sum(r.comm_time for r in self.reports)

    @property
    def write_time(self) -> float:
        return sum(r.write_time for r in self.reports)

    def accounting_closes(self, tol: float = 1e-9) -> bool:
        """True when the direct-path identities hold: glob metadata plus
        the per-entry report totals equals the end-to-end time, AND the
        byte story reconciles — each report's per-tier wire bytes sum to
        its net wire total, and the codec's compressed traffic is a
        subset of it (savings never negative)."""
        time_ok = abs(self.metadata_time + sum(r.total_time for r in
                                               self.reports)
                      - self.total_time) <= tol
        bytes_ok = all(sum(r.tier_bytes.values()) == r.net_bytes
                       for r in self.reports)
        comp_ok = all(r.comp.saved_bytes >= 0
                      and r.comp.wire_bytes <= r.net_bytes
                      for r in self.reports)
        return time_ok and bytes_ok and comp_ok


# ---------------------------------------------------------------------------
# the client facade
# ---------------------------------------------------------------------------

class StagingClient:
    """One handle over every way data reaches node-local memory.

    ``client.stage(spec_or_patterns, config)`` runs any registered
    one-shot engine (typed config selects it); with a
    :class:`ServiceConfig` — or a client constructed with
    ``service=`` — the same call routes through the long-lived dataset
    catalog (registration, coalescing, leases). ``client.session(name)``
    opens a context-managed analysis session whose leases auto-release
    on exit. ``client.stream_stager(config)`` hands out the incremental
    streamed-delivery driver for consumers that interleave ingest with
    compute (the online HEDM loop).

    `fabric` is the simulated cluster; `service` an optional
    :class:`~repro.core.datasvc.StagingService` or :class:`ServiceConfig`
    (built lazily); `registry` defaults to the process-wide
    :data:`ENGINES`; `trace` turns on timeline-resolved telemetry
    (``True`` builds a fresh `repro.core.telemetry.Tracer`, or pass your
    own) attached fabric-wide — spans/metrics record simulated time but
    NEVER change it (docs/observability.md). Off (the default) the
    fabric keeps the zero-cost :data:`~repro.core.telemetry.NULL_TRACER`.
    """

    def __init__(self, fabric: Fabric,
                 service: Optional[object] = None,
                 registry: EngineRegistry = ENGINES,
                 trace: Union[bool, Tracer] = False):
        self.fabric = fabric
        self.registry = registry
        if trace:
            fabric.attach_tracer(trace if isinstance(trace, Tracer)
                                 else Tracer())
        self._service = None
        self._service_config: Optional[ServiceConfig] = None
        if isinstance(service, ServiceConfig):
            self._service_config = service
        elif service is not None:
            self._service = service

    # -- telemetry ----------------------------------------------------------
    @property
    def tracer(self) -> TracerLike:
        """The fabric-wide tracer (the shared
        :data:`~repro.core.telemetry.NULL_TRACER` when tracing is off)."""
        return self.fabric.tracer

    def write_trace(self, path: str) -> str:
        """Export every recorded span as a Chrome trace-event JSON file
        (load it at https://ui.perfetto.dev); returns `path`.
        Raises when the client was built without ``trace=``."""
        if not self.fabric.tracer.enabled:
            raise ValueError(
                "tracing is off; construct StagingClient(fabric, "
                "trace=True) to record a timeline")
        return write_chrome_trace(self.fabric.tracer, path)

    def flight_report(self) -> str:
        """The plain-text flight-recorder report (critical-path breakdown
        per stage, tier attribution, FS contention, metrics digest)."""
        if not self.fabric.tracer.enabled:
            raise ValueError(
                "tracing is off; construct StagingClient(fabric, "
                "trace=True) to record a timeline")
        return flight_recorder(self.fabric.tracer)

    @property
    def planner(self) -> CollectivePlanner:
        """The `repro.core.collectives.CollectivePlanner` bound to the
        fabric's current topology — pure cost queries (``plan_*`` touches
        no traffic counters). A per-call ``TopologyConfig`` on an engine
        config rebinds it for that stage only."""
        return self.fabric.net.planner

    # -- service plumbing ---------------------------------------------------
    @property
    def service(self):
        """The attached :class:`~repro.core.datasvc.StagingService`
        (built on first use when the client was given a
        :class:`ServiceConfig`); None when the client is engine-only."""
        if self._service is None and self._service_config is not None:
            self._service = self._build_service(self._service_config)
        return self._service

    def _build_service(self, cfg: ServiceConfig):
        from repro.core.datasvc import StagingService
        return StagingService(self.fabric, cfg.budget_bytes,
                              engine=cfg.engine, registry=self.registry)

    def session(self, name: str) -> "ClientSession":
        """A context-managed analysis session on the attached service:
        every lease it still holds is released on ``__exit__`` (at the
        last simulated time the session observed, or pass
        ``close(t=...)`` explicitly), exception or not."""
        svc = self.service
        if svc is None:
            raise ValueError(
                "client has no staging service; construct it with "
                "StagingClient(fabric, service=ServiceConfig(...)) or an "
                "existing StagingService")
        return ClientSession(self, svc.session(name))

    def qos_scheduler(self, policy=None, loop=None):
        """An event-driven `repro.core.qos.QoSScheduler` over the attached
        service: concurrent sessions submit timed requests onto a shared
        `repro.core.events.EventLoop` and contend for the budget under the
        given `repro.core.qos.QoSPolicy` (default: the ``qos`` policy;
        pass ``repro.core.qos.FIFO`` for the arrival-order baseline)."""
        svc = self.service
        if svc is None:
            raise ValueError(
                "client has no staging service; construct it with "
                "StagingClient(fabric, service=ServiceConfig(...)) or an "
                "existing StagingService")
        from repro.core.qos import QoSScheduler
        return QoSScheduler(svc, policy=policy, loop=loop)

    # -- staging ------------------------------------------------------------
    def stage(self, what: Stageable,
              config: Optional[Union[EngineConfig, ServiceConfig]] = None,
              t0: float = 0.0, session: str = "client",
              resolve: bool = True, pin: bool = True) -> Report:
        """Stage `what` (a :class:`StagingSpec`, a glob pattern, or a
        sequence of patterns) starting at simulated time `t0`.

        `config` selects the path: a typed engine config runs that
        one-shot engine; ``None`` on a service-attached client routes
        through the dataset catalog under `session` (the service's own
        engine is used — a spec-embedded engine config is ignored there);
        ``None`` otherwise defaults to the spec's embedded config, then
        :class:`CollectiveConfig`. A :class:`ServiceConfig` belongs in
        the CLIENT constructor, not here — passing one raises. With
        ``resolve=False`` the entry file lists are taken as CONCRETE
        shared-FS paths — no leader glob or manifest broadcast is run or
        charged (the programmatic path the HEDM runners use). `pin`
        applies only to the CONVENIENCE forms (a pattern or a path list,
        which become a single broadcast entry): ``pin=False`` leaves the
        replicas evictable, matching a bare engine call — a full
        :class:`StagingSpec` carries pinning per entry instead.

        Returns a unified :class:`Report`; on the catalog path its
        ``leases`` belong to the caller (use :meth:`session` to scope
        them so they can never leak).
        """
        spec = as_spec(what, pin=pin)
        if isinstance(config, ServiceConfig):
            # a per-call ServiceConfig would silently reroute LATER
            # config-less calls through the catalog (and leak leases with
            # no scope to release them) — the service is a property of
            # the CLIENT, so demand it at construction
            raise ValueError(
                "ServiceConfig configures the client, not a single call: "
                "construct StagingClient(fabric, service=ServiceConfig("
                "...)), then stage(..., config=None) routes through the "
                "catalog — ideally inside a `with client.session(name)` "
                "scope so the leases auto-release")
        has_service = (self._service is not None
                       or self._service_config is not None)
        if config is None and has_service:
            # the attached service wins over any spec-embedded engine
            # config: the service stages with ITS engine, and a session
            # scope must never silently fall back to an unleased direct
            # stage
            if not resolve:
                raise ValueError(
                    "resolve=False is not supported on the catalog path: "
                    "the service registers datasets by PATTERN (resolved "
                    "once by the leader root); pass concrete paths via "
                    "service.register(name, paths=...) instead")
            return self._stage_catalog(spec, self.service, session, t0)
        if config is None:
            config = spec.config or CollectiveConfig()
        return self._stage_direct(spec, config, t0, resolve)

    def _stage_direct(self, spec: StagingSpec, config: EngineConfig,
                      t0: float, resolve: bool) -> Report:
        entry_ = self.registry.entry_for(config)
        reports: List[StagingReport] = []
        all_files: List[str] = []
        t_meta = 0.0
        t = t0
        # a config-level FaultConfig scopes a what-if fault timeline to
        # THIS stage op (None -> the fabric's live schedule, trivially
        # empty on a healthy fabric — the exact pre-fault path)
        fault_cfg = getattr(config, "faults", None)
        sched = (fault_cfg.build(self.fabric.n_hosts)
                 if fault_cfg is not None else None)
        with self.fabric.net.scoped_faults(sched):
            for entry in spec.broadcasts:
                if resolve:
                    from repro.core.iohook import resolve_manifest_timed
                    # the manifest broadcast is part of the stage op: plan
                    # it under the config's topology too (None -> fabric
                    # binding)
                    with self.fabric.net.scoped_topology(
                            getattr(config, "topology", None)):
                        files, t_resolved, bcast = resolve_manifest_timed(
                            self.fabric, entry.files, t)
                    t_meta += t_resolved - t - bcast     # glob phase only
                    t = t_resolved
                else:
                    files, bcast = list(entry.files), 0.0
                kw = config.to_kw()
                if isinstance(config, StreamConfig):
                    self._check_window(config, files)
                    if entry.pin:
                        # the streaming engine must pin AT INGEST: with a
                        # bounded window, post-hoc pinning would mark
                        # already-evicted files
                        kw["pin_paths"] = list(files) + [
                            p for p in config.pin_paths if p not in files]
                rep, t = entry_.stage_fn(self.fabric, files, t, **kw)
                rep.broadcast_time = bcast           # on_root manifest push
                reports.append(rep)
                all_files.extend(files)
                if entry.pin:
                    # only hosts that received replicas hold pins (a dead
                    # host's store was never written; pinning it would
                    # strand a stale refcount past its recovery)
                    hosts = (self.fabric.hosts if self.fabric.faults.trivial
                             else self.fabric.live_hosts(t))
                    for host in hosts:
                        for f in files:
                            host.store.pin(f)
        return Report(engine=entry_.name, n_hosts=self.fabric.n_hosts,
                      resolved_files=all_files, reports=reports,
                      metadata_time=t_meta, total_time=t - t0)

    def _check_window(self, config: StreamConfig,
                      files: Sequence[str]) -> None:
        if config.window_bytes is None or not files:
            return
        biggest = max(self.fabric.fs.size(f) for f in files)
        if config.window_bytes < biggest:
            raise ValueError(
                f"window_bytes ({config.window_bytes}) is smaller than the "
                f"largest frame to be staged ({biggest} B): not even one "
                f"frame fits the node cache")

    def _stage_catalog(self, spec: StagingSpec, service, session,
                       t0: float) -> Report:
        """Catalog-backed staging: register + acquire through the service.
        Per-dataset reports are SHARED across coalesced acquisitions, so
        the direct-path accounting identity does not apply here;
        ``metadata_time`` still covers the registration glob phase only
        (the manifest broadcast lands in ``service.stats.broadcast_time``).
        """
        session_id = getattr(session, "session_id", session)
        reports: List[StagingReport] = []
        leases: List = []
        all_files: List[str] = []
        t_meta = 0.0
        t = t0
        t_end = t0
        for entry in spec.broadcasts:
            name = "|".join(entry.files)
            bcast0 = service.stats.broadcast_time
            ds, t_reg = service.register(name, patterns=entry.files, t=t)
            t_meta += (t_reg - t) - (service.stats.broadcast_time - bcast0)
            lease = service.acquire(session_id, name, t_reg)
            leases.append(lease)
            t = t_reg
            t_end = max(t_end, lease.t_ready)
            if ds.last_report is not None:
                reports.append(ds.last_report)
            all_files.extend(ds.paths)
        return Report(engine="service", n_hosts=self.fabric.n_hosts,
                      resolved_files=all_files, reports=reports,
                      metadata_time=t_meta, total_time=t_end - t0,
                      leases=leases, service=service)

    # -- live fault injection -----------------------------------------------
    def inject(self, kind: Union[FaultEvent, FaultKind, str],
               t: float = 0.0, *, host: Optional[int] = None,
               tier: Optional[str] = None, t_end: float = math.inf,
               factor: float = 1.0, apply: bool = True) -> FaultEvent:
        """Inject a LIVE fault into the fabric's timeline (unlike a
        config-level :class:`FaultConfig`, this mutates state: a host
        death wipes its node-local store when applied).

        `kind` is a :class:`~repro.core.faults.FaultKind` (or its string
        value, or a prebuilt :class:`~repro.core.faults.FaultEvent`);
        ``host`` names the victim for death/recovery, ``tier``/``t_end``/
        ``factor`` describe a degradation window. With ``apply=True``
        (default) the fault clock advances to the event time — through
        the attached service's ``sync_faults`` when there is one, so
        catalog entries transition to DEGRADED in the same call; pass
        ``apply=False`` to schedule a future event and let the next
        ``sync_faults``/``advance_faults`` pick it up."""
        if isinstance(kind, FaultEvent):
            ev = kind
        else:
            ev = FaultEvent(t, FaultKind(kind), host=host, tier=tier,
                            t_end=t_end, factor=factor)
        self.fabric.faults.inject(ev)
        if apply:
            # sync the catalog when a service is ATTACHED (never build one
            # just to sync — an unbuilt service has no entries to degrade)
            if self._service is not None:
                self._service.sync_faults(ev.t)
            else:
                self.fabric.advance_faults(ev.t)
        return ev

    # -- streamed delivery (incremental driver) -----------------------------
    def stream_stager(self, config: StreamConfig,
                      t0: float = 0.0) -> StreamStager:
        """The incremental streamed-delivery driver configured by
        `config` (``window_bytes`` is required here — an open-ended
        stream has no "whole set" to default to). ``pin_paths`` are
        pre-pinned on the stager (exempt from window eviction the moment
        they land). ``rate_hz`` belongs to the DETECTOR, not the
        delivery window: feed it to the
        :class:`~repro.core.streaming.DetectorSource` the caller attaches
        (as the online HEDM runner does). Use this when compute
        interleaves with ingest; for whole-set delivery just call
        :meth:`stage` with the same config."""
        if not isinstance(config, StreamConfig):
            raise ValueError(
                f"stream_stager needs a StreamConfig, got "
                f"{type(config).__name__}")
        if config.window_bytes is None:
            raise ValueError(
                "StreamConfig.window_bytes is required for an incremental "
                "stream stager (there is no dataset to default it to)")
        stager = StreamStager(self.fabric, window_bytes=config.window_bytes,
                              t0=t0, topology=config.topology,
                              compression=config.compression)
        for p in config.pin_paths:
            stager.pin(p)
        return stager


class ClientSession:
    """A session-scoped campaign: an
    :class:`~repro.core.datasvc.AnalysisSession` bound to its client.

    Context manager — ``__exit__`` releases every lease the session still
    holds (exception or not) at the last simulated time it observed, so a
    forgotten ``release`` can no longer wedge later admissions.
    ``stage(...)`` routes a spec through the catalog under this session,
    with the resulting leases owned (and therefore auto-released) here.
    Everything else (``acquire``/``release``/``put_result``/``flush``/
    ``tag``/``close``) delegates to the underlying session.
    """

    def __init__(self, client: StagingClient, session) -> None:
        self._client = client
        self._session = session

    def __getattr__(self, name: str):
        return getattr(self._session, name)

    def stage(self, what: Stageable, t0: Optional[float] = None) -> Report:
        """Catalog-backed stage under this session at `t0` (default: the
        last simulated time this session observed). ALWAYS routes through
        the service — a spec-embedded engine config is ignored here (the
        service stages with its own engine), so the session's lease
        guarantees can never be silently bypassed."""
        t = self._session._t_last if t0 is None else t0
        rep = self._client._stage_catalog(as_spec(what),
                                          self._session.service,
                                          self._session, t)
        self._session.note(t + rep.total_time)
        return rep

    def __enter__(self) -> "ClientSession":
        self._session.__enter__()
        return self

    def __exit__(self, exc_type, exc, tb):
        return self._session.__exit__(exc_type, exc, tb)


def deprecated_call(old: str, new: str) -> None:
    """Emit the one shared deprecation message for a legacy surface."""
    warnings.warn(
        f"{old} is a compatibility shim over the unified staging client "
        f"API; migrate to {new} (see docs/api.md)",
        DeprecationWarning, stacklevel=3)

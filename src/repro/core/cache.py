"""Application-memory input caching (paper §VI-B).

"We modified NF-HEDM to cache all inputs in application memory (for each
variable, tasks first check to see if it has already been read, if not, they
perform read operations to instantiate it). Since Swift/T reuses the same
processes for subsequent tasks, HEDM tasks after the first do not need to
perform Read operations at all."

``TaskInputCache`` is that layer: a per-worker-process memoization of
deserialized inputs above the node-local store. First access pays the
node-local read; subsequent accesses are free. Also provides the pinned
reuse across human-in-the-loop cycles.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Set, Tuple

import numpy as np

from repro.core.fabric import Fabric, NodeLocalStore, pin_ref, unpin_ref


@dataclass
class TaskInputCache:
    """Per-process in-memory cache over a node-local store.

    ``capacity_bytes`` bounds the deserialized working set (bytes; default
    16 GiB ~ a BG/Q I/O-node's RAM share); beyond it, entries evict FIFO.
    ``read_time_charged`` accumulates SIMULATED seconds spent on cache
    misses (``size / local_read_bw``) — hits are free, which is exactly
    the §VI-B effect; no wall-clock time is ever involved."""
    store: NodeLocalStore
    capacity_bytes: int = 1 << 34
    _mem: Dict[str, Any] = field(default_factory=dict)
    _sizes: Dict[str, int] = field(default_factory=dict)
    _pins: Dict[str, int] = field(default_factory=dict)   # lease refcounts
    _faulted: Set[str] = field(default_factory=set)       # ever faulted in
    hits: int = 0
    misses: int = 0
    read_time_charged: float = 0.0      # simulated seconds spent on misses

    def get(self, path: str,
            deserialize: Callable[[np.ndarray], Any] = lambda b: b
            ) -> Optional[Any]:
        """The deserialized value of `path`, or None if it is resident on
        neither this cache nor the backing node-local store.

        `deserialize` maps the raw uint8 buffer to the application object
        (parsed once, on the miss that faults it in); the raw byte size —
        not the deserialized footprint — is what counts against
        ``capacity_bytes`` and the charged read time."""
        if path in self._mem:
            self.hits += 1              # free: already in application memory
            return self._mem[path]
        raw = self.store.read(path)
        if raw is None:
            if path in self._faulted:
                # a path this cache HELD is now resident nowhere: the
                # backing store force-dropped it (NodeLocalStore.drop
                # clears its pins) — mirror that, or the stale pin would
                # shield a later re-staged copy from capacity eviction
                # forever. A pin placed AHEAD of first staging (never
                # faulted) is live intent and survives.
                self._pins.pop(path, None)
                self._faulted.discard(path)
            return None
        self.misses += 1
        self.read_time_charged += raw.size / self.store.constants.local_read_bw
        val = deserialize(raw)
        self._put(path, val, raw.size)
        self._faulted.add(path)
        return val

    def _put(self, path: str, val: Any, size: int) -> None:
        total = sum(self._sizes.values()) + size
        if total > self.capacity_bytes:
            # one ordered sweep (FIFO ~ LRU-ish, unpinned): the seed
            # restarted the victim generator per eviction — O(n) per
            # victim, O(n^2) per put on a cold cache full of small entries
            for victim in list(self._mem):
                if total <= self.capacity_bytes:
                    break
                if victim in self._pins:
                    continue
                total -= self._sizes.pop(victim)
                del self._mem[victim]
        self._mem[path] = val
        self._sizes[path] = size

    def pin(self, path: str) -> None:
        """Exempt `path` from capacity eviction (lease-aware: a dataset
        leased from the staging service stays deserialized across task
        waves). Refcounted — each pin needs a matching :meth:`unpin`."""
        pin_ref(self._pins, path)

    def unpin(self, path: str) -> None:
        """Drop one pin reference; the entry becomes evictable once the
        last holder unpins. No-op when `path` is not pinned."""
        unpin_ref(self._pins, path)

    def drop(self, path: str) -> None:
        """Force-drop `path` from this cache, mirroring
        `repro.core.fabric.NodeLocalStore.drop`: any pin refs go with the
        entry (a forced drop must not leave stale pins that would shield
        a later re-faulted copy). Pure bookkeeping — no time charged."""
        self._mem.pop(path, None)
        self._sizes.pop(path, None)
        self._pins.pop(path, None)
        self._faulted.discard(path)

    @property
    def resident_bytes(self) -> int:
        """Raw bytes currently held (the eviction accounting basis)."""
        return sum(self._sizes.values())

"""Shared discrete-event timeline for concurrent simulated sessions.

Everything in the simulator is driven by callers passing explicit
simulated times ``t`` (`repro.core.fabric`'s accounting discipline).
That contract has a latent serial-clock assumption: shared-resource
state (``SharedFilesystem.busy_until``, the catalog's admission queue)
is mutated in PROGRAM order, so two sessions interleaved out of
timestamp order would see causally impossible state. The
:class:`EventLoop` here makes the timeline explicit: independent
sessions, stages, streams, repairs and fault injections are scheduled
as timestamped events and executed in GLOBAL simulated-time order with
deterministic tie-breaking — which is exactly what lets them genuinely
overlap (contending for FS bandwidth and node memory) instead of
serializing on call order.

Determinism: events fire in ``(t, priority, seq)`` order. ``seq`` is a
monotone issue counter, so two events at the same instant and priority
fire in the order they were scheduled — the same schedule always
replays identically (the property the invariant suite in
``tests/test_events.py`` pins down). Scheduling into the past raises
:class:`CausalityError`: time never runs backwards on a shared
timeline.

The loop runs callbacks; it moves no bytes and charges no time itself.
`repro.core.qos.QoSScheduler` drives a
`repro.core.datasvc.StagingService` on one of these loops; the
many-task engine's internal heap (`repro.core.manytask`) is the same
idiom specialized to task dispatch.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple


class CausalityError(RuntimeError):
    """Raised when an event is scheduled before the loop's current time."""


@dataclass
class Event:
    """One timestamped callback on the shared timeline.

    ``priority`` breaks ties at equal ``t`` (lower fires first), ``seq``
    breaks ties at equal ``(t, priority)`` (schedule order). ``key`` is
    a free-form label (a session id, a host, ``"fault"``) recorded in
    the loop's history — the invariant suite asserts per-key timestamp
    monotonicity over it."""
    t: float
    priority: int
    seq: int
    fn: Callable[[], None] = field(compare=False)
    key: Optional[str] = field(default=None, compare=False)
    canceled: bool = field(default=False, compare=False)

    def sort_key(self) -> Tuple[float, int, int]:
        return (self.t, self.priority, self.seq)


class EventLoop:
    """Priority queue of timestamped events with deterministic replay.

    ``now`` only moves forward; an event's callback may schedule further
    events at any ``t >= now`` (including ``now`` itself — it fires in
    this same drain, after anything already due there with a smaller
    ``(priority, seq)``)."""

    def __init__(self, t0: float = 0.0, history_limit: int = 100_000,
                 history_key_limit: Optional[int] = None):
        self.now = t0
        self.fired = 0
        # fired events, firing order — a ring buffer so a long-running
        # loop's memory stays bounded. `history_limit` caps the global
        # retention; `history_key_limit` (optional) additionally caps
        # retention PER `key`, so one chatty session cannot crowd every
        # other key out of the window. `fired` keeps counting either way;
        # `history_dropped` counts evictions.
        self.history: Deque[Event] = deque()
        self.history_limit = history_limit
        self.history_key_limit = history_key_limit
        self.history_dropped = 0
        self._key_counts: Dict[Optional[str], int] = {}
        self._heap: List[Tuple[Tuple[float, int, int], Event]] = []
        self._seq = 0

    # -- scheduling ---------------------------------------------------------
    def schedule(self, t: float, fn: Callable[[], None], *,
                 priority: int = 0, key: Optional[str] = None) -> Event:
        """Schedule ``fn`` to fire at simulated time `t`; returns the
        :class:`Event` handle (pass it to :meth:`cancel`)."""
        if t < self.now:
            raise CausalityError(
                f"cannot schedule an event at t={t:.6f} < now={self.now:.6f}"
                f" (key={key!r}): the shared timeline only moves forward")
        ev = Event(t=float(t), priority=priority, seq=self._seq, fn=fn,
                   key=key)
        self._seq += 1
        heapq.heappush(self._heap, (ev.sort_key(), ev))
        return ev

    def schedule_after(self, delay: float, fn: Callable[[], None], *,
                       priority: int = 0, key: Optional[str] = None
                       ) -> Event:
        """Schedule ``fn`` `delay` seconds after ``now`` — the natural
        form for callbacks that compute a duration while handling the
        current event (a consumer finishing `delay` after a frame lands,
        a credit granted one ack later).  A negative delay is a
        causality violation like any past-scheduling."""
        if delay < 0:
            raise CausalityError(
                f"cannot schedule an event {-delay:.6f}s in the past "
                f"(key={key!r}): the shared timeline only moves forward")
        return self.schedule(self.now + delay, fn, priority=priority,
                             key=key)

    def cancel(self, event: Event) -> None:
        """Cancel `event`; a canceled event is skipped silently."""
        event.canceled = True

    # -- inspection ---------------------------------------------------------
    @property
    def pending(self) -> int:
        """Count of scheduled, not-yet-fired, not-canceled events."""
        return sum(1 for _, ev in self._heap if not ev.canceled)

    def peek(self) -> Optional[float]:
        """Timestamp of the next event to fire, or None when drained."""
        while self._heap and self._heap[0][1].canceled:
            heapq.heappop(self._heap)
        return self._heap[0][1].t if self._heap else None

    # -- execution ----------------------------------------------------------
    def _record(self, ev: Event) -> None:
        """Append `ev` to the bounded fired-history ring buffer."""
        self.history.append(ev)
        self._key_counts[ev.key] = self._key_counts.get(ev.key, 0) + 1
        if (self.history_key_limit is not None
                and self._key_counts[ev.key] > self.history_key_limit):
            # evict the OLDEST event with this key (the deque stays in
            # firing order; only the matching entry is removed)
            for i, old in enumerate(self.history):
                if old.key == ev.key:
                    del self.history[i]
                    break
            self._key_counts[ev.key] -= 1
            self.history_dropped += 1
        while len(self.history) > self.history_limit:
            old = self.history.popleft()
            self._key_counts[old.key] -= 1
            if self._key_counts[old.key] == 0:
                del self._key_counts[old.key]
            self.history_dropped += 1

    def step(self) -> Optional[Event]:
        """Fire exactly the next event (advancing ``now`` to it); returns
        it, or None when the timeline is drained."""
        while self._heap:
            _, ev = heapq.heappop(self._heap)
            if ev.canceled:
                continue
            self.now = ev.t
            self.fired += 1
            self._record(ev)
            ev.fn()
            return ev
        return None

    def run(self, until: float = math.inf) -> float:
        """Fire every event with ``t <= until`` (in timeline order,
        including events scheduled along the way); returns the new
        ``now`` — the last firing time, or `until` when it is finite."""
        while True:
            t_next = self.peek()
            if t_next is None or t_next > until:
                break
            self.step()
        if math.isfinite(until) and until > self.now:
            self.now = until
        return self.now

    def advance(self, t: float) -> float:
        """Alias of ``run(until=t)`` — drain the timeline up to `t`."""
        return self.run(until=t)

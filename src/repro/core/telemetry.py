"""Timeline-resolved telemetry: span tracing, metrics, trace export.

The simulator's accounting discipline (`repro.core.fabric`) produces
end-of-run aggregates — ``tier_bytes``, ``busy_time``, ``StagingReport``
totals — which say *how much* but never *when*. This module adds the
instrument on the discrete-event timeline: a :class:`Tracer` records
hierarchical spans stamped in SIMULATED time (never wall clock), a
:class:`MetricsRegistry` collects counters, gauges and fixed-bucket
histograms, and two exporters turn a recording into something a human
can read — Chrome trace-event JSON (:func:`to_chrome_trace`, loadable in
Perfetto / ``chrome://tracing``) and a plain-text flight-recorder report
(:func:`flight_recorder`) with a critical-path breakdown of where each
stage's simulated seconds went.

The contract carried over from the fault and QoS layers: telemetry is
STRICTLY additive. Every instrumentation site in the fabric guards on
``tracer.enabled`` (the default :data:`NULL_TRACER` is off), so the
disabled path is the exact pre-telemetry code path — all quick-parity
anchors bit-exact — and the enabled path only RECORDS simulated times
computed by the existing arithmetic; it never feeds back into them.

Span taxonomy, metrics catalog and exporter how-tos are documented in
``docs/observability.md``.
"""
from __future__ import annotations

import bisect
import json
import math
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterator, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np


def exact_percentile(values: Sequence[float], p: float) -> float:
    """The shared percentile everyone quotes: ``np.percentile`` with its
    default linear interpolation, returned as a plain float. QoS summary
    latencies (`repro.core.qos.QoSScheduler.summary`) and the benchmark
    anchors route through here so the recorded baselines stay bit-exact
    no matter who computes the number."""
    return float(np.percentile(np.asarray(list(values), dtype=float), p))


# -- metrics ----------------------------------------------------------------

# Simulated-seconds histogram edges: geometric 100us .. 1000s, generous
# enough for a single collective and an 8K-host QoS campaign alike.
DEFAULT_SECONDS_BUCKETS: Tuple[float, ...] = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1,
    1.0, 3.0, 10.0, 30.0, 100.0, 300.0, 1000.0)


@dataclass
class Counter:
    """Monotone event counter."""
    name: str
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


@dataclass
class Gauge:
    """A sampled time series of ``(simulated t, value)`` points — e.g.
    per-tier bandwidth utilization or stream-cache resident bytes. Points
    are kept in record order; exporters emit them as Chrome ``C``
    (counter-track) events."""
    name: str
    series: List[Tuple[float, float]] = field(default_factory=list)

    def record(self, t: float, value: float) -> None:
        self.series.append((float(t), float(value)))

    @property
    def last(self) -> Optional[float]:
        return self.series[-1][1] if self.series else None


class Histogram:
    """Fixed-bucket histogram with closed-form percentile estimation.

    ``buckets`` are ascending upper bounds (``le`` semantics); one
    implicit overflow bucket catches everything above the last edge.
    :meth:`percentile` linearly interpolates within the target bucket
    assuming a uniform in-bucket distribution (Prometheus
    ``histogram_quantile`` semantics), clamped to the observed
    ``[min, max]`` — so a single-bucket histogram has an exact closed
    form the tests pin down."""

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_SECONDS_BUCKETS):
        if list(buckets) != sorted(buckets) or len(buckets) == 0:
            raise ValueError(f"histogram {name!r}: bucket edges must be "
                             f"non-empty and ascending, got {buckets!r}")
        self.name = name
        self.edges: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def percentile(self, p: float) -> float:
        """Estimated p-th percentile (``0 <= p <= 100``) from the bucket
        counts alone; ``nan`` when empty."""
        if self.count == 0:
            return math.nan
        target = (p / 100.0) * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = 0.0 if i == 0 else self.edges[i - 1]
                hi = self.edges[i] if i < len(self.edges) else self.vmax
                frac = (target - cum) / c
                est = lo + frac * (hi - lo)
                return min(max(est, self.vmin), self.vmax)
            cum += c
        return self.vmax

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "count": self.count, "sum": self.total,
            "min": self.vmin if self.count else None,
            "max": self.vmax if self.count else None,
            "buckets": {f"le_{e:g}": c
                        for e, c in zip(self.edges, self.counts)},
            "overflow": self.counts[-1],
        }
        for p in (50, 90, 99):
            q = self.percentile(p)
            out[f"p{p}"] = None if math.isnan(q) else q
        return out


class MetricsRegistry:
    """Name-addressed registry of counters, gauges and histograms.

    ``counter``/``gauge``/``histogram`` are get-or-create (one instance
    per name for the registry's lifetime); :meth:`snapshot` returns a
    JSON-able dict — the ``metrics`` block embedded in every
    ``BENCH_*.json``."""

    def __init__(self) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_SECONDS_BUCKETS
                  ) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, buckets)
        return h

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {n: self.counters[n].value
                         for n in sorted(self.counters)},
            "gauges": {n: {"n": len(g.series), "last": g.last,
                           "min": (min(v for _, v in g.series)
                                   if g.series else None),
                           "max": (max(v for _, v in g.series)
                                   if g.series else None)}
                       for n, g in sorted(self.gauges.items())},
            "histograms": {n: self.histograms[n].snapshot()
                           for n in sorted(self.histograms)},
        }


# -- spans ------------------------------------------------------------------

@dataclass
class Span:
    """One closed interval of simulated time on a named track.

    ``parent`` is the enclosing span's ``span_id`` (None for roots);
    ``track`` is the coarse UI row family (``engine``, ``fs``, ``net``,
    ``net/<tier>``, ``svc``, ``qos``, ``stream``). ``t_end == t_start``
    marks an instant (a lifecycle transition)."""
    name: str
    t_start: float
    t_end: float
    track: str = "main"
    parent: Optional[int] = None
    span_id: int = -1
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


class Tracer:
    """Records :class:`Span`\\ s and owns a :class:`MetricsRegistry`.

    Two recording styles:

      * :meth:`span` — a completed interval, parented to the innermost
        open :meth:`region` (or an explicit ``parent``).
      * :meth:`region` — a context manager opening a span whose end is
        not yet known; spans recorded inside auto-nest under it. The
        caller sets ``sp.t_end`` before the block exits (it defaults to
        the start time otherwise — telemetry never invents durations).

    Every fabric instrumentation site guards on :attr:`enabled`, so a
    :class:`NullTracer` (``enabled = False``) costs one attribute check
    and nothing else."""

    enabled = True

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.spans: List[Span] = []
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._stack: List[Span] = []

    # -- recording ----------------------------------------------------------
    def _resolve_parent(self, parent: Union[None, int, Span]
                        ) -> Tuple[Optional[int], Optional[str]]:
        if isinstance(parent, Span):
            return parent.span_id, parent.track
        if parent is not None:
            return parent, None
        if self._stack:
            top = self._stack[-1]
            return top.span_id, top.track
        return None, None

    def span(self, name: str, t_start: float, t_end: float,
             track: Optional[str] = None,
             parent: Union[None, int, Span] = None, **attrs: Any) -> Span:
        """Record a completed span; returns it."""
        pid, ptrack = self._resolve_parent(parent)
        sp = Span(name=name, t_start=float(t_start), t_end=float(t_end),
                  track=track or ptrack or "main", parent=pid,
                  span_id=len(self.spans), attrs=attrs)
        self.spans.append(sp)
        return sp

    def instant(self, name: str, t: float, track: Optional[str] = None,
                **attrs: Any) -> Span:
        """Record a zero-duration lifecycle event at simulated `t`."""
        return self.span(name, t, t, track=track, **attrs)

    @contextmanager
    def region(self, name: str, t_start: float,
               track: Optional[str] = None, **attrs: Any) -> Iterator[Span]:
        """Open a span covering the ``with`` block; see class docstring."""
        sp = self.span(name, t_start, math.nan, track=track, **attrs)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            self._stack.pop()
            if math.isnan(sp.t_end):
                sp.t_end = sp.t_start

    # -- inspection ---------------------------------------------------------
    def roots(self, track: Optional[str] = None) -> List[Span]:
        """Top-level spans (no parent), optionally filtered by track."""
        return [s for s in self.spans if s.parent is None
                and (track is None or s.track == track)]

    def children(self, span: Span) -> List[Span]:
        """Direct children of `span`, in record order."""
        return [s for s in self.spans if s.parent == span.span_id]


class _NullMetric:
    """Shared sink behind :class:`NullTracer`: every recording method is
    a no-op, so even un-guarded metric calls on the off path cannot
    accumulate state."""

    def inc(self, n: float = 1.0) -> None:
        pass

    def record(self, t: float, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass


_NULL_METRIC = _NullMetric()


class _NullRegistry:
    def counter(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, name: str, buckets: Sequence[float] = ()
                  ) -> _NullMetric:
        return _NULL_METRIC

    def snapshot(self) -> Dict[str, Any]:
        return {"counters": {}, "gauges": {}, "histograms": {}}


class NullTracer:
    """The default, disabled tracer: records nothing, costs an attribute
    check. Instrumentation sites MUST guard span/metric recording on
    ``tracer.enabled`` — only :meth:`region` (used as a structural
    ``with``) is expected to run on the off path, and it yields a shared
    dummy span."""

    enabled = False

    def __init__(self) -> None:
        self.spans: Tuple[Span, ...] = ()
        self.metrics = _NullRegistry()
        self._dummy = Span("null", 0.0, 0.0)

    def span(self, name: str, t_start: float, t_end: float,
             track: Optional[str] = None,
             parent: Union[None, int, Span] = None, **attrs: Any) -> Span:
        return self._dummy

    def instant(self, name: str, t: float, track: Optional[str] = None,
                **attrs: Any) -> Span:
        return self._dummy

    @contextmanager
    def region(self, name: str, t_start: float,
               track: Optional[str] = None, **attrs: Any) -> Iterator[Span]:
        yield self._dummy

    def roots(self, track: Optional[str] = None) -> List[Span]:
        return []

    def children(self, span: Span) -> List[Span]:
        return []


NULL_TRACER = NullTracer()

TracerLike = Union[Tracer, NullTracer]


# -- Chrome trace-event export ---------------------------------------------

def _assign_lanes(spans: List[Span]) -> Dict[int, int]:
    """Greedy interval partitioning of ROOT spans into display lanes
    (Chrome ``tid``\\ s): a root goes to the first lane whose previous
    occupant ended by its start, so overlapping roots (concurrent QoS
    requests) get separate rows while a serial stream (the FS busy
    timeline) stays on one. Children inherit the root's lane."""
    lanes: List[float] = []
    out: Dict[int, int] = {}
    for sp in sorted(spans, key=lambda s: (s.t_start, s.span_id)):
        for i, end in enumerate(lanes):
            if end <= sp.t_start:
                lanes[i] = sp.t_end
                out[sp.span_id] = i + 1
                break
        else:
            lanes.append(sp.t_end)
            out[sp.span_id] = len(lanes)
    return out


def to_chrome_trace(tracer: Tracer) -> Dict[str, Any]:
    """Export a recording as Chrome trace-event JSON (the dict; dump it
    with :func:`write_chrome_trace`). Loadable in Perfetto
    (https://ui.perfetto.dev) and ``chrome://tracing``:

      * one PROCESS per track (``engine``, ``fs``, ``net``,
        ``net/<tier>``, ``svc``, ``qos``, ``stream``) with a
        ``process_name`` metadata event;
      * root spans laid out on greedy non-overlapping THREAD lanes,
        children on their root's lane — Perfetto then renders the
        parent/child nesting by interval containment;
      * spans as ``ph:"X"`` complete events (``ts``/``dur`` in
        microseconds of simulated time), instants as ``ph:"i"``, gauge
        series as ``ph:"C"`` counter tracks under a ``metrics`` process.
    """
    tracks: List[str] = sorted({s.track for s in tracer.spans})
    pid_of = {track: i + 1 for i, track in enumerate(tracks)}
    events: List[Dict[str, Any]] = []
    for track in tracks:
        events.append({"ph": "M", "name": "process_name",
                       "pid": pid_of[track], "tid": 0,
                       "args": {"name": track}})

    # lane assignment per track, roots only; children inherit
    tid_of: Dict[int, int] = {}
    by_id = {s.span_id: s for s in tracer.spans}
    for track in tracks:
        roots = [s for s in tracer.spans
                 if s.track == track and
                 (s.parent is None or by_id[s.parent].track != track)]
        tid_of.update(_assign_lanes(roots))
    for sp in tracer.spans:            # record order = parents first
        if sp.span_id not in tid_of:
            tid_of[sp.span_id] = tid_of.get(sp.parent, 1)

    for sp in tracer.spans:
        args = {k: v for k, v in sp.attrs.items() if v is not None}
        args["span_id"] = sp.span_id
        if sp.parent is not None:
            args["parent"] = sp.parent
        base = {"name": sp.name, "cat": sp.track, "pid": pid_of[sp.track],
                "tid": tid_of[sp.span_id], "ts": sp.t_start * 1e6,
                "args": args}
        if sp.t_end == sp.t_start:
            events.append({**base, "ph": "i", "s": "t"})
        else:
            events.append({**base, "ph": "X", "dur": sp.duration * 1e6})

    gauges = getattr(tracer.metrics, "gauges", {})
    if gauges:
        mpid = len(tracks) + 1
        events.append({"ph": "M", "name": "process_name", "pid": mpid,
                       "tid": 0, "args": {"name": "metrics"}})
        for name in sorted(gauges):
            for t, v in gauges[name].series:
                events.append({"ph": "C", "name": name, "pid": mpid,
                               "tid": 0, "ts": t * 1e6, "args": {name: v}})
    return {"traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"clock": "simulated", "spans": len(tracer.spans)}}


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    """Dump :func:`to_chrome_trace` JSON to `path`; returns `path`."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer), f)
    return path


_VALID_PHASES = {"X", "i", "M", "C"}


def validate_chrome_trace(trace: Dict[str, Any]) -> int:
    """Assert `trace` is structurally valid trace-event JSON (the subset
    this module emits); returns the event count. Used by the exporter
    tests and the CI telemetry smoke."""
    assert isinstance(trace, dict) and "traceEvents" in trace, (
        "trace must be a JSON object with a traceEvents list")
    events = trace["traceEvents"]
    assert isinstance(events, list) and events, "traceEvents empty"
    for ev in events:
        assert ev.get("ph") in _VALID_PHASES, f"bad phase in {ev!r}"
        assert isinstance(ev.get("pid"), int), f"bad pid in {ev!r}"
        assert isinstance(ev.get("tid"), int), f"bad tid in {ev!r}"
        if ev["ph"] in ("X", "i", "C"):
            assert isinstance(ev.get("ts"), (int, float)), f"no ts: {ev!r}"
        if ev["ph"] == "X":
            assert isinstance(ev.get("dur"), (int, float)), f"no dur: {ev!r}"
            assert ev["dur"] >= 0, f"negative dur: {ev!r}"
        if ev["ph"] in ("X", "i"):
            assert isinstance(ev.get("name"), str), f"no name: {ev!r}"
    return len(events)


# -- flight recorder --------------------------------------------------------

def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024 or unit == "TiB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}TiB"


def flight_recorder(tracer: Tracer) -> str:
    """Plain-text post-mortem of a recording: per-stage critical-path
    breakdown (phase children of each ``stage.*`` span — they partition
    the stage's total by construction), per-tier wire-time/byte
    attribution from the collective tier spans, FS busy-vs-wait totals,
    and a metrics digest. Everything quoted is SIMULATED seconds."""
    lines: List[str] = []
    spans = tracer.spans
    lines.append("== flight recorder (simulated time) ==")
    lines.append(f"spans: {len(spans)}  "
                 f"tracks: {', '.join(sorted({s.track for s in spans}))}")

    kids: Dict[Optional[int], List[Span]] = {}
    for s in spans:
        kids.setdefault(s.parent, []).append(s)

    stage_roots = [s for s in spans if s.parent is None
                   and s.name.startswith(("stage.", "stream.frame"))]
    for root in stage_roots:
        total = root.duration
        hdr = ", ".join(f"{k}={v}" for k, v in sorted(root.attrs.items())
                        if not isinstance(v, dict))
        lines.append("")
        lines.append(f"{root.name} [{root.t_start:.6f} -> "
                     f"{root.t_end:.6f}]  total {total:.6f}s"
                     + (f"  ({hdr})" if hdr else ""))
        want = ("stream." if root.name == "stream.frame" else "phase.")
        phases = [c for c in kids.get(root.span_id, ())
                  if c.name.startswith(want)]
        attributed = 0.0
        best: Tuple[float, str] = (0.0, "-")
        for c in phases:
            share = c.duration / total if total > 0 else 0.0
            attributed += c.duration
            best = max(best, (c.duration, c.name))
            lines.append(f"  {c.name:<22s} {c.duration:12.6f}s "
                         f"{100 * share:6.1f}%")
        rest = total - attributed
        if abs(rest) > 1e-12 * max(1.0, abs(total)):
            lines.append(f"  {'(unattributed)':<22s} {rest:12.6f}s")
        if phases:
            lines.append(f"  critical path: {best[1]} "
                         f"({100 * best[0] / total if total else 0:.1f}%)")

    tier_time: Dict[str, float] = {}
    tier_nbytes: Dict[str, float] = {}
    for s in spans:
        if s.name.startswith("tier."):
            tier = s.name[len("tier."):]
            tier_time[tier] = tier_time.get(tier, 0.0) + s.duration
            tier_nbytes[tier] = tier_nbytes.get(tier, 0.0) \
                + s.attrs.get("nbytes", 0)
    if tier_time:
        lines.append("")
        lines.append("tier attribution (wire time per topology tier):")
        for tier in sorted(tier_time):
            dt, nb = tier_time[tier], tier_nbytes[tier]
            bw = nb / dt if dt > 0 else 0.0
            lines.append(f"  {tier:<12s} {dt:12.6f}s  "
                         f"{_fmt_bytes(nb):>10s}  {bw / 1e9:8.2f} GB/s")

    comp_c = sum(s.duration for s in spans if s.name == "comp.compress")
    comp_d = sum(s.duration for s in spans if s.name == "comp.decompress")
    counters = tracer.metrics.snapshot()["counters"]
    comp_payload = counters.get("comp.payload_bytes", 0)
    comp_wire = counters.get("comp.wire_bytes", 0)
    if comp_payload or comp_c or comp_d:
        wire_s = sum(tier_time.values())
        ratio = comp_payload / comp_wire if comp_wire else 1.0
        lines.append("")
        lines.append(
            f"compression: {_fmt_bytes(comp_payload)} payload -> "
            f"{_fmt_bytes(comp_wire)} wire ({ratio:.2f}x, "
            f"{_fmt_bytes(counters.get('comp.bytes_saved', 0))} saved), "
            f"codec {comp_c + comp_d:.6f}s "
            f"(compress {comp_c:.6f}s / decompress {comp_d:.6f}s) "
            f"vs wire {wire_s:.6f}s")

    wan_pulls = [s for s in spans if s.name == "wan.pull"]
    if wan_pulls:
        pull_s = sum(s.duration for s in wan_pulls)
        retry_s = sum(s.duration for s in spans
                      if s.name == "wan.retransmit")
        retries = sum(s.attrs.get("retries", 0) for s in spans
                      if s.name == "wan.retransmit")
        credit_s = sum(s.duration for s in spans if s.name == "wan.credit")
        drops = sum(1 for s in spans if s.name == "wan.drop")
        lines.append("")
        lines.append(f"WAN ingest: {len(wan_pulls)} pulls {pull_s:.6f}s, "
                     f"retransmit {retry_s:.6f}s ({retries:g} retries), "
                     f"credit-wait {credit_s:.6f}s, {drops} drops")

    fs_busy = sum(s.duration for s in spans
                  if s.track == "fs" and s.name != "fs.wait")
    fs_wait = sum(s.duration for s in spans if s.name == "fs.wait")
    if fs_busy or fs_wait:
        lines.append("")
        lines.append(f"shared FS: busy {fs_busy:.6f}s, "
                     f"contention wait {fs_wait:.6f}s")

    snap = tracer.metrics.snapshot()
    if snap["counters"] or snap["histograms"]:
        lines.append("")
        lines.append("metrics:")
        for name, val in snap["counters"].items():
            lines.append(f"  {name:<32s} {val:g}")
        for name, h in snap["histograms"].items():
            if h["count"]:
                lines.append(f"  {name:<32s} n={h['count']} "
                             f"p50={h['p50']:.6f}s p99={h['p99']:.6f}s")
    return "\n".join(lines) + "\n"

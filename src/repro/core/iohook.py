"""The Swift I/O hook, reimplemented (paper §IV, Fig. 6).

A declarative staging spec — "broadcast these files to this node-local
destination" — executed by the runtime before tasks run. Mirrors the paper:

  * the spec can come from an environment variable (``REPRO_IO_HOOK``), as
    ``SWIFT_IO_HOOK`` did;
  * glob resolution happens ONCE (leader rank 0) and the resolved list is
    broadcast — metadata contention avoidance (§IV: "only one process
    performs any globs");
  * transfers default to collective staging; ``mode`` selects the engine —
    ``"collective"`` (two-phase MPI_File_read_all), ``"pipelined"``
    (chunked read/all-gather overlap), ``"naive"`` (uncoordinated per-host
    reads, the baseline), or ``"stream"`` (detector-push ingestion that
    never reads the shared FS back — `repro.core.streaming`);
  * files are pinned in the node-local store for reuse across task waves.

All times returned are SIMULATED seconds (see `repro.core.fabric`).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.fabric import Fabric
from repro.core.leader import LeaderGroup
from repro.core.staging import BATCH_STAGE_FNS, StagingReport
from repro.core.streaming import stage_stream

_STAGE_FNS = {**BATCH_STAGE_FNS, "stream": stage_stream}


@dataclass(frozen=True)
class BroadcastEntry:
    """One broadcast directive: glob patterns -> node-local destination."""
    files: Tuple[str, ...]
    dest: str = "/tmp"
    pin: bool = True


@dataclass
class StagingSpec:
    """Fig. 6 analogue. JSON-serializable so it can ride an env var."""
    broadcasts: List[BroadcastEntry] = field(default_factory=list)

    @classmethod
    def from_json(cls, text: str) -> "StagingSpec":
        raw = json.loads(text)
        return cls(broadcasts=[
            BroadcastEntry(files=tuple(b["files"]), dest=b.get("dest", "/tmp"),
                           pin=b.get("pin", True))
            for b in raw.get("broadcasts", [])])

    def to_json(self) -> str:
        return json.dumps({"broadcasts": [
            {"files": list(b.files), "dest": b.dest, "pin": b.pin}
            for b in self.broadcasts]})

    @classmethod
    def from_env(cls, env: str = "REPRO_IO_HOOK") -> Optional["StagingSpec"]:
        text = os.environ.get(env)
        return cls.from_json(text) if text else None


@dataclass
class HookResult:
    resolved_files: List[str]
    reports: List[StagingReport]
    metadata_time: float
    total_time: float
    # catalog-backed mode only: the leases this hook acquired, one per
    # broadcast entry. The CALLER owns them — release each via
    # ``service.release(lease.session_id, lease.dataset, t)`` when done,
    # or the datasets stay pinned/unevictable forever.
    leases: List = field(default_factory=list)

    @property
    def staged_bytes(self) -> int:
        return sum(r.total_bytes for r in self.reports)


def resolve_manifest_timed(fabric: Fabric, patterns: Sequence[str], t0: float
                           ) -> Tuple[List[str], float, float]:
    """Leader-rank metadata resolution with a phase breakdown.

    ONE process (the leader-group root) runs the globs, then the resolved
    list is broadcast to the other leaders via
    :meth:`repro.core.leader.LeaderGroup.on_root` (a naive implementation
    runs the glob on every rank, congesting the FS — paper §IV).

    `patterns` are fnmatch globs against the shared FS; `t0` the simulated
    start time (s). Returns ``(resolved paths, completion time,
    broadcast seconds)`` — the broadcast is included in the completion
    time AND reported separately so callers can charge it into
    ``StagingReport.broadcast_time``."""
    leaders = LeaderGroup(fabric)
    glob_done = [t0]

    def root_globs() -> List[str]:
        files: List[str] = []
        t = t0
        for pattern in patterns:
            names, t = fabric.fs.glob(pattern, t)
            files.extend(names)
        glob_done[0] = t
        return files

    files, bcast = leaders.on_root(root_globs)
    return files, glob_done[0] + bcast, bcast


def resolve_manifest(fabric: Fabric, patterns: Sequence[str], t0: float
                     ) -> Tuple[List[str], float]:
    """:func:`resolve_manifest_timed` without the breakdown — returns
    ``(resolved paths, completion time)``, broadcast included."""
    files, t, _ = resolve_manifest_timed(fabric, patterns, t0)
    return files, t


def run_io_hook(fabric: Fabric, spec: StagingSpec, t0: float = 0.0,
                collective: bool = True, mode: Optional[str] = None,
                stage_kw: Optional[Dict] = None,
                service=None, session: str = "iohook") -> HookResult:
    """Execute the hook: resolve globs once, broadcast the manifest, stage.

    Parameters: `spec` is the declarative staging spec (Fig. 6); `t0` the
    simulated start time (s); ``mode`` selects the staging engine
    ("collective", "pipelined", "naive", "stream") and overrides the
    legacy ``collective`` flag when given; ``stage_kw`` forwards
    engine-specific keywords (e.g. ``{"chunk_bytes": 1 << 20}`` for
    pipelined, ``{"rate_hz": 10.0, "window_bytes": ...}`` for stream).
    Returns a :class:`HookResult` whose times are simulated seconds.

    The leader metadata broadcast (the root's resolved manifest pushed to
    the other leaders) is charged into each report's ``broadcast_time``;
    ``metadata_time`` covers the glob phase only, so
    ``metadata_time + sum(report total_times) == total_time``.

    **Catalog-backed mode**: pass ``service`` (a
    :class:`repro.core.datasvc.StagingService`) to route each broadcast
    entry through the long-lived dataset catalog instead of staging
    directly — the entry registers as a dataset (named by its pattern
    tuple) and is acquired under ``session``. Concurrent hook runs
    against the same service COALESCE into one collective stage, replicas
    stay lease-pinned until the session releases them, and the staging
    engine/params are the service's (``mode``/``stage_kw`` are ignored).
    The acquired leases come back in ``HookResult.leases`` and belong to
    the caller: release them (``service.release(lease.session_id,
    lease.dataset, t)``) when the session is done, or the datasets stay
    unevictable and later admissions can wedge.
    """
    if service is not None:
        return _run_io_hook_catalog(fabric, spec, t0, service, session)
    if mode is None:
        mode = "collective" if collective else "naive"
    if mode not in _STAGE_FNS:
        raise ValueError(f"unknown staging mode {mode!r}; expected one of "
                         f"{sorted(_STAGE_FNS)}")
    stage = _STAGE_FNS[mode]
    stage_kw = stage_kw or {}
    reports: List[StagingReport] = []
    t_meta = 0.0
    t = t0
    all_files: List[str] = []
    for entry in spec.broadcasts:
        files, t_resolved, bcast = resolve_manifest_timed(
            fabric, entry.files, t)
        t_meta += t_resolved - t - bcast     # glob phase only
        t = t_resolved
        kw = stage_kw
        if mode == "stream" and entry.pin:
            # the streaming engine must pin AT INGEST: with a bounded
            # window, post-hoc pinning would mark already-evicted files
            kw = dict(stage_kw, pin_paths=files)
        rep, t = stage(fabric, files, t, **kw)
        rep.broadcast_time = bcast           # on_root manifest broadcast
        reports.append(rep)
        all_files.extend(files)
        if entry.pin:
            for host in fabric.hosts:
                for f in files:
                    host.store.pin(f)
    return HookResult(resolved_files=all_files, reports=reports,
                      metadata_time=t_meta, total_time=t - t0)


def _run_io_hook_catalog(fabric: Fabric, spec: StagingSpec, t0: float,
                         service, session: str) -> HookResult:
    """Catalog-backed hook execution: register + acquire through a
    :class:`repro.core.datasvc.StagingService`. Reports are the datasets'
    last staging reports — SHARED across coalesced hook runs (a second
    hook that joins an in-flight stage sees the same report object), so
    the per-hook accounting identity of the direct modes (metadata_time +
    report totals == total_time) does not apply here; ``metadata_time``
    still covers the registration glob phase only (the manifest broadcast
    lands in ``service.stats.broadcast_time``)."""
    reports: List[StagingReport] = []
    leases: List = []
    all_files: List[str] = []
    t_meta = 0.0
    t = t0
    t_end = t0
    for entry in spec.broadcasts:
        name = "|".join(entry.files)
        bcast0 = service.stats.broadcast_time
        ds, t_reg = service.register(name, patterns=entry.files, t=t)
        t_meta += (t_reg - t) - (service.stats.broadcast_time - bcast0)
        lease = service.acquire(session, name, t_reg)
        leases.append(lease)
        t = t_reg
        t_end = max(t_end, lease.t_ready)
        if ds.last_report is not None:
            reports.append(ds.last_report)
        all_files.extend(ds.paths)
    return HookResult(resolved_files=all_files, reports=reports,
                      metadata_time=t_meta, total_time=t_end - t0,
                      leases=leases)


def naive_per_rank_globs(fabric: Fabric, patterns: Sequence[str],
                         t0: float = 0.0) -> float:
    """The anti-pattern (every rank globs): returns completion time, for the
    metadata-contention comparison benchmark."""
    t_end = t0
    for _ in range(fabric.n_ranks):
        t = t0
        for pattern in patterns:
            _, t = fabric.fs.glob(pattern, t)
        t_end = max(t_end, t)
    return t_end - t0

"""The Swift I/O hook, reimplemented (paper §IV, Fig. 6).

A declarative staging spec — "broadcast these files to this node-local
destination" — executed by the runtime before tasks run. Mirrors the paper:

  * the spec can come from an environment variable (``REPRO_IO_HOOK``), as
    ``SWIFT_IO_HOOK`` did;
  * glob resolution happens ONCE (leader rank 0) and the resolved list is
    broadcast — metadata contention avoidance (§IV: "only one process
    performs any globs");
  * transfers default to collective staging; every engine registered in
    `repro.core.api.ENGINES` is selectable;
  * files are pinned in the node-local store for reuse across task waves.

Since the unified client API landed, this module is the COMPATIBILITY
layer: :func:`run_io_hook` is a thin shim over
`repro.core.api.StagingClient` (its ``mode``/``collective``/``stage_kw``
arguments are deprecated but honored), and
:class:`~repro.core.api.StagingSpec` / :class:`~repro.core.api.BroadcastEntry`
live in ``repro.core.api`` (re-exported here). New code should call
``StagingClient.stage(spec, config)`` with a typed engine config directly
— see ``docs/api.md`` for the migration table. The leader-side metadata
resolution (:func:`resolve_manifest_timed`) still lives here and is what
the client charges for glob + manifest broadcast.

All times returned are SIMULATED seconds (see `repro.core.fabric`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# Compatibility re-exports: the spec types moved to the unified API.
from repro.core.api import (ENGINES, BroadcastEntry, StagingClient,  # noqa: F401
                            StagingSpec, deprecated_call)
from repro.core.fabric import Fabric
from repro.core.leader import LeaderGroup
from repro.core.staging import StagingReport


@dataclass
class HookResult:
    """Legacy hook accounting — the pre-client shape of
    `repro.core.api.Report`, kept for the shim's callers."""
    resolved_files: List[str]
    reports: List[StagingReport]
    metadata_time: float
    total_time: float
    # catalog-backed mode only: the leases this hook acquired, one per
    # broadcast entry. The CALLER owns them — release each via
    # ``service.release(lease.session_id, lease.dataset, t)`` when done,
    # or the datasets stay pinned/unevictable forever. (The client API's
    # ``client.session(...)`` context manager does this automatically.)
    leases: List = field(default_factory=list)

    @property
    def staged_bytes(self) -> int:
        return sum(r.total_bytes for r in self.reports)


def resolve_manifest_timed(fabric: Fabric, patterns: Sequence[str], t0: float
                           ) -> Tuple[List[str], float, float]:
    """Leader-rank metadata resolution with a phase breakdown.

    ONE process (the leader-group root) runs the globs, then the resolved
    list is broadcast to the other leaders via
    :meth:`repro.core.leader.LeaderGroup.on_root` (a naive implementation
    runs the glob on every rank, congesting the FS — paper §IV).

    `patterns` are fnmatch globs against the shared FS; `t0` the simulated
    start time (s). Returns ``(resolved paths, completion time,
    broadcast seconds)`` — the broadcast is included in the completion
    time AND reported separately so callers can charge it into
    ``StagingReport.broadcast_time``."""
    leaders = LeaderGroup(fabric)
    glob_done = [t0]

    def root_globs() -> List[str]:
        files: List[str] = []
        t = t0
        for pattern in patterns:
            names, t = fabric.fs.glob(pattern, t)
            files.extend(names)
        glob_done[0] = t
        return files

    files, bcast = leaders.on_root(root_globs)
    return files, glob_done[0] + bcast, bcast


def resolve_manifest(fabric: Fabric, patterns: Sequence[str], t0: float
                     ) -> Tuple[List[str], float]:
    """:func:`resolve_manifest_timed` without the breakdown — returns
    ``(resolved paths, completion time)``, broadcast included."""
    files, t, _ = resolve_manifest_timed(fabric, patterns, t0)
    return files, t


def run_io_hook(fabric: Fabric, spec: StagingSpec, t0: float = 0.0,
                collective: Optional[bool] = None, mode: Optional[str] = None,
                stage_kw: Optional[Dict] = None,
                service=None, session: str = "iohook") -> HookResult:
    """Execute the hook: resolve globs once, broadcast the manifest, stage.

    .. deprecated:: compatibility shim over
       `repro.core.api.StagingClient` — prefer ``StagingClient(fabric)
       .stage(spec, config)`` with a typed engine config
       (``CollectiveConfig``/``PipelinedConfig``/``NaiveConfig``/
       ``StreamConfig``), or ``StagingClient(fabric, service=...)`` with a
       ``client.session(...)`` scope for the catalog path. The legacy
       arguments keep working: ``mode`` (an engine name from the
       `repro.core.api.ENGINES` registry) overrides the ``collective``
       boolean, and ``stage_kw`` loose keywords are validated into the
       engine's typed config (unknown modes and unknown parameters raise
       ``ValueError`` listing the registered alternatives). A spec that
       embeds its own engine config is honored — exactly as the client
       honors it — when none of ``mode``/``collective``/``stage_kw`` are
       given.

    `spec` is the declarative staging spec (Fig. 6); `t0` the simulated
    start time (s). Returns a :class:`HookResult` whose times are
    simulated seconds. The leader metadata broadcast is charged into each
    report's ``broadcast_time``; ``metadata_time`` covers the glob phase
    only, so ``metadata_time + sum(report total_times) == total_time``.

    **Catalog-backed mode**: pass ``service`` (a
    :class:`repro.core.datasvc.StagingService`) to route each broadcast
    entry through the long-lived dataset catalog under ``session`` —
    concurrent hook runs coalesce, the service's engine is used
    (``mode``/``stage_kw`` are ignored), and the acquired leases come
    back in ``HookResult.leases``, owned by the caller.
    """
    deprecated_call("run_io_hook", "repro.core.api.StagingClient.stage")
    client = StagingClient(fabric, service=service)
    if service is not None:
        rep = client.stage(spec, t0=t0, session=session)
    elif (mode is None and stage_kw is None and collective is None
          and spec.config is not None):
        # the spec fully selects its transport (engine block in the
        # JSON): honor it, exactly as the client does
        rep = client.stage(spec, t0=t0)
    else:
        if mode is None:
            mode = "naive" if collective is False else "collective"
        config = ENGINES.config_for(mode, **(stage_kw or {}))
        rep = client.stage(spec, config, t0=t0)
    return HookResult(resolved_files=rep.resolved_files, reports=rep.reports,
                      metadata_time=rep.metadata_time,
                      total_time=rep.total_time, leases=rep.leases)


def naive_per_rank_globs(fabric: Fabric, patterns: Sequence[str],
                         t0: float = 0.0) -> float:
    """The anti-pattern (every rank globs): returns completion time, for the
    metadata-contention comparison benchmark."""
    t_end = t0
    for _ in range(fabric.n_ranks):
        t = t0
        for pattern in patterns:
            _, t = fabric.fs.glob(pattern, t)
        t_end = max(t_end, t)
    return t_end - t0

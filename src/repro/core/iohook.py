"""The Swift I/O hook, reimplemented (paper §IV, Fig. 6).

A declarative staging spec — "broadcast these files to this node-local
destination" — executed by the runtime before tasks run. Mirrors the paper:

  * the spec can come from an environment variable (``REPRO_IO_HOOK``), as
    ``SWIFT_IO_HOOK`` did;
  * glob resolution happens ONCE (leader rank 0) and the resolved list is
    broadcast — metadata contention avoidance (§IV: "only one process
    performs any globs");
  * transfers default to collective staging; ``mode`` selects the engine —
    ``"collective"`` (two-phase MPI_File_read_all), ``"pipelined"``
    (chunked read/all-gather overlap), ``"naive"`` (uncoordinated per-host
    reads, the baseline), or ``"stream"`` (detector-push ingestion that
    never reads the shared FS back — `repro.core.streaming`);
  * files are pinned in the node-local store for reuse across task waves.

All times returned are SIMULATED seconds (see `repro.core.fabric`).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.fabric import Fabric
from repro.core.staging import BATCH_STAGE_FNS, StagingReport
from repro.core.streaming import stage_stream

_STAGE_FNS = {**BATCH_STAGE_FNS, "stream": stage_stream}


@dataclass(frozen=True)
class BroadcastEntry:
    """One broadcast directive: glob patterns -> node-local destination."""
    files: Tuple[str, ...]
    dest: str = "/tmp"
    pin: bool = True


@dataclass
class StagingSpec:
    """Fig. 6 analogue. JSON-serializable so it can ride an env var."""
    broadcasts: List[BroadcastEntry] = field(default_factory=list)

    @classmethod
    def from_json(cls, text: str) -> "StagingSpec":
        raw = json.loads(text)
        return cls(broadcasts=[
            BroadcastEntry(files=tuple(b["files"]), dest=b.get("dest", "/tmp"),
                           pin=b.get("pin", True))
            for b in raw.get("broadcasts", [])])

    def to_json(self) -> str:
        return json.dumps({"broadcasts": [
            {"files": list(b.files), "dest": b.dest, "pin": b.pin}
            for b in self.broadcasts]})

    @classmethod
    def from_env(cls, env: str = "REPRO_IO_HOOK") -> Optional["StagingSpec"]:
        text = os.environ.get(env)
        return cls.from_json(text) if text else None


@dataclass
class HookResult:
    resolved_files: List[str]
    reports: List[StagingReport]
    metadata_time: float
    total_time: float

    @property
    def staged_bytes(self) -> int:
        return sum(r.total_bytes for r in self.reports)


def resolve_manifest(fabric: Fabric, patterns: Sequence[str], t0: float
                     ) -> Tuple[List[str], float]:
    """Leader-rank metadata resolution: ONE process runs the globs, then the
    list is broadcast (a naive implementation runs the glob on every rank,
    congesting the FS — paper §IV).

    `patterns` are fnmatch globs against the shared FS; `t0` the simulated
    start time (s). Returns ``(resolved paths, completion time)``, the
    broadcast of the (small) manifest included."""
    files: List[str] = []
    t = t0
    for pattern in patterns:
        names, t = fabric.fs.glob(pattern, t)
        files.extend(names)
    # broadcast the (small) manifest to all leaders
    manifest_bytes = sum(len(f) for f in files) + 8 * len(files)
    t += fabric.net.broadcast_time(max(manifest_bytes, 1), fabric.n_hosts)
    return files, t


def run_io_hook(fabric: Fabric, spec: StagingSpec, t0: float = 0.0,
                collective: bool = True, mode: Optional[str] = None,
                stage_kw: Optional[Dict] = None) -> HookResult:
    """Execute the hook: resolve globs once, broadcast the manifest, stage.

    Parameters: `spec` is the declarative staging spec (Fig. 6); `t0` the
    simulated start time (s); ``mode`` selects the staging engine
    ("collective", "pipelined", "naive", "stream") and overrides the
    legacy ``collective`` flag when given; ``stage_kw`` forwards
    engine-specific keywords (e.g. ``{"chunk_bytes": 1 << 20}`` for
    pipelined, ``{"rate_hz": 10.0, "window_bytes": ...}`` for stream).
    Returns a :class:`HookResult` whose times are simulated seconds.
    """
    if mode is None:
        mode = "collective" if collective else "naive"
    if mode not in _STAGE_FNS:
        raise ValueError(f"unknown staging mode {mode!r}; expected one of "
                         f"{sorted(_STAGE_FNS)}")
    stage = _STAGE_FNS[mode]
    stage_kw = stage_kw or {}
    reports: List[StagingReport] = []
    t_meta = 0.0
    t = t0
    all_files: List[str] = []
    for entry in spec.broadcasts:
        files, t_resolved = resolve_manifest(fabric, entry.files, t)
        t_meta += t_resolved - t
        t = t_resolved
        kw = stage_kw
        if mode == "stream" and entry.pin:
            # the streaming engine must pin AT INGEST: with a bounded
            # window, post-hoc pinning would mark already-evicted files
            kw = dict(stage_kw, pin_paths=files)
        rep, t = stage(fabric, files, t, **kw)
        reports.append(rep)
        all_files.extend(files)
        if entry.pin:
            for host in fabric.hosts:
                for f in files:
                    host.store.pin(f)
    return HookResult(resolved_files=all_files, reports=reports,
                      metadata_time=t_meta, total_time=t - t0)


def naive_per_rank_globs(fabric: Fabric, patterns: Sequence[str],
                         t0: float = 0.0) -> float:
    """The anti-pattern (every rank globs): returns completion time, for the
    metadata-contention comparison benchmark."""
    t_end = t0
    for _ in range(fabric.n_ranks):
        t = t0
        for pattern in patterns:
            _, t = fabric.fs.glob(pattern, t)
        t_end = max(t_end, t)
    return t_end - t0

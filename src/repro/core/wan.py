"""Cross-facility WAN ingest: detector -> wide-area tier -> compute pods.

The paper stages detector data through a shared parallel FS inside one
machine; the follow-on deployments (Welborn et al., streaming detector
data from a beamline into Perlmutter compute nodes across ESnet) move
the detector OUTSIDE the cluster entirely.  This module models that
cross-facility hop as a first-class subsystem on top of the existing
stack — nothing below it changes arithmetic:

  * **Topology** — the ``wan_beamline`` canned machine
    (`repro.core.topology.WAN_BEAMLINE`): the whole pod is one "rack" on
    fast cluster links, so delivery collectives stay local, while the
    ingest hop crosses a ``wan`` tier with ~10 Gb/s of bandwidth and
    25 ms latency. WAN weather is a seeded
    `repro.core.faults.FaultSchedule.wan_jitter` timeline of transient
    degradation windows scaling that tier.
  * **Pull-based flow control** — :class:`WanSession`: the detector owns
    a bounded DAQ frame buffer and may only push a frame across the WAN
    while it holds a *send credit*.  Consumers grant one credit back each
    time a frame is fully released from the node window, so the credit
    window caps unconsumed in-flight frames and the node cache can never
    wedge (the credit window is validated against the window budget).
    When the producer buffer overflows waiting for credits, the OLDEST
    frame is overwritten (DAQ ring-buffer semantics) and accounted in
    ``frames_dropped`` — never silently.  Everything is scheduled on the
    shared `repro.core.events.EventLoop` timeline: emissions, sends,
    per-subscriber consumption, acks, credit grants.
  * **Publish/subscribe fan-out** — :class:`WanFanout`: N subscriber
    campaigns tap ONE WAN stream.  Each frame crosses the WAN once and
    fans out locally through the existing
    `repro.core.streaming.StreamStager` scatter + ring-broadcast plans;
    per-subscriber cursors are the stager's multi-consumer acks, and a
    frame is retained until the SLOWEST subscriber passes it (the
    watermark — surfaced as ``StreamReport.watermark_frame`` /
    ``watermark_lag`` / ``consumer_lag``).
  * **Loss model** — seeded stop-and-wait retransmission on the WAN hop:
    each attempt re-serializes the frame
    (`repro.core.collectives.CollectivePlanner.plan_point_to_point` with
    ``attempts=k``), so retransmits cost both time and ingest-tier
    bytes.  Zero loss draws nothing from the RNG and takes the exact
    lossless plan.
  * **Engine** — :func:`stage_wan`, registered as ``"wan"`` in
    `repro.core.api.ENGINES` (typed config: ``WanStreamConfig``) so
    ``StagingClient.stage`` drives it like any other engine, with
    telemetry spans (``wan.pull``, ``wan.credit``, ``wan.retransmit``)
    riding the PR 8 tracer.

**Regression anchor**: with zero jitter, zero loss and a credit window
that never binds (the defaults), the WAN path issues exactly the same
``StreamStager.ingest`` calls in the same order as
`repro.core.streaming.stage_stream` — byte- and time-exact, asserted in
``tests/test_wan.py`` and the ``--wan --quick`` bench smoke.

Units: simulated SECONDS and real BYTES throughout (see
`repro.core.fabric`); replicas are zero-copy read-only views and stay
byte-exact.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import (Deque, Dict, List, Optional, Sequence, Tuple, Union)

import numpy as np

from repro.core.collectives import CollectivePlanner
from repro.core.compression import CompressionLike
from repro.core.events import EventLoop
from repro.core.fabric import Fabric
from repro.core.faults import FaultSchedule
from repro.core.staging import StagingReport, _close_stage_span
from repro.core.streaming import (DetectorSource, FrameRecord, StreamReport,
                                  StreamStager)
from repro.core.topology import TopologyLike, resolve_topology


@dataclass
class WanReport:
    """Accounting for one WAN acquisition (all times simulated s).

    ``stream`` is the local fan-out's `repro.core.streaming.StreamReport`
    (per-subscriber lag and the slowest-subscriber watermark live there);
    the fields here are the WAN-side story: what crossed the wide-area
    tier, what got dropped at the detector, what the credit protocol
    cost."""
    n_subscribers: int = 1
    n_frames: int = 0            # frames the detector emitted
    frames_delivered: int = 0    # frames that crossed the WAN and landed
    frames_dropped: int = 0      # frames overwritten in the DAQ buffer
    retransmits: int = 0         # extra WAN attempts under the loss model
    wan_bytes: int = 0           # ingest-tier wire bytes (incl. retries)
    wan_time: float = 0.0        # total WAN serialization time
    credit_stall_time: float = 0.0  # producer waited for credits (sum)
    credits_granted: int = 0     # credits returned by consumers
    buffer_peak: int = 0         # DAQ-buffer high-water mark (frames)
    makespan: float = 0.0        # last t_avail - t0 (delivery-limited)
    drain_makespan: float = 0.0  # last subscriber ack - t0
    stream: Optional[StreamReport] = None


class WanFanout(StreamStager):
    """Pub/sub fan-out of ONE WAN stream with a seeded loss model.

    The WAN crossing IS the stager's detector->leader point-to-point hop
    (charged to the topology's ingest tier), so this subclass only
    overrides the :meth:`StreamStager._pull_time` seam: a seeded
    geometric draw decides how many stop-and-wait attempts the frame
    needs, and the hop is planned with ``attempts=k`` — time and
    ingest-tier bytes scale together.  ``loss_rate=0`` draws NOTHING
    from the RNG and takes the parent's exact lossless plan (the
    ``stage_stream`` parity anchor).  Everything else — scatter,
    ring broadcast, window policy, multi-consumer acks — is inherited
    unchanged: the local fan-out is the existing delivery machinery.
    """

    def __init__(self, fabric: Fabric, window_bytes: int, *,
                 loss_rate: float = 0.0, loss_seed: int = 0, **kw):
        super().__init__(fabric, window_bytes, **kw)
        if not 0.0 <= loss_rate < 1.0:
            raise ValueError(
                f"loss_rate must be in [0, 1) — a rate of 1 never "
                f"delivers (that is a partition, not loss), got "
                f"{loss_rate}")
        self.loss_rate = loss_rate
        self._loss_rng = np.random.default_rng(loss_seed)
        self.retransmits = 0     # extra attempts beyond the first
        self.wan_time = 0.0      # total ingest-hop serialization
        self.wan_bytes = 0       # ingest-tier bytes incl. retries

    def _pull_time(self, nbytes: int, t: float) -> float:
        attempts = 1
        if self.loss_rate > 0.0:
            while self._loss_rng.random() < self.loss_rate:
                attempts += 1
        plan = self.fabric.net.point_to_point(nbytes, t=t,
                                              attempts=attempts)
        dt = plan.time
        self.wan_time += dt
        # wire bytes: retransmissions re-send the *compressed* frame, so
        # an elected codec shrinks every attempt (== attempts * nbytes
        # when no codec is active)
        self.wan_bytes += plan.total_bytes
        if attempts > 1:
            self.retransmits += attempts - 1
        tr = self.fabric.tracer
        if tr.enabled:
            # record only: dt was computed above, untraced
            sp = tr.span("wan.pull", t, t + dt, track="wan",
                         nbytes=nbytes, wire_bytes=plan.total_bytes,
                         attempts=attempts)
            if attempts > 1:
                # failed attempts occupy the leading (k-1)/k of the hop
                tr.span("wan.retransmit", t, t + dt * (attempts - 1)
                        / attempts, track="wan", parent=sp,
                        retries=attempts - 1)
                tr.metrics.counter("wan.retransmits").inc(attempts - 1)
            tr.metrics.counter("wan.pulls").inc()
            tr.metrics.histogram("wan.pull_s").observe(dt)
        return dt


class _Subscriber:
    """One consumer campaign's cursor: where its busy clock stands."""

    __slots__ = ("name", "consume_s", "busy", "consumed")

    def __init__(self, name: str, consume_s: float, t0: float):
        self.name = name
        self.consume_s = consume_s   # per-frame processing time (s)
        self.busy = t0               # this subscriber's serialization clock
        self.consumed = 0


class WanSession:
    """One cross-facility acquisition on the shared event timeline.

    Wires the three WAN pieces together: a :class:`DetectorSource`
    emitting frames on the far side of the WAN, a bounded producer
    buffer with credit-gated sends, and a :class:`WanFanout` delivering
    each admitted frame to every node-local store with N subscriber
    campaigns acking it.  The protocol, entirely event-driven on a
    `repro.core.events.EventLoop`:

      1. *emit* — frame lands in the DAQ buffer; if the buffer exceeds
         ``buffer_frames`` the OLDEST waiting frame is overwritten
         (``frames_dropped``).
      2. *send* — while the buffer is non-empty and a credit is held:
         pop the oldest frame, spend a credit, and ingest it (the WAN
         pull + local fan-out). Time spent waiting for a credit is the
         frame's ``wan.credit`` span (``credit_stall_time``).
      3. *consume/ack* — each subscriber processes the frame
         ``consume_s`` after it is available (serialized per
         subscriber) and acks it: ``release(path, consumer=name)``.
      4. *credit grant* — once EVERY subscriber acked (the watermark
         passed the frame), one credit returns and blocked sends
         resume.

    Never-wedge guarantee: with an unpinned bounded window the number of
    unreleased resident frames can never exceed ``credit_window``, so
    ``credit_window * max_frame + pinned bytes <= window_bytes`` (checked
    at construction) means admission always fits after evicting released
    frames — the node cache cannot wedge, no matter the jitter.
    """

    def __init__(self, fabric: Fabric, source: DetectorSource, *,
                 window_bytes: Optional[int] = None,
                 credit_window: Optional[int] = None,
                 buffer_frames: Optional[int] = None,
                 subscribers: Union[int, Sequence[str]] = 1,
                 consume_hz: Union[None, float, Sequence[float]] = None,
                 loss_rate: float = 0.0, loss_seed: int = 0,
                 topology: TopologyLike = None,
                 compression: CompressionLike = None,
                 faults: Optional[FaultSchedule] = None,
                 pin_paths: Sequence[str] = (),
                 t0: float = 0.0, loop: Optional[EventLoop] = None):
        self.fabric = fabric
        self.t0 = t0
        self.loop = loop if loop is not None else EventLoop(t0=t0)
        self._faults = faults
        self._frames = [(fid, path, buf, t_emit)
                        for fid, path, buf, t_emit in source]
        sizes = [int(np.ascontiguousarray(buf).nbytes)
                 for _, _, buf, _ in self._frames]
        total = sum(sizes)
        max_frame = max(sizes, default=1)
        n_frames = len(self._frames)

        if isinstance(subscribers, int):
            if subscribers < 1:
                raise ValueError(
                    f"subscribers must be >= 1, got {subscribers}")
            names = [f"sub{i}" for i in range(subscribers)]
        else:
            names = [str(n) for n in subscribers]
            if not names:
                raise ValueError("subscribers must name at least one "
                                 "consumer campaign")
            if len(set(names)) != len(names):
                raise ValueError(f"duplicate subscriber names: {names}")
        if consume_hz is None:
            rates: List[Optional[float]] = [None] * len(names)
        elif isinstance(consume_hz, (int, float)):
            rates = [float(consume_hz)] * len(names)
        else:
            rates = [float(r) for r in consume_hz]
            if len(rates) != len(names):
                raise ValueError(
                    f"consume_hz lists {len(rates)} rates for "
                    f"{len(names)} subscribers")
        for r in rates:
            if r is not None and r <= 0:
                raise ValueError(
                    f"consume_hz must be a positive per-subscriber "
                    f"processing rate (or None for instant acks), got {r}")
        self._subs = [_Subscriber(n, 0.0 if r is None else 1.0 / r, t0)
                      for n, r in zip(names, rates)]

        window = int(window_bytes) if window_bytes is not None \
            else max(total, 1)
        if credit_window is None:
            credit_window = max(1, min(n_frames or 1, window // max_frame))
        if credit_window < 1:
            raise ValueError(
                f"credit_window must be >= 1, got {credit_window}")
        pin_set = set(pin_paths)
        pinned_bytes = sum(sz for (_, p, _, _), sz
                           in zip(self._frames, sizes) if p in pin_set)
        if (window < total
                and credit_window * max_frame + pinned_bytes > window):
            raise ValueError(
                f"flow control cannot guarantee progress: "
                f"credit_window={credit_window} x max frame "
                f"{max_frame} B + {pinned_bytes} B pinned exceeds the "
                f"{window} B node window — shrink the credit window or "
                f"grow the window budget")
        if buffer_frames is not None and buffer_frames < 1:
            raise ValueError(
                f"buffer_frames must be >= 1 (or None for an unbounded "
                f"DAQ buffer), got {buffer_frames}")
        self._buffer_cap = buffer_frames
        self._credits = credit_window
        self.credit_window = credit_window
        self._pin_set = pin_set
        self._buffer: Deque[Tuple[int, str, np.ndarray, float]] = deque()

        self.stager = WanFanout(fabric, window, loss_rate=loss_rate,
                                loss_seed=loss_seed, t0=t0,
                                topology=topology, compression=compression)
        for sub in self._subs:
            self.stager.register_consumer(sub.name)
        self.report = WanReport(n_subscribers=len(self._subs))

    # -- event handlers -----------------------------------------------------
    def _on_emit(self, fid: int, path: str, buf: np.ndarray,
                 t_emit: float) -> None:
        t = self.loop.now
        self._buffer.append((fid, path, buf, t_emit))
        self.report.n_frames += 1
        self.report.buffer_peak = max(self.report.buffer_peak,
                                      len(self._buffer))
        if (self._buffer_cap is not None
                and len(self._buffer) > self._buffer_cap):
            # DAQ ring buffer: the oldest waiting frame is overwritten
            old_fid, old_path, _, old_emit = self._buffer.popleft()
            self.report.frames_dropped += 1
            tr = self.fabric.tracer
            if tr.enabled:
                tr.span("wan.drop", old_emit, t, track="wan",
                        frame_id=old_fid, path=old_path)
                tr.metrics.counter("wan.drops").inc()
        self._try_send(t)

    def _try_send(self, t: float) -> None:
        while self._buffer and self._credits > 0:
            fid, path, buf, t_emit = self._buffer.popleft()
            self._credits -= 1
            wait = t - t_emit
            if wait > 0:
                self.report.credit_stall_time += wait
                tr = self.fabric.tracer
                if tr.enabled:
                    tr.span("wan.credit", t_emit, t, track="wan",
                            frame_id=fid, path=path)
                    tr.metrics.histogram("wan.credit_wait_s").observe(wait)
            rec = self.stager.ingest(path, buf, t_emit, t_offer=t)
            if path in self._pin_set:
                self.stager.pin(path)
            self.report.frames_delivered += 1
            for sub in self._subs:
                done = max(rec.t_avail, sub.busy) + sub.consume_s
                sub.busy = done
                self.loop.schedule_after(
                    done - t, partial(self._on_ack, sub, path),
                    key=f"wan.sub.{sub.name}")

    def _on_ack(self, sub: _Subscriber, path: str) -> None:
        t = self.loop.now
        sub.consumed += 1
        self.stager.release(path, t, consumer=sub.name)
        if self.stager.fully_released(path):
            # the watermark passed this frame: one credit returns
            self._credits += 1
            self.report.credits_granted += 1
            tr = self.fabric.tracer
            if tr.enabled:
                tr.metrics.counter("wan.credits").inc()
            self._try_send(t)

    # -- driver -------------------------------------------------------------
    def run(self) -> WanReport:
        """Play the whole acquisition on the event loop; returns the
        :class:`WanReport` (local fan-out accounting in ``.stream``)."""
        with self.fabric.net.scoped_faults(self._faults):
            for fid, path, buf, t_emit in self._frames:
                self.loop.schedule(t_emit,
                                   partial(self._on_emit, fid, path, buf,
                                           t_emit),
                                   key="wan.detector")
            self.loop.run()
        srep = self.stager.finish()
        rep = self.report
        rep.stream = srep
        rep.retransmits = self.stager.retransmits
        rep.wan_time = self.stager.wan_time
        rep.wan_bytes = self.stager.wan_bytes
        rep.makespan = srep.ingest_makespan
        rep.drain_makespan = self.loop.now - self.t0
        return rep

    @property
    def records(self) -> List[FrameRecord]:
        return self.stager.records


def stage_wan(fabric: Fabric, paths: Sequence[str], t0: float = 0.0,
              rate_hz: Optional[float] = None,
              window_bytes: Optional[int] = None,
              pin_paths: Sequence[str] = (),
              topology: TopologyLike = None,
              credit_window: Optional[int] = None,
              buffer_frames: Optional[int] = None,
              subscribers: Union[int, Sequence[str]] = 1,
              consume_hz: Union[None, float, Sequence[float]] = None,
              loss_rate: float = 0.0, loss_seed: int = 0,
              compression: CompressionLike = None,
              jitter_seed: Optional[int] = None, jitter_windows: int = 0,
              jitter_window_s: Optional[float] = None,
              jitter_factors: Tuple[float, float] = (0.3, 0.9),
              ) -> Tuple[StagingReport, float]:
    """Cross-facility staging engine (``mode="wan"``).

    `paths` replay from the producer's buffers across the WAN ingest
    tier (the shared FS is never read back, ``fs_bytes == 0``) and fan
    out locally to every node-local store.  ``window_bytes`` defaults to
    the whole set; like ``stage_stream`` the unbounded window keeps
    every frame resident at the end (the engine pins the set at ingest —
    subscriber acks then only drive the credit protocol).  A bounded
    window turns the node cache into a sliding window governed by the
    slowest subscriber's watermark.  ``jitter_seed`` overlays a seeded
    `repro.core.faults.FaultSchedule.wan_jitter` timeline of
    ``jitter_windows`` brownouts on the ingest tier, COMPOSED with
    whatever fault schedule the fabric already runs.  With the defaults
    (no jitter, no loss, credits never binding) the engine is byte- and
    time-exact vs ``stage_stream`` — the regression anchor.

    Returns ``(report, completion t)`` like every engine; the report's
    ``mode`` is ``"wan"``, ``n_chunks`` the delivered frame count, and
    the full :class:`WanReport` rides on ``report.wan``.
    """
    total = sum(fabric.fs.size(p) for p in paths)
    bounded = window_bytes is not None and window_bytes < total
    src = DetectorSource.replay_fs(fabric, paths, rate_hz=rate_hz, t0=t0)
    topo = (resolve_topology(topology) if topology is not None
            else fabric.net.topology)

    faults = None
    if jitter_seed is not None and jitter_windows > 0:
        # deterministic horizon estimate: acquisition span + every frame's
        # healthy WAN serialization, with slack — windows past delivery
        # are simply never consulted.  Planned (not executed): no bytes
        # are accounted by the estimate.
        sizes = [fabric.fs.size(p) for p in paths]
        planner = CollectivePlanner(topo, fabric.constants)
        xfer = sum(planner.plan_point_to_point(sz).time for sz in sizes)
        acq = (len(paths) / rate_hz) if rate_hz else 0.0
        horizon = t0 + acq + 4.0 * xfer + 1.0
        faults = FaultSchedule.wan_jitter(
            jitter_seed, horizon, tier=topo.ingest_tier.name,
            n_windows=jitter_windows, window=jitter_window_s,
            factor_range=jitter_factors)
        if not fabric.faults.trivial:
            # scoped_faults REPLACES the bound schedule, so compose the
            # jitter with the fabric's own timeline explicitly
            faults = FaultSchedule(list(fabric.faults.events)
                                   + list(faults.events))

    # unbounded window: the whole set stays resident (stage_stream
    # semantics) — pin everything at ingest so subscriber releases can
    # never evict; bounded: only the caller's pins are exempt
    pin_set = (set(pin_paths) | set(paths)) if not bounded \
        else set(pin_paths)

    with fabric.tracer.region("stage.wan", t0, track="engine") as tsp:
        session = WanSession(
            fabric, src, window_bytes=window_bytes or max(total, 1),
            credit_window=credit_window, buffer_frames=buffer_frames,
            subscribers=subscribers, consume_hz=consume_hz,
            loss_rate=loss_rate, loss_seed=loss_seed, topology=topology,
            compression=compression, faults=faults, pin_paths=pin_set, t0=t0)
        wrep = session.run()
        srep = wrep.stream

        rep = StagingReport(n_hosts=fabric.n_hosts,
                            total_bytes=srep.total_bytes, mode="wan")
        rep.stage_time = 0.0                   # no FS read phase at all
        rep.write_time = srep.total_bytes / fabric.constants.local_bw
        rep.comm_time = max(0.0, srep.ingest_makespan - rep.write_time)
        rep.fs_bytes = 0
        rep.net_bytes = srep.net_bytes
        rep.tier_bytes = dict(srep.tier_bytes)
        rep.comp = srep.comp
        rep.n_chunks = srep.n_frames
        rep.wan = wrep                        # full WAN-side accounting
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + srep.ingest_makespan

"""Collective planner: explicit algorithms over a hierarchical topology.

The pre-topology ``Interconnect`` hardcoded ONE algorithm per collective
(pipelined ring broadcast, ring all-gather) on ONE link class. This module
makes the choice explicit: a :class:`CollectivePlanner` bound to a
`repro.core.topology.Topology` plans each collective as a named algorithm,
selects by message size and host count via the cost model (unless the
topology pins an algorithm — :data:`~repro.core.topology.FLAT` pins the
legacy rings as a numeric regression anchor), and accounts the wire bytes
PER TIER, which is what a flat model cannot express.

Algorithms:

  broadcast   ``pipelined_ring``    — the legacy ring: the buffer streams
                                      once at the bottleneck tier plus
                                      (P-2) one-segment pipeline fills.
              ``binomial_tree``     — ceil(log2 P) doubling rounds; the
                                      first ceil(log2 R) rounds cross
                                      racks. Wins for small messages.
              ``scatter_allgather`` — van de Geijn: binomial scatter of
                                      1/P shards, then a ring all-gather.
              ``hierarchical``      — inter-rack binomial tree among rack
                                      leaders + parallel intra-rack
                                      pipelined rings. Collapses to the
                                      flat ring on a single rack.
  allgather   ``ring``              — the legacy P-1 step ring.
              ``hierarchical``      — intra-rack ring, leader ring of
                                      rack blocks, intra-rack broadcast
                                      of the foreign blocks.
  scatter     ``binomial``          — halving rounds down a binomial tree.
              ``hierarchical``      — inter-rack binomial of rack blocks,
                                      then intra-rack binomial.

Planning is PURE (no counters touched): ``plan_*`` returns a
:class:`CollectivePlan` with the duration and per-tier byte map;
`repro.core.fabric.Interconnect` executes plans and accumulates traffic.
All durations are SIMULATED seconds (`repro.core.fabric`), sizes bytes.

**Compression-aware planning** (``codec=`` on every ``plan_*``): the
planner elects compress-at-source PER TIER — tier ``T`` ships the
compressed representation iff

    n/Cc + n/Cd + compressed_size(n)/bw_T  <  n/bw_T

(per-transfer link latencies appear on both sides and cancel), where
``Cc``/``Cd`` are the codec's compress/decompress throughputs and
``bw_T`` the single-transfer tier bandwidth *including degradation*
(`repro.core.faults` tier factors shift the decision).  On elected
tiers every transfer's wire size is ``compressed_size(payload)``; the
codec edges are charged ONCE per plan (compress at the sending edge,
decompress at the receiving edge — parallel edges overlap).  The plan
then reports wire bytes in ``tier_bytes`` and the logical traffic in
``payload_tier_bytes``.  ``codec=None`` (or an identity codec) is the
bit-exact pre-compression path — the regression anchor.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple

from repro.core.compression import Codec
from repro.core.topology import LinkTier, Topology

TierBytes = Dict[str, int]


class LinkPartitionedError(RuntimeError):
    """A plan would cross a tier degraded to factor 0 (a partition)."""


@dataclass
class CollectivePlan:
    """One planned collective: the algorithm picked, its modeled duration
    and the wire traffic it will put on each topology tier.

    ``nbytes`` is the op's payload parameter (broadcast: message bytes;
    allgather: per-host shard bytes; scatter: total bytes at the root).

    ``tier_bytes`` is always WIRE traffic (what crosses each link);
    when a codec elected one or more tiers, ``payload_tier_bytes``
    carries the logical traffic the same plan would move raw, and
    ``time`` includes the once-per-plan codec edge charges.  Without an
    election the two byte maps are the same quantity and
    ``payload_tier_bytes`` stays ``None``.
    """
    op: str
    algorithm: str
    nbytes: int
    n_hosts: int
    time: float
    tier_bytes: TierBytes = field(default_factory=dict)
    rerouted: int = 0       # dead hosts the schedule was repaired around
    codec: str = "none"                       # codec the plan was made with
    compressed_tiers: Tuple[str, ...] = ()    # tiers shipping compressed
    payload_tier_bytes: Optional[TierBytes] = None
    compress_time: float = 0.0                # sending-edge codec charge
    decompress_time: float = 0.0              # receiving-edge codec charge

    @property
    def total_bytes(self) -> int:
        """Wire bytes summed over tiers (the legacy ``bytes_moved``)."""
        return sum(self.tier_bytes.values())

    @property
    def payload_bytes(self) -> int:
        """Logical bytes the plan delivers over the wire traffic."""
        per_tier = (self.payload_tier_bytes
                    if self.payload_tier_bytes is not None
                    else self.tier_bytes)
        return sum(per_tier.values())

    @property
    def bytes_saved(self) -> int:
        """Wire bytes compression removed (0 without an election)."""
        return self.payload_bytes - self.total_bytes

    @property
    def codec_time(self) -> float:
        """Seconds the plan spends in the codec (vs on the wire)."""
        return self.compress_time + self.decompress_time


def _add(bytes_: TierBytes, tier: LinkTier, nbytes: int) -> None:
    if nbytes:
        bytes_[tier.name] = bytes_.get(tier.name, 0) + int(nbytes)


class CollectivePlanner:
    """Plans broadcast/allgather/scatter over one topology + calibration.

    `topology` is the machine shape; `constants` any object with
    ``link_bw``/``link_latency`` (a `repro.core.fabric.FabricConstants`)
    that tiers with unset bandwidth/latency inherit — how :data:`FLAT`
    reproduces every calibration's legacy numbers exactly.
    """

    def __init__(self, topology: Topology, constants) -> None:
        self.topology = topology
        self.constants = constants
        # active-codec state for the algorithm bodies, set (with
        # try/finally) only around a plan whose election is non-empty —
        # the raw path never consults a codec, so it stays bit-exact
        self._codec: Optional[Codec] = None
        self._elected: FrozenSet[str] = frozenset()

    # -- tier primitives ----------------------------------------------------
    def _bw(self, tier: LinkTier, concurrent: int = 1) -> float:
        """Effective per-transfer bandwidth: the link rate, shared under
        the tier's bisection cap when `concurrent` transfers cross it.

        A degraded tier (``scale < 1``, see `repro.core.faults`) delivers
        the scaled rate; the healthy scale of exactly 1.0 skips the
        multiplication so zero-fault plans stay bit-exact."""
        bw = tier.bw if tier.bw is not None else self.constants.link_bw
        cap = tier.bisection_cap
        if tier.scale != 1.0:
            if tier.scale == 0.0:
                raise LinkPartitionedError(
                    f"link tier {tier.name!r} is partitioned (scale 0); "
                    f"no plan can cross it")
            bw *= tier.scale
            if cap is not None:
                cap *= tier.scale
        if cap is not None:
            bw = min(bw, cap / max(concurrent, 1))
        return bw

    def _lat(self, tier: LinkTier) -> float:
        return (tier.latency if tier.latency is not None
                else self.constants.link_latency)

    def _wire(self, tier: LinkTier, nbytes: int) -> int:
        """Bytes an `nbytes`-payload transfer puts on `tier`: the codec's
        compressed size on elected tiers, the payload itself otherwise.
        Applied PER TRANSFER (each message is compressed independently),
        so byte maps and step times stay consistent."""
        if self._codec is not None and tier.name in self._elected:
            return self._codec.compressed_size(nbytes)
        return nbytes

    def _xfer(self, tier: LinkTier, nbytes: int, concurrent: int = 1
              ) -> float:
        """Duration of `concurrent` simultaneous `nbytes`-payload
        transfers across `tier` (they overlap; the cap shares
        bandwidth). Wire size per transfer via :meth:`_wire`."""
        return self._wire(tier, nbytes) / self._bw(tier, concurrent) \
            + self._lat(tier)

    # -- compression election -----------------------------------------------
    def compression_wins(self, tier: LinkTier, codec: Optional[Codec],
                         nbytes: int) -> bool:
        """The closed-form per-tier decision: ship compressed on `tier`
        iff compress + decompress + compressed wire time beats raw wire
        time for one `nbytes` transfer —

            n/Cc + n/Cd + compressed_size(n)/bw_T  <  n/bw_T

        (the per-transfer latency appears on both sides and cancels).
        ``bw_T`` includes fault degradation, so a browned-out tier can
        flip the decision toward compression.  A partitioned tier is
        never elected (no plan can cross it anyway)."""
        if codec is None or codec.is_identity or nbytes <= 0:
            return False
        w = codec.compressed_size(nbytes)
        if w >= nbytes:
            return False
        try:
            bw = self._bw(tier, 1)
        except LinkPartitionedError:
            return False
        return (codec.compress_time(nbytes) + codec.decompress_time(nbytes)
                + w / bw < nbytes / bw)

    def compression_election(self, codec: Optional[Codec], nbytes: int
                             ) -> FrozenSet[str]:
        """Names of the topology tiers where :meth:`compression_wins`
        for an `nbytes` payload (the op's payload parameter — one
        decision per plan, applied to every transfer on the tier)."""
        if codec is None or codec.is_identity or nbytes <= 0:
            return frozenset()
        tiers = [self.topology.intra]
        if self.topology.inter is not None:
            tiers.append(self.topology.inter)
        return frozenset(t.name for t in tiers
                         if self.compression_wins(t, codec, nbytes))

    # -- shared building blocks ---------------------------------------------
    def _ring_bcast_piece(self, nbytes: int, m: int, tier: LinkTier,
                          concurrent: int = 1) -> float:
        """Pipelined ring broadcast of `nbytes` over `m` hosts all on one
        `tier`: stream once + (m-2) one-segment pipeline fills."""
        if m <= 1:
            return 0.0
        wire = self._wire(tier, nbytes)
        seg = min(wire, self.topology.seg_bytes)
        step = seg / self._bw(tier, concurrent) + self._lat(tier)
        return (wire / self._bw(tier, concurrent) + (m - 2) * step
                + self._lat(tier))

    def _tree_rounds(self, m: int) -> int:
        return int(math.ceil(math.log2(m))) if m > 1 else 0

    def _binomial_piece(self, m: int, size_of_round: Callable[[int], int],
                        tier_of_round: Callable[[int], Tuple[LinkTier, int]]
                        ) -> Tuple[float, TierBytes]:
        """Generic binomial schedule over `m` participants: round ``j``
        has ``min(2^j, m - 2^j)`` transfers of ``size_of_round(j)`` bytes
        on ``tier_of_round(j) -> (tier, crossing concurrency)``."""
        time, bytes_ = 0.0, {}
        for j in range(self._tree_rounds(m)):
            transfers = min(1 << j, m - (1 << j))
            size = size_of_round(j)
            tier, conc = tier_of_round(j)
            time += self._xfer(tier, size, concurrent=min(transfers, conc))
            _add(bytes_, tier, transfers * self._wire(tier, size))
        return time, bytes_

    def _round_tiers(self, m: int, inter_rounds: int
                     ) -> Callable[[int], Tuple[LinkTier, int]]:
        """Round -> tier map: the first `inter_rounds` rounds (largest
        strides) cross racks, the rest stay intra-rack."""
        topo = self.topology

        def tier_of(j: int) -> Tuple[LinkTier, int]:
            if j < inter_rounds and topo.inter is not None:
                return topo.inter, 1 << j
            return topo.intra, 1
        return tier_of

    # -- broadcast algorithms -----------------------------------------------
    def _bcast_pipelined_ring(self, nbytes: int, P: int
                              ) -> Tuple[float, TierBytes]:
        """The legacy ring generalized: rack-major host order, so P-1 hops
        of which R-1 cross racks; the pipeline rate is set by the slowest
        step (FLAT: exactly the pre-topology formula)."""
        topo = self.topology
        R, _ = topo.racks(P)
        crossings = R - 1
        candidates: List[Tuple[LinkTier, int]] = [(topo.intra, 1)]
        if crossings and topo.inter is not None:
            candidates.append((topo.inter, crossings))

        def seg_step(tc: Tuple[LinkTier, int]) -> float:
            seg = min(self._wire(tc[0], nbytes), topo.seg_bytes)
            return seg / self._bw(tc[0], tc[1]) + self._lat(tc[0])

        tier, conc = max(candidates, key=seg_step)
        wire = self._wire(tier, nbytes)
        seg = min(wire, topo.seg_bytes)
        step = seg / self._bw(tier, conc) + self._lat(tier)
        time = (wire / self._bw(tier, conc) + (P - 2) * step
                + self._lat(tier))
        bytes_: TierBytes = {}
        _add(bytes_, topo.intra,
             (P - 1 - crossings) * self._wire(topo.intra, nbytes))
        if crossings and topo.inter is not None:
            _add(bytes_, topo.inter,
                 crossings * self._wire(topo.inter, nbytes))
        return time, bytes_

    def _bcast_binomial_tree(self, nbytes: int, P: int
                             ) -> Tuple[float, TierBytes]:
        R, _ = self.topology.racks(P)
        inter_rounds = self._tree_rounds(R)
        return self._binomial_piece(P, lambda j: nbytes,
                                    self._round_tiers(P, inter_rounds))

    def _bcast_scatter_allgather(self, nbytes: int, P: int
                                 ) -> Tuple[float, TierBytes]:
        shard = -(-nbytes // P)
        t_sc, b_sc = self._scatter_binomial(nbytes, P)
        t_ag, b_ag = self._allgather_ring(shard, P)
        for k, v in b_ag.items():
            b_sc[k] = b_sc.get(k, 0) + v
        return t_sc + t_ag, b_sc

    def _bcast_hierarchical(self, nbytes: int, P: int
                            ) -> Tuple[float, TierBytes]:
        """Inter-rack binomial tree among rack leaders, then parallel
        intra-rack pipelined rings. Single rack: exactly the flat ring."""
        topo = self.topology
        R, H = topo.racks(P)
        if R <= 1 or topo.inter is None:
            return self._bcast_pipelined_ring(nbytes, P)
        t_tree, bytes_ = self._binomial_piece(
            R, lambda j: nbytes, lambda j: (topo.inter, 1 << j))
        t_ring = self._ring_bcast_piece(nbytes, H, topo.intra)
        _add(bytes_, topo.intra, (P - R) * nbytes)
        return t_tree + t_ring, bytes_

    # -- allgather algorithms -----------------------------------------------
    def _allgather_ring(self, shard: int, P: int) -> Tuple[float, TierBytes]:
        """The legacy ring: P-1 steps, every host forwarding one shard;
        with R racks, R of the P ring edges cross racks every step."""
        topo = self.topology
        R, _ = topo.racks(P)
        crossings = R if R > 1 else 0
        candidates: List[Tuple[LinkTier, int]] = [(topo.intra, 1)]
        if crossings and topo.inter is not None:
            candidates.append((topo.inter, crossings))
        step = max(self._xfer(t, shard, concurrent=c) for t, c in candidates)
        time = (P - 1) * step
        bytes_: TierBytes = {}
        _add(bytes_, topo.intra,
             (P - crossings) * (P - 1) * self._wire(topo.intra, shard))
        if crossings and topo.inter is not None:
            _add(bytes_, topo.inter,
                 crossings * (P - 1) * self._wire(topo.inter, shard))
        return time, bytes_

    def _allgather_hierarchical(self, shard: int, P: int
                                ) -> Tuple[float, TierBytes]:
        """Intra-rack ring all-gather, leader ring of rack blocks, then
        intra-rack broadcast of the foreign blocks. Single rack: the
        flat ring."""
        topo = self.topology
        R, H = topo.racks(P)
        if R <= 1 or topo.inter is None:
            return self._allgather_ring(shard, P)
        sizes = [H] * (P // H) + ([P % H] if P % H else [])
        bytes_: TierBytes = {}
        # phase 1: ring all-gather of `shard` inside every rack (parallel)
        t1 = (H - 1) * self._xfer(topo.intra, shard)
        _add(bytes_, topo.intra,
             sum(h * (h - 1) for h in sizes) * self._wire(topo.intra, shard))
        # phase 2: leader ring of rack blocks (every block crosses R-1x)
        t2 = (R - 1) * self._xfer(topo.inter, H * shard, concurrent=R)
        _add(bytes_, topo.inter,
             (R - 1) * sum(self._wire(topo.inter, h * shard) for h in sizes))
        # phase 3: broadcast the (P - h) foreign shards inside each rack;
        # the shortest rack receives the most, so it bounds the phase
        t3 = max(self._ring_bcast_piece((P - h) * shard, h, topo.intra)
                 for h in set(sizes))
        _add(bytes_, topo.intra,
             sum((h - 1) * self._wire(topo.intra, (P - h) * shard)
                 for h in sizes))
        return t1 + t2 + t3, bytes_

    # -- scatter algorithms --------------------------------------------------
    def _scatter_binomial(self, nbytes: int, P: int
                          ) -> Tuple[float, TierBytes]:
        """Halving rounds: round j moves ceil(n / 2^(j+1)) per transfer —
        total (P-1)/P of the buffer through the root's link."""
        R, _ = self.topology.racks(P)
        inter_rounds = self._tree_rounds(R)
        return self._binomial_piece(
            P, lambda j: -(-nbytes // (1 << (j + 1))),
            self._round_tiers(P, inter_rounds))

    def _scatter_hierarchical(self, nbytes: int, P: int
                              ) -> Tuple[float, TierBytes]:
        topo = self.topology
        R, H = topo.racks(P)
        if R <= 1 or topo.inter is None:
            return self._scatter_binomial(nbytes, P)
        t1, b1 = self._binomial_piece(
            R, lambda j: -(-nbytes // (1 << (j + 1))),
            lambda j: (topo.inter, 1 << j))
        block = -(-nbytes // R)
        t2, b2 = self._binomial_piece(
            H, lambda j: -(-block // (1 << (j + 1))),
            lambda j: (topo.intra, 1))
        for k, v in b2.items():
            b1[k] = b1.get(k, 0) + v * R          # every rack scatters
        return t1 + t2, b1

    # -- planning entrypoints -----------------------------------------------
    _ALGORITHMS: Dict[str, Dict[str, str]] = {
        "broadcast": {"pipelined_ring": "_bcast_pipelined_ring",
                      "binomial_tree": "_bcast_binomial_tree",
                      "scatter_allgather": "_bcast_scatter_allgather",
                      "hierarchical": "_bcast_hierarchical"},
        "allgather": {"ring": "_allgather_ring",
                      "hierarchical": "_allgather_hierarchical"},
        "scatter": {"binomial": "_scatter_binomial",
                    "hierarchical": "_scatter_hierarchical"},
    }

    def algorithms(self, op: str) -> List[str]:
        """The algorithm names this planner knows for `op`."""
        return list(self._ALGORITHMS[op])

    def _codec_charges(self, op: str, codec: Codec, nbytes: int,
                       n_hosts: int) -> Tuple[float, float]:
        """Once-per-plan codec edge charges ``(compress, decompress)``.

        Compress happens at the sending edge(s), decompress at the
        receiving edge(s); edges working in parallel overlap, so each
        side charges its serialized per-edge payload:

          broadcast  — root compresses `n`, every receiver decompresses
                       `n` in parallel.
          allgather  — every host compresses its own shard in parallel,
                       then decompresses the P-1 foreign shards.
          scatter    — the root compresses the full buffer, every
                       receiver decompresses its 1/P shard in parallel.

        The charges depend only on the op and payload — NOT on the
        algorithm — so adding them after best-by-wire-time selection
        preserves the algorithm ordering."""
        if op == "broadcast":
            return codec.compress_time(nbytes), codec.decompress_time(nbytes)
        if op == "allgather":
            return (codec.compress_time(nbytes),
                    (n_hosts - 1) * codec.decompress_time(nbytes))
        if op == "scatter":
            shard = -(-nbytes // n_hosts)
            return codec.compress_time(nbytes), codec.decompress_time(shard)
        raise ValueError(f"no codec charge model for op {op!r}")

    def _plan(self, op: str, nbytes: int, n_hosts: int,
              algorithm: Optional[str], dead: int = 0,
              codec: Optional[Codec] = None) -> CollectivePlan:
        if nbytes < 0:
            raise ValueError(f"{op} payload must be >= 0 bytes, "
                             f"got {nbytes}")
        if op not in self._ALGORITHMS:
            raise ValueError(f"unknown collective {op!r}; planner knows: "
                             f"{', '.join(self._ALGORITHMS)}")
        if dead < 0:
            raise ValueError(f"dead host count must be >= 0, got {dead}")
        if n_hosts <= 1:
            # a single host (or none) moves nothing — every algorithm
            # degenerates to the empty plan
            return CollectivePlan(op=op, algorithm=algorithm or "none",
                                  nbytes=nbytes, n_hosts=n_hosts, time=0.0,
                                  rerouted=dead,
                                  codec=codec.name if codec else "none")
        if algorithm is None:
            algorithm = self.topology.pinned_algorithms.get(op)
        table = self._ALGORITHMS[op]
        if algorithm is not None:
            if algorithm not in table:
                raise ValueError(
                    f"unknown {op} algorithm {algorithm!r}; available: "
                    f"{', '.join(table)}")
            names = [algorithm]
        else:
            names = list(table)
        elected = self.compression_election(codec, nbytes)
        active = codec if elected else None
        best: Optional[CollectivePlan] = None
        if active is not None:
            self._codec, self._elected = active, elected
        try:
            for name in names:
                time, bytes_ = getattr(self, table[name])(nbytes, n_hosts)
                plan = CollectivePlan(op=op, algorithm=name, nbytes=nbytes,
                                      n_hosts=n_hosts, time=time,
                                      tier_bytes=bytes_)
                if best is None or plan.time < best.time:
                    best = plan
        finally:
            if active is not None:
                self._codec, self._elected = None, frozenset()
        # only tiers that actually carry bytes in this plan pay (or win)
        # anything: an elected-but-idle tier (e.g. the wan tier under a
        # single-rack fan-out broadcast) must not charge codec time
        used = (frozenset(t for t, b in best.tier_bytes.items() if b)
                & elected) if active is not None else frozenset()
        if used:
            # the same algorithm run raw gives the logical (payload)
            # traffic the wire bytes stand in for
            _, payload = getattr(self, table[best.algorithm])(nbytes,
                                                              n_hosts)
            best.payload_tier_bytes = payload
            best.compress_time, best.decompress_time = self._codec_charges(
                op, active, nbytes, n_hosts)
            best.time += best.compress_time + best.decompress_time
            best.codec = active.name
            best.compressed_tiers = tuple(sorted(used))
        elif codec is not None:
            best.codec = codec.name    # requested but no tier elected: raw
        if dead:
            # re-routing cost of repairing the ring/tree schedule around
            # the dead hosts: each skip splices one extra intra-tier hop
            # into the schedule's critical path (the payload itself is
            # already planned over the LIVE host count only)
            best.time += dead * self._lat(self.topology.intra)
            best.rerouted = dead
        return best

    def plan_broadcast(self, nbytes: int, n_hosts: int,
                       algorithm: Optional[str] = None,
                       dead: int = 0,
                       codec: Optional[Codec] = None) -> CollectivePlan:
        """Plan a one-root broadcast of `nbytes` to `n_hosts` LIVE hosts;
        `dead` skipped hosts add re-routing latency to the schedule.
        `codec` enables per-tier compress-at-source election."""
        return self._plan("broadcast", nbytes, n_hosts, algorithm, dead,
                          codec=codec)

    def plan_allgather(self, shard_bytes: int, n_hosts: int,
                       algorithm: Optional[str] = None,
                       dead: int = 0,
                       codec: Optional[Codec] = None) -> CollectivePlan:
        """Plan an all-gather where each of `n_hosts` LIVE hosts
        contributes `shard_bytes`; `dead` adds re-routing latency.
        `codec` enables per-tier compress-at-source election."""
        return self._plan("allgather", shard_bytes, n_hosts, algorithm, dead,
                          codec=codec)

    def plan_scatter(self, total_bytes: int, n_hosts: int,
                     algorithm: Optional[str] = None,
                     dead: int = 0,
                     codec: Optional[Codec] = None) -> CollectivePlan:
        """Plan a root scatter of `total_bytes` into 1/P shards over the
        LIVE hosts; `dead` adds re-routing latency. `codec` enables
        per-tier compress-at-source election."""
        return self._plan("scatter", total_bytes, n_hosts, algorithm, dead,
                          codec=codec)

    def plan_replichain(self, stripe_bytes: int, n_hosts: int,
                        replication: int,
                        codec: Optional[Codec] = None) -> CollectivePlan:
        """Plan R-way chained stripe replication: after the striped read,
        every host forwards its stripe to its successor for R-1 pipelined
        rounds (chained declustering), leaving stripe ``i`` resident on
        hosts ``i .. i+R-1 (mod P)``.

        Each round is P concurrent `stripe_bytes` transfers on the ring;
        with R_racks racks, R_racks of the P ring edges cross racks every
        round (same geometry as the ring all-gather)."""
        if not 1 <= replication <= max(n_hosts, 1):
            raise ValueError(
                f"replication must be in [1, n_hosts={n_hosts}], "
                f"got {replication}")
        topo = self.topology
        rounds = replication - 1
        if n_hosts <= 1 or rounds == 0 or stripe_bytes == 0:
            return CollectivePlan(op="replichain", algorithm="ring",
                                  nbytes=stripe_bytes, n_hosts=n_hosts,
                                  time=0.0,
                                  codec=codec.name if codec else "none")
        R, _ = topo.racks(n_hosts)
        crossings = R if R > 1 else 0
        candidates: List[Tuple[LinkTier, int]] = [(topo.intra, 1)]
        if crossings and topo.inter is not None:
            candidates.append((topo.inter, crossings))
        # restrict the election to tiers this chain actually crosses (the
        # candidates carrying > 0 transfers), so an elected-but-idle tier
        # never charges codec time or skews the step max
        carrying = {t.name for t, _ in candidates
                    if t is not topo.intra or n_hosts - crossings > 0}
        elected = frozenset(
            t for t in self.compression_election(codec, stripe_bytes)
            if t in carrying)
        active = codec if elected else None
        if active is not None:
            self._codec, self._elected = active, elected
        try:
            step = max(self._xfer(t, stripe_bytes, concurrent=c)
                       for t, c in candidates)
            bytes_: TierBytes = {}
            _add(bytes_, topo.intra,
                 rounds * (n_hosts - crossings)
                 * self._wire(topo.intra, stripe_bytes))
            if crossings and topo.inter is not None:
                _add(bytes_, topo.inter,
                     rounds * crossings * self._wire(topo.inter,
                                                     stripe_bytes))
        finally:
            if active is not None:
                self._codec, self._elected = None, frozenset()
        plan = CollectivePlan(op="replichain", algorithm="ring",
                              nbytes=stripe_bytes, n_hosts=n_hosts,
                              time=rounds * step, tier_bytes=bytes_)
        if active is not None:
            payload: TierBytes = {}
            _add(payload, topo.intra,
                 rounds * (n_hosts - crossings) * stripe_bytes)
            if crossings and topo.inter is not None:
                _add(payload, topo.inter, rounds * crossings * stripe_bytes)
            plan.payload_tier_bytes = payload
            # every host compresses its stripe once (parallel); each of
            # the R-1 forwarding rounds lands one stripe to decompress
            plan.compress_time = active.compress_time(stripe_bytes)
            plan.decompress_time = rounds * active.decompress_time(
                stripe_bytes)
            plan.time += plan.compress_time + plan.decompress_time
            plan.codec = active.name
            plan.compressed_tiers = tuple(sorted(elected))
        elif codec is not None:
            plan.codec = codec.name
        return plan

    def plan_repair(self, transfers: List[Tuple[int, int, int]],
                    n_hosts: int) -> CollectivePlan:
        """Plan an explicit point-to-point repair schedule: `transfers` is
        ``[(src_host, dst_host, nbytes), ...]`` in issue order.

        Each host's NIC serializes its transfers (a busy-line per host);
        transfers between different host pairs overlap. The tier of each
        transfer follows rack membership (rack-major placement, as in
        :meth:`~repro.core.topology.Topology.racks`). The duration is the
        makespan of the schedule — deterministic in the transfer order."""
        topo = self.topology
        hpr = topo.hosts_per_rack
        busy: Dict[int, float] = {}
        t_done = 0.0
        bytes_: TierBytes = {}
        total = 0
        for src, dst, nbytes in transfers:
            if topo.is_flat or (src // hpr) == (dst // hpr):
                tier = topo.intra
            else:
                tier = topo.inter
            start = max(busy.get(src, 0.0), busy.get(dst, 0.0))
            end = start + self._xfer(tier, nbytes)
            busy[src] = busy[dst] = end
            t_done = max(t_done, end)
            _add(bytes_, tier, nbytes)
            total += nbytes
        return CollectivePlan(op="repair", algorithm="p2p_reroute",
                              nbytes=total, n_hosts=n_hosts, time=t_done,
                              tier_bytes=bytes_)

    def plan_point_to_point(self, nbytes: int, attempts: int = 1,
                            codec: Optional[Codec] = None) -> CollectivePlan:
        """One off-machine message (detector NIC -> leader host) over the
        topology's ingest tier.

        `attempts` models stop-and-wait retransmission on a lossy WAN
        hop (`repro.core.wan`): each attempt serializes the full payload
        plus one tier latency, so time and ingest-tier bytes both scale
        by `attempts`.  The default of 1 keeps the plan identical to the
        lossless path (algorithm ``"direct"``); retries are labeled
        ``"retransmit"`` so traces and plan dumps show them.  A tier at
        scale 0 is a partition, not loss — no number of attempts crosses
        it, and :class:`LinkPartitionedError` propagates from `_bw`.

        With a `codec` elected on the ingest tier, every attempt re-sends
        the COMPRESSED frame (the sender keeps the compressed buffer, so
        compress is charged once, not per retry) — the wire-byte win
        compounds with retransmission on the lossy WAN pipe."""
        if nbytes < 0:
            raise ValueError(f"nbytes must be >= 0, got {nbytes}")
        if attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {attempts}")
        tier = self.topology.ingest_tier
        algo = "direct" if attempts == 1 else "retransmit"
        elected = self.compression_election(codec, nbytes)
        active = codec if tier.name in elected else None
        if active is not None:
            self._codec, self._elected = active, elected
        try:
            t_wire = attempts * self._xfer(tier, nbytes)
            wire = self._wire(tier, nbytes)
        finally:
            if active is not None:
                self._codec, self._elected = None, frozenset()
        plan = CollectivePlan(op="point_to_point", algorithm=algo,
                              nbytes=nbytes, n_hosts=1, time=t_wire)
        _add(plan.tier_bytes, tier, attempts * wire)
        if active is not None:
            payload: TierBytes = {}
            _add(payload, tier, attempts * nbytes)
            plan.payload_tier_bytes = payload
            plan.compress_time = active.compress_time(nbytes)
            plan.decompress_time = active.decompress_time(nbytes)
            plan.time += plan.compress_time + plan.decompress_time
            plan.codec = active.name
            plan.compressed_tiers = (tier.name,)
        elif codec is not None:
            plan.codec = codec.name
        return plan

"""Codec cost model for compression-aware tiered staging.

Bytes crossing the slowest link dominate turnaround (the paper's core
lesson; PR 5's per-tier accounting makes the cost measurable and PR 9's
~10 Gb/s WAN ingest tier makes it painful).  This module supplies the
*codec* side of the compress-vs-raw decision: a :class:`Codec` models a
lossless detector-frame compressor as three numbers — compress
throughput, decompress throughput, and a deterministic compression
ratio — and the :class:`~repro.core.collectives.CollectivePlanner`
elects, per link tier, whether shipping compressed beats shipping raw
(the bandwidth/throughput-ratio analysis of Hayot-Sasson et al.).

Everything here is deterministic and pure: ``compressed_size`` is a
closed-form function of the payload size (no RNG, no data inspection),
so simulated plans replay bit-exactly and *payload* bytes vs *wire*
bytes are separable in every report.

The identity codec (``"none"``) resolves to ``None`` everywhere, which
keeps every pre-existing code path bit-exact — the regression anchor.

This is unrelated to :mod:`repro.train.compression` (int8 gradient
quantization for the training loop); this module is about staging
wire-byte reduction.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, fields, replace
from typing import Mapping, Optional, Union

__all__ = [
    "Codec", "CODECS", "CompressionConfig", "CompressionLike",
    "CompressionStats", "resolve_codec",
]


# ---------------------------------------------------------------------------
# codec model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Codec:
    """A lossless codec as a throughput/ratio point.

    ``compress_bw`` / ``decompress_bw`` are single-edge codec
    throughputs in bytes/s of *payload* processed; ``ratio`` is the
    deterministic payload/wire size ratio (>= 1).  Detector frames are
    sparse int data, so a cheap bitshuffle+LZ4-class lossless pass gets
    a healthy ratio at memory-bandwidth-order speeds — that operating
    point is the default (``"frame-lossless"`` below).
    """
    name: str
    compress_bw: float = float("inf")
    decompress_bw: float = float("inf")
    ratio: float = 1.0

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("codec name must be non-empty")
        if not (self.compress_bw > 0 and self.decompress_bw > 0):
            raise ValueError(
                f"codec throughputs must be positive, got "
                f"compress_bw={self.compress_bw} "
                f"decompress_bw={self.decompress_bw}")
        if not self.ratio >= 1.0:
            raise ValueError(f"codec ratio must be >= 1, got {self.ratio}")

    @property
    def is_identity(self) -> bool:
        """True when compression never changes a byte count."""
        return self.ratio == 1.0

    def compressed_size(self, nbytes: int) -> int:
        """Deterministic wire size of an ``nbytes`` payload (>= 1 for any
        non-empty payload: headers never vanish)."""
        if nbytes <= 0:
            return 0
        if self.is_identity:
            return int(nbytes)
        return max(1, math.ceil(nbytes / self.ratio))

    def compress_time(self, nbytes: int) -> float:
        """Seconds to compress ``nbytes`` of payload at one edge."""
        if nbytes <= 0 or self.is_identity:
            return 0.0
        return nbytes / self.compress_bw

    def decompress_time(self, nbytes: int) -> float:
        """Seconds to decompress back to ``nbytes`` of payload."""
        if nbytes <= 0 or self.is_identity:
            return 0.0
        return nbytes / self.decompress_bw


#: Registered codecs.  ``"frame-lossless"`` is the default detector-frame
#: operating point: a multithreaded bitshuffle+LZ4-class lossless pass on
#: sparse int frames — 3.2x ratio at 4 GB/s compress / 8 GB/s decompress.
#: Its election LHS (1/Cc + 1/Cd = 0.375 ns/B) sits *between* the 2 GB/s
#: cluster links (RHS 0.344 ns/B -> ship raw) and the 1.25 GB/s WAN
#: ingest tier (RHS 0.55 ns/B -> compress at source), so the per-tier
#: decision is visible on the stock ``wan_beamline`` topology.
#: ``"frame-fast"`` (lighter filter, faster, smaller ratio) crosses over
#: on 2 GB/s links too — the hierarchical-compounding point.
#: ``"frame-deep"`` (heavier entropy stage) is too slow even for the WAN
#: pipe — the raw-wins end of the sweep.
CODECS: Mapping[str, Codec] = {
    "none": Codec(name="none"),
    "frame-lossless": Codec(name="frame-lossless", compress_bw=4e9,
                            decompress_bw=8e9, ratio=3.2),
    "frame-fast": Codec(name="frame-fast", compress_bw=8e9,
                        decompress_bw=16e9, ratio=2.5),
    "frame-deep": Codec(name="frame-deep", compress_bw=0.8e9,
                        decompress_bw=2e9, ratio=4.5),
}

DEFAULT_CODEC = "frame-lossless"


# ---------------------------------------------------------------------------
# typed config (the FaultConfig / TopologyConfig pattern)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CompressionConfig:
    """Declarative codec selection for typed engine configs.

    ``codec`` names a :data:`CODECS` entry; the optional overrides
    replace that codec's throughput/ratio fields (for sweeps and tests).
    ``CompressionConfig()`` / ``"none"`` is the identity — engines take
    the exact uncompressed code path.
    """
    codec: str = "none"
    compress_bw: Optional[float] = None
    decompress_bw: Optional[float] = None
    ratio: Optional[float] = None

    def __post_init__(self) -> None:
        if self.codec not in CODECS:
            raise ValueError(
                f"unknown codec {self.codec!r}; "
                f"registered: {sorted(CODECS)}")
        # validation of override values is delegated to Codec.__post_init__
        self.build()

    def build(self) -> Optional[Codec]:
        """Resolve to a :class:`Codec`, or ``None`` for the identity."""
        base = CODECS[self.codec]
        over = {k: v for k, v in (("compress_bw", self.compress_bw),
                                  ("decompress_bw", self.decompress_bw),
                                  ("ratio", self.ratio)) if v is not None}
        codec = replace(base, **over) if over else base
        return None if codec.is_identity else codec

    def to_dict(self) -> dict:
        """JSON-ready dict (omits unset overrides)."""
        out: dict = {"codec": self.codec}
        for f in fields(self):
            v = getattr(self, f.name)
            if f.name != "codec" and v is not None:
                out[f.name] = v
        return out

    @classmethod
    def coerce(cls, value: "CompressionLike") -> "CompressionConfig":
        """Accept loose spellings: name string, mapping, Codec, config."""
        if isinstance(value, cls):
            return value
        if value is None:
            return cls()
        if isinstance(value, str):
            return cls(codec=value)
        if isinstance(value, Codec):
            if value.name in CODECS and CODECS[value.name] == value:
                return cls(codec=value.name)
            # ad-hoc codec: carry it through as overrides on its name if
            # registered, else reject (configs must stay serializable)
            if value.name in CODECS:
                return cls(codec=value.name, compress_bw=value.compress_bw,
                           decompress_bw=value.decompress_bw,
                           ratio=value.ratio)
            raise ValueError(
                f"codec {value.name!r} is not registered; add it to "
                f"repro.core.compression.CODECS or pass a "
                f"CompressionConfig with overrides")
        if isinstance(value, Mapping):
            return cls(**value)
        raise TypeError(
            f"cannot coerce {type(value).__name__} to CompressionConfig")


CompressionLike = Union[None, str, Codec, CompressionConfig, Mapping]


def resolve_codec(value: CompressionLike) -> Optional[Codec]:
    """Resolve any loose compression spelling to an active :class:`Codec`
    or ``None`` (identity: the bit-exact uncompressed path)."""
    if value is None:
        return None
    if isinstance(value, Codec):
        return None if value.is_identity else value
    return CompressionConfig.coerce(value).build()


# ---------------------------------------------------------------------------
# byte/time accounting
# ---------------------------------------------------------------------------

@dataclass
class CompressionStats:
    """Accumulated codec accounting over executed plans.

    ``payload_bytes`` counts the logical bytes compression was applied
    to on elected tiers; ``wire_bytes`` the bytes that actually crossed
    those tiers.  Plans with no elected tier contribute nothing (their
    wire bytes ARE their payload bytes — see
    ``CollectivePlan.payload_tier_bytes``).
    """
    plans: int = 0
    payload_bytes: int = 0
    wire_bytes: int = 0
    compress_time: float = 0.0
    decompress_time: float = 0.0

    @property
    def saved_bytes(self) -> int:
        return self.payload_bytes - self.wire_bytes

    @property
    def wire_ratio(self) -> float:
        """payload/wire ratio actually achieved (1.0 when idle)."""
        if self.wire_bytes <= 0:
            return 1.0
        return self.payload_bytes / self.wire_bytes

    @property
    def codec_time(self) -> float:
        return self.compress_time + self.decompress_time

    def copy(self) -> "CompressionStats":
        return replace(self)

    def delta(self, since: "CompressionStats") -> "CompressionStats":
        """Stats accumulated after the ``since`` snapshot."""
        return CompressionStats(
            plans=self.plans - since.plans,
            payload_bytes=self.payload_bytes - since.payload_bytes,
            wire_bytes=self.wire_bytes - since.wire_bytes,
            compress_time=self.compress_time - since.compress_time,
            decompress_time=self.decompress_time - since.decompress_time)

    def add(self, other: "CompressionStats") -> None:
        self.plans += other.plans
        self.payload_bytes += other.payload_bytes
        self.wire_bytes += other.wire_bytes
        self.compress_time += other.compress_time
        self.decompress_time += other.decompress_time

    def to_dict(self) -> dict:
        return {
            "plans": self.plans,
            "payload_bytes": self.payload_bytes,
            "wire_bytes": self.wire_bytes,
            "saved_bytes": self.saved_bytes,
            "wire_ratio": self.wire_ratio,
            "compress_time_s": self.compress_time,
            "decompress_time_s": self.decompress_time,
        }

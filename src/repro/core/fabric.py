"""Simulated cluster fabric: shared filesystem, interconnect, node-local tiers.

The container is a single CPU process, so multi-host behaviour is reproduced
with a discrete-event model that moves REAL bytes (staging results are
byte-exact and testable) while accounting SIMULATED time against bandwidth
constants. Two calibrations ship:

  * ``BGQ``  — constants fit to the paper's measured aggregates (GPFS peak
    240 GB/s; ~22 GB/s effective for uncoordinated replicated reads — the
    naive path measured in Fig. 11; ~150 GB/s for coordinated disjoint-stripe
    collective reads; 5D-torus links).
  * ``TPU_POD`` — v5e-flavored: per-host NIC to object store, 50 GB/s/link
    ICI intra-pod, DCN across pods.

The key physical distinction the paper exploits:
  naive   — every node reads the FULL dataset from shared storage
            (aggregate bytes = P x size, uncoordinated -> congested rate)
  staged  — nodes read DISJOINT 1/P stripes (aggregate = 1 x size at
            sequential rate) and replicate over the interconnect.

Units, everywhere in this module: times are SIMULATED seconds (an
accounting clock advanced against the bandwidth constants — never wall
clock; only benchmark harnesses measure wall time), sizes are bytes,
bandwidths bytes/second, latencies seconds. Methods that model an I/O or
network operation take the caller's current simulated time ``t`` and
return the operation's completion time on the same clock.
"""
from __future__ import annotations

import fnmatch
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.collectives import CollectivePlan, CollectivePlanner
from repro.core.compression import (Codec, CompressionLike, CompressionStats,
                                    resolve_codec)
from repro.core.faults import FaultEvent, FaultKind, FaultSchedule
from repro.core.telemetry import NULL_TRACER, TracerLike
from repro.core.topology import FLAT, Topology, TopologyLike, resolve_topology


@dataclass
class FabricConstants:
    """Calibration constants for one simulated machine (all bandwidths in
    bytes/s, all latencies in seconds of simulated time)."""
    name: str
    fs_seq_bw: float          # coordinated (disjoint, striped) read bw, bytes/s
    fs_rand_bw: float         # uncoordinated/replicated read bw, bytes/s
    fs_md_latency: float      # metadata op latency (glob/stat), s
    fs_op_latency: float      # per-read-request latency, s
    coll_latency_base: float  # per-file collective-read sync overhead, s
    coll_latency_log: float   # + this * log2(P) (MPI collective scaling), s
    link_bw: float            # per-host interconnect link bw, bytes/s
    link_latency: float       # per-message latency, s
    local_bw: float           # node-local store WRITE bw, bytes/s
    local_read_bw: float      # per-process node-local READ bw, bytes/s


# Calibrated to the paper's measurements (§VI-B, Figs. 10/11):
#   naive 8192-node input = 210 s for 577 MB/node  -> 22.5 GB/s congested GPFS
#   staged Staging+Write  = ~36 s for 736 files    -> ~48 ms/file collective
#     overhead at P=8192  = base 5 ms + 3.3 ms * log2(8192)
#   Read phase 10.8 s for 577 MB                   -> 53.4 MB/s per-process
#     RAM-disk read (BG/Q /tmp is an I/O-node service)
BGQ = FabricConstants(
    name="bgq",
    fs_seq_bw=150e9, fs_rand_bw=22.5e9,
    fs_md_latency=1e-3, fs_op_latency=5e-3,
    coll_latency_base=5e-3, coll_latency_log=3.3e-3,
    link_bw=2e9, link_latency=2.5e-6,
    local_bw=4e9, local_read_bw=53.4e6,
)

# v5e-pod flavored: object store over per-host NICs, ICI links, host RAM tier
TPU_POD = FabricConstants(
    name="tpu_pod",
    fs_seq_bw=200e9, fs_rand_bw=30e9,
    fs_md_latency=5e-4, fs_op_latency=1e-3,
    coll_latency_base=1e-3, coll_latency_log=2e-4,
    link_bw=50e9, link_latency=1e-6,
    local_bw=100e9, local_read_bw=10e9,
)


def pin_ref(pins: Dict[str, int], path: str) -> None:
    """Add one pin reference to `path` in the refcount map `pins` — the
    shared idiom behind :meth:`NodeLocalStore.pin`, ``StreamStager.pin``
    and ``TaskInputCache.pin`` (one implementation, one semantics)."""
    pins[path] = pins.get(path, 0) + 1


def unpin_ref(pins: Dict[str, int], path: str) -> bool:
    """Drop one pin reference on `path`; returns True if the caller held
    one (False = no-op — `path` was not pinned in `pins`). The entry
    leaves the map when the last holder unpins."""
    count = pins.get(path, 0)
    if count == 0:
        return False
    if count == 1:
        del pins[path]
    else:
        pins[path] = count - 1
    return True


@dataclass
class SharedFilesystem:
    """Bandwidth-accounted shared parallel filesystem (GPFS stand-in)."""
    constants: FabricConstants
    files: Dict[str, np.ndarray] = field(default_factory=dict)
    busy_until: float = 0.0           # shared-resource serialization point
    busy_time: float = 0.0            # total seconds of bandwidth occupancy
    wait_time: float = 0.0            # total seconds requests queued behind
    #                                   earlier traffic (the contention signal
    #                                   concurrent sessions produce)
    bytes_read: int = 0
    read_requests: int = 0
    bytes_written: int = 0            # time-accounted writes (write-back path)
    write_requests: int = 0
    metadata_ops: int = 0
    tracer: TracerLike = NULL_TRACER  # shared via Fabric.attach_tracer

    def _occupy(self, t: float, seconds: float, op: str = "io") -> float:
        """Claim `seconds` of the shared busy stream for a request issued
        at `t`; returns the start time (``max(t, busy_until)``). All
        occupancy/wait accounting funnels through here — and so does all
        FS telemetry: one ``fs.<op>`` busy span per request plus an
        ``fs.wait`` span when it queued behind earlier traffic."""
        start = max(t, self.busy_until)
        self.wait_time += start - t
        self.busy_until = start + seconds
        self.busy_time += seconds
        tr = self.tracer
        if tr.enabled:
            if start > t:
                tr.span("fs.wait", t, start, track="fs", op=op)
                tr.metrics.counter("fs.contention_waits").inc()
                tr.metrics.histogram("fs.wait_s").observe(start - t)
            tr.span(f"fs.{op}", start, start + seconds, track="fs")
        return start

    def put(self, path: str, data: np.ndarray) -> None:
        """Install `data` (any dtype, flattened to uint8) at `path`.
        Producer-side writes are not time-accounted — the model charges
        reads, which is where the paper's contention lives."""
        self.files[path] = np.ascontiguousarray(data).view(np.uint8).ravel()

    def size(self, path: str) -> int:
        """File size in bytes (no metadata latency charged)."""
        return int(self.files[path].size)

    def glob(self, pattern: str, t: float) -> Tuple[List[str], float]:
        """Resolve `pattern` (fnmatch) at simulated time `t`.

        Returns ``(sorted matches, completion time)``; charges one
        ``fs_md_latency`` scaled by directory size per scan, serialized on
        the shared-FS busy stream like any other request."""
        self.metadata_ops += 1
        names = sorted(n for n in self.files if fnmatch.fnmatch(n, pattern))
        self._occupy(t, self.constants.fs_md_latency * (1 + len(names) / 64),
                     op="metadata")
        return names, self.busy_until

    def read(self, path: str, offset: int, size: int, t: float,
             coordinated: bool) -> Tuple[np.ndarray, float]:
        """Read `size` bytes at `offset` from `path`, issued at simulated
        time `t`. Returns ``(zero-copy view of the bytes, completion t)``.

        `coordinated` selects the bandwidth regime: disjoint-stripe
        collective reads stream at ``fs_seq_bw``; uncoordinated
        full-replica reads contend at ``fs_rand_bw``.

        The FS is a shared resource: bandwidth serializes (busy_until),
        request latencies overlap (charged to the caller's completion time
        only) — concurrent requests from many hosts each pay one latency.
        """
        bw = (self.constants.fs_seq_bw if coordinated
              else self.constants.fs_rand_bw)
        self._occupy(t, size / bw, op="read")
        t_done = self.busy_until + self.constants.fs_op_latency
        self.bytes_read += size
        self.read_requests += 1
        return self.files[path][offset:offset + size], t_done

    def read_striped(self, path: str, stripes: List[Tuple[int, int]],
                     t: float, coordinated: bool = True
                     ) -> Tuple[np.ndarray, float]:
        """Batched form of P concurrent disjoint-stripe reads issued at `t`.

        Time-model equivalent to calling :meth:`read` once per stripe (the FS
        serializes bandwidth; per-request latencies overlap, so completion is
        last-byte time + one latency) but with O(1) Python cost — the staging
        hot path at P=1024+ hosts. Returns a zero-copy view spanning the
        stripes' covered byte range. An EMPTY stripe list (degenerate P
        slicing) is a true no-op: nothing read, no latency charged, the
        busy stream untouched.
        """
        if not stripes:
            return self.files[path][:0], t
        total = sum(sz for _, sz in stripes)
        bw = (self.constants.fs_seq_bw if coordinated
              else self.constants.fs_rand_bw)
        self._occupy(t, total / bw, op="read")
        t_done = self.busy_until + self.constants.fs_op_latency
        self.bytes_read += total
        self.read_requests += len(stripes)
        lo = min((off for off, _ in stripes), default=0)
        hi = max((off + sz for off, sz in stripes), default=0)
        return self.files[path][lo:hi], t_done

    def write(self, path: str, data: np.ndarray, t: float,
              coordinated: bool = False) -> float:
        """Time-accounted write of `data` (any dtype, flattened to uint8)
        to `path`, issued at simulated time `t`. Returns the completion
        time. Unlike :meth:`put` (the un-accounted producer-side install),
        this is the WRITE-BACK path: analysis results flushed to the
        shared FS pay bandwidth and latency like any read.

        `coordinated` selects the regime exactly as for reads: disjoint
        collective stripes stream at ``fs_seq_bw``; uncoordinated
        full-replica writes contend at ``fs_rand_bw``. Bandwidth
        serializes on the shared busy stream; the per-request latency
        overlaps (charged to this caller's completion only).
        """
        buf = np.ascontiguousarray(data).view(np.uint8).ravel()
        bw = (self.constants.fs_seq_bw if coordinated
              else self.constants.fs_rand_bw)
        self._occupy(t, buf.size / bw, op="write")
        t_done = self.busy_until + self.constants.fs_op_latency
        self.files[path] = buf
        self.bytes_written += buf.size
        self.write_requests += 1
        return t_done

    def write_gather(self, path: str, data: np.ndarray,
                     stripes: List[Tuple[int, int]], t: float,
                     coordinated: bool = True) -> float:
        """Batched form of P concurrent disjoint-stripe writes issued at
        `t` — the data-gather + write half of a two-phase
        ``MPI_File_write_all`` (the write-back mirror of
        :meth:`read_striped`). Time-model equivalent to one :meth:`write`
        per stripe (bandwidth serializes, per-request latencies overlap)
        at O(1) Python cost; the file's final content is installed whole.
        Returns the completion time of the last stripe. An EMPTY stripe
        list (degenerate P slicing) is a true no-op: nothing written or
        installed, no latency charged, the busy stream untouched.
        """
        if not stripes:
            return t
        buf = np.ascontiguousarray(data).view(np.uint8).ravel()
        total = sum(sz for _, sz in stripes)
        bw = (self.constants.fs_seq_bw if coordinated
              else self.constants.fs_rand_bw)
        self._occupy(t, total / bw, op="write")
        t_done = self.busy_until + self.constants.fs_op_latency
        self.files[path] = buf
        self.bytes_written += total
        self.write_requests += len(stripes)
        return t_done


@dataclass
class Interconnect:
    """Topology-aware interconnect: executes planned collectives.

    The algorithms live in `repro.core.collectives.CollectivePlanner`,
    bound to this fabric's `repro.core.topology.Topology` (default:
    :data:`~repro.core.topology.FLAT`, which pins the legacy ring
    algorithms and inherits the calibration's link constants — bit-for-bit
    the pre-topology accounting). Methods return the DURATION (simulated
    s) of one collective/message and account the wire traffic in
    ``bytes_moved`` (total) and ``tier_bytes`` (per topology tier);
    callers place the duration on their own timeline (collectives from
    disjoint host groups may overlap, so there is no global busy stream
    here).

    ``faults`` is the fabric's `repro.core.faults.FaultSchedule`; when it
    is non-trivial, collectives issued at simulated time ``t`` (the new
    optional ``t=`` argument; default ``now``, the fault clock advanced by
    ``Fabric.advance_faults``) are planned over the LIVE host set with
    ring/tree re-routing latency for the dead, under per-tier degraded
    bandwidth. A trivial (empty) schedule takes the exact pre-fault code
    path — bit-exact zero-fault accounting.

    ``codec`` is the bound compression codec (`repro.core.compression`):
    every collective planned here passes it to the planner, which elects
    compress-at-source per tier. ``None`` (the default) is the identity —
    the exact pre-compression code path. ``bytes_moved``/``tier_bytes``
    always count WIRE bytes; ``comp`` accumulates the payload-vs-wire
    split over plans that elected at least one tier."""
    constants: FabricConstants
    topology: Topology = FLAT
    bytes_moved: int = 0
    tier_bytes: Dict[str, int] = field(default_factory=dict)
    faults: Optional[FaultSchedule] = None
    now: float = 0.0                  # fault clock (advance_faults)
    tracer: TracerLike = NULL_TRACER  # shared via Fabric.attach_tracer
    codec: Optional[Codec] = None     # bound via scoped_codec / configs
    comp: CompressionStats = field(default_factory=CompressionStats)

    def __post_init__(self) -> None:
        self._planner = CollectivePlanner(self.topology, self.constants)

    # -- topology binding ---------------------------------------------------
    @property
    def planner(self) -> CollectivePlanner:
        """The collective planner bound to the current topology — use its
        ``plan_*`` methods for PURE cost queries (no traffic accounted).
        Rebuilt whenever ``topology`` changes, so assigning the field
        directly is as good as :meth:`set_topology`."""
        if self._planner.topology is not self.topology:
            self._planner = CollectivePlanner(self.topology, self.constants)
        return self._planner

    def set_topology(self, topology: TopologyLike) -> None:
        """Rebind the interconnect to `topology` (any loose spelling —
        name, config, or instance). Traffic counters are kept; tier names
        from the previous topology remain in ``tier_bytes``."""
        self.topology = resolve_topology(topology)

    @contextmanager
    def scoped_topology(self, topology: TopologyLike) -> Iterator[None]:
        """Temporarily rebind to `topology` for one staging operation
        (how a per-call ``TopologyConfig`` on an engine config takes
        effect); ``None`` keeps the current binding — a no-op."""
        if topology is None:
            yield
            return
        prev = self.topology
        self.set_topology(topology)
        try:
            yield
        finally:
            self.topology = prev

    # -- compression binding ------------------------------------------------
    @contextmanager
    def scoped_codec(self, compression: CompressionLike) -> Iterator[None]:
        """Temporarily bind a codec for one staging operation (how a
        per-call ``CompressionConfig`` on an engine config takes effect).
        Accepts any loose spelling (name, config, codec); ``None`` keeps
        the current binding — a no-op, the bit-exact identity path."""
        if compression is None:
            yield
            return
        prev = self.codec
        self.codec = resolve_codec(compression)
        try:
            yield
        finally:
            self.codec = prev

    def comp_snapshot(self) -> CompressionStats:
        """Copy of the codec accounting (pair with :meth:`comp_delta`)."""
        return self.comp.copy()

    def comp_delta(self, snapshot: CompressionStats) -> CompressionStats:
        """Codec accounting accumulated since `snapshot`."""
        return self.comp.delta(snapshot)

    # -- fault awareness ----------------------------------------------------
    @contextmanager
    def scoped_faults(self, faults: Optional[FaultSchedule]
                      ) -> Iterator[None]:
        """Temporarily bind `faults` for one staging operation (how a
        per-call ``FaultConfig`` on an engine config takes effect);
        ``None`` keeps the current binding — a no-op."""
        if faults is None:
            yield
            return
        prev = self.faults
        self.faults = faults
        try:
            yield
        finally:
            self.faults = prev

    def _fault_state(self, t: Optional[float], n_hosts: int
                     ) -> Tuple[CollectivePlanner, int]:
        """``(planner, dead)`` for a collective over `n_hosts` issued at
        `t`: the planner carries any degraded tier scales active at `t`
        and `dead` counts schedule members to re-route around. The
        trivial schedule returns the bound planner untouched — the exact
        pre-fault path."""
        sched = self.faults
        if sched is None or sched.trivial:
            return self.planner, 0
        tq = self.now if t is None else t
        dead = min(sched.n_dead(tq, n_hosts), max(n_hosts - 1, 0))
        factors = sched.tier_factors(self.topology.tier_names(), tq)
        planner = self.planner
        if factors:
            planner = CollectivePlanner(self.topology.degraded(factors),
                                        self.constants)
        return planner, dead

    # -- execution: plan + account ------------------------------------------
    def execute(self, plan: CollectivePlan) -> float:
        """Account `plan`'s wire traffic and return its duration."""
        for tier, nbytes in plan.tier_bytes.items():
            self.tier_bytes[tier] = self.tier_bytes.get(tier, 0) + nbytes
        self.bytes_moved += plan.total_bytes
        if plan.compressed_tiers:
            self.comp.plans += 1
            self.comp.payload_bytes += plan.payload_bytes
            self.comp.wire_bytes += plan.total_bytes
            self.comp.compress_time += plan.compress_time
            self.comp.decompress_time += plan.decompress_time
        return plan.time

    def _execute_traced(self, plan: CollectivePlan,
                        t: Optional[float]) -> float:
        """:meth:`execute` plus telemetry: one ``collective.<op>`` span
        over ``[t, t + duration)`` with per-tier child spans partitioning
        the interval proportional to each tier's wire bytes, a per-tier
        bandwidth-utilization gauge series, and a duration histogram
        observation. The recorded times are the ones :meth:`execute`
        already computed — tracing never changes the arithmetic."""
        dt = self.execute(plan)
        tr = self.tracer
        if tr.enabled:
            t0 = self.now if t is None else t
            sp = tr.span(f"collective.{plan.op}", t0, t0 + dt, track="net",
                         algorithm=plan.algorithm, nbytes=plan.nbytes,
                         n_hosts=plan.n_hosts, rerouted=plan.rerouted,
                         wire_bytes=plan.total_bytes, codec=plan.codec)
            # codec edges bracket the wire interval: compress at the
            # sending edge before the first byte, decompress at the
            # receiving edge after the last (both 0.0 without a codec —
            # the tier partition below is then exactly the legacy one)
            t_wire = t0 + plan.compress_time
            wire_dt = dt - plan.compress_time - plan.decompress_time
            if plan.compressed_tiers:
                if plan.compress_time > 0:
                    tr.span("comp.compress", t0, t_wire, track="net",
                            parent=sp, codec=plan.codec,
                            payload_bytes=plan.payload_bytes)
                if plan.decompress_time > 0:
                    tr.span("comp.decompress", t0 + dt - plan.decompress_time,
                            t0 + dt, track="net", parent=sp,
                            codec=plan.codec,
                            payload_bytes=plan.payload_bytes)
                tr.metrics.counter("comp.plans").inc()
                tr.metrics.counter("comp.payload_bytes").inc(
                    plan.payload_bytes)
                tr.metrics.counter("comp.wire_bytes").inc(plan.total_bytes)
                tr.metrics.counter("comp.bytes_saved").inc(plan.bytes_saved)
            total = plan.total_bytes
            if wire_dt > 0 and total > 0:
                tcur = t_wire
                for tier in sorted(plan.tier_bytes):
                    nb = plan.tier_bytes[tier]
                    share = wire_dt * (nb / total)
                    tr.span(f"tier.{tier}", tcur, tcur + share,
                            track=f"net/{tier}", parent=sp, nbytes=nb)
                    gauge = tr.metrics.gauge(f"net.bw.{tier}")
                    gauge.record(tcur, nb / share if share > 0 else 0.0)
                    gauge.record(tcur + share, 0.0)
                    tcur += share
            tr.metrics.histogram("collective.duration_s").observe(dt)
            tr.metrics.counter(f"collective.{plan.op}").inc()
        return dt

    def tier_snapshot(self) -> Dict[str, int]:
        """Copy of the per-tier counters (pair with :meth:`tier_delta`)."""
        return dict(self.tier_bytes)

    def tier_delta(self, snapshot: Dict[str, int]) -> Dict[str, int]:
        """Per-tier bytes moved since `snapshot` (zero deltas dropped)."""
        return {k: v - snapshot.get(k, 0) for k, v in self.tier_bytes.items()
                if v - snapshot.get(k, 0)}

    def broadcast(self, nbytes: int, n_hosts: int,
                  algorithm: Optional[str] = None,
                  t: Optional[float] = None) -> float:
        """Duration (s) of a one-root broadcast of `nbytes` to `n_hosts`
        hosts, planned over the bound topology (algorithm selected by the
        cost model unless pinned or given). `t` is the issue time consulted
        against the fault schedule (default: the fault clock ``now``)."""
        planner, dead = self._fault_state(t, n_hosts)
        return self._execute_traced(
            planner.plan_broadcast(nbytes, n_hosts - dead, algorithm,
                                   dead=dead, codec=self.codec), t)

    def allgather(self, shard_bytes: int, n_hosts: int,
                  algorithm: Optional[str] = None,
                  t: Optional[float] = None) -> float:
        """Duration (s) of an all-gather where each of `n_hosts` hosts
        contributes `shard_bytes`, planned over the bound topology (dead
        hosts at issue time `t` are re-routed around)."""
        planner, dead = self._fault_state(t, n_hosts)
        return self._execute_traced(
            planner.plan_allgather(shard_bytes, n_hosts - dead, algorithm,
                                   dead=dead, codec=self.codec), t)

    def scatter(self, total_bytes: int, n_hosts: int,
                algorithm: Optional[str] = None,
                t: Optional[float] = None) -> float:
        """Duration (s) of a root scatter of `total_bytes` into 1/P
        shards, planned over the bound topology (dead hosts at issue time
        `t` are re-routed around)."""
        planner, dead = self._fault_state(t, n_hosts)
        return self._execute_traced(
            planner.plan_scatter(total_bytes, n_hosts - dead, algorithm,
                                 dead=dead, codec=self.codec), t)

    def replichain(self, stripe_bytes: int, n_hosts: int, replication: int,
                   t: Optional[float] = None) -> float:
        """Duration (s) of R-way chained stripe replication (the comm
        phase of ``stage_replicated``); degraded tiers at `t` apply."""
        planner, _ = self._fault_state(t, n_hosts)
        return self._execute_traced(
            planner.plan_replichain(stripe_bytes, n_hosts, replication,
                                    codec=self.codec), t)

    def repair(self, transfers: List[Tuple[int, int, int]], n_hosts: int,
               t: Optional[float] = None) -> float:
        """Duration (s) of an explicit point-to-point repair schedule
        (``[(src, dst, nbytes), ...]``; see
        `repro.core.collectives.CollectivePlanner.plan_repair`)."""
        planner, _ = self._fault_state(t, n_hosts)
        return self._execute_traced(planner.plan_repair(transfers, n_hosts),
                                    t)

    def point_to_point(self, nbytes: int, t: Optional[float] = None,
                       attempts: int = 1) -> CollectivePlan:
        """Execute one `nbytes` off-machine ingest message and return the
        EXECUTED plan (duration in ``.time``, wire bytes in
        ``.tier_bytes``/``.total_bytes``) — the form
        `repro.core.wan.WanFanout` needs, since with a bound codec the
        retransmitted wire bytes are the COMPRESSED size, not
        ``attempts * nbytes``."""
        planner, _ = self._fault_state(t, 1)
        plan = planner.plan_point_to_point(nbytes, attempts=attempts,
                                           codec=self.codec)
        self._execute_traced(plan, t)
        return plan

    def point_to_point_time(self, nbytes: int, t: Optional[float] = None,
                            attempts: int = 1) -> float:
        """Duration (s) of one `nbytes` off-machine message (the
        detector->leader ingest hop in `repro.core.streaming`), charged
        to the topology's ingest tier (degraded at `t` if scheduled).
        `attempts` > 1 replays the hop that many times — the WAN
        retransmission model (`repro.core.wan`); time and ingest-tier
        bytes scale together."""
        return self.point_to_point(nbytes, t=t, attempts=attempts).time

    # -- deprecated aliases (pre-topology names) ----------------------------
    def ring_allgather_time(self, shard_bytes: int, n_hosts: int) -> float:
        """Deprecated alias of :meth:`allgather` (the algorithm is now
        planned, not hardwired to the ring)."""
        warnings.warn(
            "Interconnect.ring_allgather_time is a deprecated pre-topology "
            "alias; call Interconnect.allgather, which routes through the "
            "CollectivePlanner (see docs/architecture.md)",
            DeprecationWarning, stacklevel=2)
        return self.allgather(shard_bytes, n_hosts)

    def broadcast_time(self, nbytes: int, n_hosts: int) -> float:
        """Deprecated alias of :meth:`broadcast`."""
        warnings.warn(
            "Interconnect.broadcast_time is a deprecated pre-topology "
            "alias; call Interconnect.broadcast, which routes through the "
            "CollectivePlanner (see docs/architecture.md)",
            DeprecationWarning, stacklevel=2)
        return self.broadcast(nbytes, n_hosts)


@dataclass
class NodeLocalStore:
    """Node-local storage tier (BG/Q RAM disk /tmp; TPU host RAM).

    Holds zero-copy read-only views delivered by the staging/streaming
    engines. Writes are charged at ``local_bw`` bytes/s of simulated time;
    reads are charged by the CONSUMER (``ManyTaskEngine._input_time`` /
    ``TaskInputCache``) at ``local_read_bw``, so :meth:`read` itself only
    counts hits/misses."""
    host_id: int
    constants: FabricConstants
    data: Dict[str, np.ndarray] = field(default_factory=dict)
    bytes_written: int = 0
    hits: int = 0
    misses: int = 0
    # pin REFCOUNTS: several holders (I/O-hook directives, stream pins,
    # dataset-service leases) may pin the same path; it stays exempt from
    # eviction until every holder unpins. Membership tests (`p in pinned`)
    # behave as the former set.
    pinned: Dict[str, int] = field(default_factory=dict)

    def write(self, path: str, data: np.ndarray, t: float) -> float:
        """Store `data` (uint8 buffer/view) at `path`, starting at
        simulated time `t`; returns the write completion time
        (``t + bytes / local_bw``)."""
        self.data[path] = data
        self.bytes_written += data.size
        return t + data.size / self.constants.local_bw

    def write_many(self, replicas: Dict[str, np.ndarray], t: float) -> float:
        """Bulk replica delivery (one dict merge, no per-file Python loop).
        Same time/byte accounting as sequential :meth:`write` calls — writes
        to one node-local store serialize on its bandwidth."""
        self.data.update(replicas)
        nbytes = sum(v.size for v in replicas.values())
        self.bytes_written += nbytes
        return t + nbytes / self.constants.local_bw

    def read(self, path: str) -> Optional[np.ndarray]:
        """The stored buffer, or None on miss. No time is charged here —
        see the class docstring for who pays ``local_read_bw``.

        A hit TOUCHES the entry (moved to most-recently-used), so
        :meth:`evict_lru` sees true access recency, not insertion order —
        a hot-but-old entry is no longer the first eviction victim."""
        if path in self.data:
            self.hits += 1
            val = self.data.pop(path)   # re-insert: dict order = LRU order
            self.data[path] = val
            return val
        self.misses += 1
        return None

    def pin(self, path: str) -> None:
        """Exempt `path` from eviction (human-in-the-loop reuse, §VI-B).
        Pins are refcounted: each :meth:`pin` needs a matching
        :meth:`unpin` before the entry becomes evictable again."""
        pin_ref(self.pinned, path)

    def unpin(self, path: str) -> None:
        """Drop one pin reference on `path` (lease release); the entry
        becomes evictable once the last holder unpins. Unpinning a path
        that is not pinned is a no-op (the holder may have been evicted
        through `drop`, which clears pins)."""
        unpin_ref(self.pinned, path)

    def drop(self, path: str) -> None:
        """Evict `path` if present. Pure bookkeeping — eviction frees
        memory, it is not an I/O, so no simulated time is charged. Any
        pin refs go with the entry (a forced drop must not leave stale
        pins that would shield a later re-staged copy)."""
        self.data.pop(path, None)
        self.pinned.pop(path, None)

    def wipe(self) -> None:
        """Lose EVERYTHING — the host died (`repro.core.faults`). All
        resident data and every pin ref go at once; counters survive
        (they describe history, not state). No simulated time charged:
        node RAM vanishes, it is not drained."""
        self.data.clear()
        self.pinned.clear()

    def evict_lru(self, budget_bytes: int) -> None:
        """Drop unpinned entries in true LRU order (reads re-insert at
        the MRU end — see :meth:`read`) until resident bytes fit
        `budget_bytes`. No simulated time charged."""
        total = sum(v.size for v in self.data.values())
        for path in list(self.data):
            if total <= budget_bytes:
                break
            if path in self.pinned:
                continue
            total -= self.data[path].size
            del self.data[path]


@dataclass
class Host:
    host_id: int
    n_ranks: int
    store: NodeLocalStore

    def leader_rank(self) -> int:
        """The paper's leader communicator: exactly one I/O rank per host."""
        return self.host_id * self.n_ranks


class Fabric:
    """A simulated cluster: P hosts x R ranks, shared FS, interconnect.

    `topology` shapes the interconnect (any loose spelling — a
    `repro.core.topology.Topology`, a ``TopologyConfig``, or a canned
    name like ``"bgq_torus"``); the default ``None`` is the FLAT
    backward-compat machine.

    `faults` is the fabric's fault timeline (`repro.core.faults`); the
    default is the TRIVIAL empty schedule, which keeps every code path
    bit-exact with the pre-fault model. State-changing events (a host
    death wipes its node-local store) apply when the simulation clock is
    advanced past them via :meth:`advance_faults`; timing effects
    (degraded tiers, dead-host re-routing) apply per-collective at the
    issue time passed to the `Interconnect` methods."""

    def __init__(self, n_hosts: int, ranks_per_host: int = 16,
                 constants: FabricConstants = BGQ,
                 topology: TopologyLike = None,
                 faults: Optional[FaultSchedule] = None):
        self.constants = constants
        self.fs = SharedFilesystem(constants)
        self.net = Interconnect(constants,
                                topology=resolve_topology(topology),
                                faults=(faults if faults is not None
                                        else FaultSchedule()))
        self.hosts = [Host(i, ranks_per_host,
                           NodeLocalStore(i, constants))
                      for i in range(n_hosts)]
        self._ranks_per_host = ranks_per_host
        self._faults_applied: set = set()
        self.tracer: TracerLike = NULL_TRACER

    def attach_tracer(self, tracer: TracerLike) -> TracerLike:
        """Bind `tracer` to the fabric and every layer that records into
        it (shared FS, interconnect) — how ``StagingClient(trace=...)``
        and the benchmarks turn telemetry on. Pass
        :data:`~repro.core.telemetry.NULL_TRACER` to turn it back off;
        either way the simulated-time arithmetic is untouched."""
        self.tracer = tracer
        self.fs.tracer = tracer
        self.net.tracer = tracer
        return tracer

    @property
    def faults(self) -> FaultSchedule:
        """The fault timeline in effect — the `Interconnect` binding, so
        a per-stage ``scoped_faults`` overlay is visible to everything
        that asks the fabric (live-host selection in the staging engines,
        catalog transitions), not just to collective timing."""
        return self.net.faults

    @faults.setter
    def faults(self, sched: FaultSchedule) -> None:
        self.net.faults = sched

    @property
    def n_hosts(self) -> int:
        return len(self.hosts)

    @property
    def n_ranks(self) -> int:
        return sum(h.n_ranks for h in self.hosts)

    def leader_hosts(self) -> List[Host]:
        return self.hosts

    # -- fault injection ----------------------------------------------------
    def advance_faults(self, t: float) -> List[FaultEvent]:
        """Advance the fault clock to simulated time `t`, applying every
        not-yet-applied event at or before `t` in timeline order (a host
        death wipes that host's node-local store, pins included; a
        recovery brings the host back BLANK). Returns the events applied
        by THIS call — `repro.core.datasvc.StagingService.sync_faults`
        turns them into catalog transitions."""
        applied: List[FaultEvent] = []
        for ev in self.faults.events:
            if ev.t > t:
                break
            key = (ev.t, ev.kind, ev.host, ev.tier, ev.t_end, ev.factor)
            if key in self._faults_applied:
                continue
            self._faults_applied.add(key)
            if (ev.kind is FaultKind.HOST_DEATH
                    and ev.host < len(self.hosts)):
                self.hosts[ev.host].store.wipe()
            applied.append(ev)
        self.net.now = max(self.net.now, t)
        return applied

    def kill_host(self, host: int, t: float) -> FaultEvent:
        """Inject a host death at simulated time `t` and apply it now."""
        ev = self.faults.inject(FaultEvent(t, FaultKind.HOST_DEATH,
                                           host=host))
        self.advance_faults(t)
        return ev

    def recover_host(self, host: int, t: float) -> FaultEvent:
        """Inject a host recovery (blank store) at `t` and apply it."""
        ev = self.faults.inject(FaultEvent(t, FaultKind.HOST_RECOVERY,
                                           host=host))
        self.advance_faults(t)
        return ev

    def degrade_tier(self, tier: str, t: float, t_end: float,
                     factor: float) -> FaultEvent:
        """Inject a link-tier degradation window ``[t, t_end)`` running at
        ``factor`` of healthy bandwidth."""
        ev = self.faults.inject(FaultEvent(t, FaultKind.LINK_DEGRADE,
                                           tier=tier, t_end=t_end,
                                           factor=factor))
        self.advance_faults(self.net.now)
        return ev

    def dead_ids(self, t: Optional[float] = None) -> List[int]:
        """Host ids dead at `t` (default: the fault clock ``now``)."""
        tq = self.net.now if t is None else t
        return sorted(h for h in self.faults.dead_hosts(tq)
                      if h < len(self.hosts))

    def live_ids(self, t: Optional[float] = None) -> List[int]:
        """Host ids alive at `t` (default: the fault clock ``now``)."""
        dead = set(self.dead_ids(t))
        return [h.host_id for h in self.hosts if h.host_id not in dead]

    def live_hosts(self, t: Optional[float] = None) -> List[Host]:
        """The :class:`Host` objects alive at `t`."""
        dead = set(self.dead_ids(t))
        return [h for h in self.hosts if h.host_id not in dead]

    # -- elasticity ---------------------------------------------------------
    def resize(self, n_hosts: int) -> List[int]:
        """Elastically grow or shrink the fabric to `n_hosts` hosts
        mid-campaign. Growing appends BLANK hosts (ids continue the
        sequence); shrinking removes the highest-id hosts and their
        node-local replicas with them. Returns the affected host ids.
        The catalog-level consequences (grown hosts lack replicas;
        shrunk hosts take redundancy with them) are handled by
        `repro.core.datasvc.StagingService.resize`."""
        if n_hosts < 1:
            raise ValueError(f"cannot resize to {n_hosts} hosts")
        old = len(self.hosts)
        if n_hosts > old:
            self.hosts.extend(
                Host(i, self._ranks_per_host,
                     NodeLocalStore(i, self.constants))
                for i in range(old, n_hosts))
            return list(range(old, n_hosts))
        removed = list(range(n_hosts, old))
        del self.hosts[n_hosts:]
        return removed

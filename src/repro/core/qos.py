"""QoS admission control + scheduling of concurrent analysis sessions.

The paper's facility setting is MULTI-TENANT: several beamline users
share one staging service, each expecting interactive turnaround. The
serial `repro.core.datasvc.StagingService` already coalesces and queues
admissions, but its callers must issue operations in timestamp order —
one session at a time. This module puts the service on the shared
`repro.core.events.EventLoop` so independent sessions genuinely overlap
in simulated time, and adds the policy layer the facility needs when
demand exceeds the node-memory budget:

  * admission control — a request whose dataset neither is resident nor
    fits the budget (even after evicting everything unleased) PARKS and
    is woken by actual lease-release events, instead of relying on the
    serial path's pre-recorded future release times;
  * scheduling — ``fifo`` admits strictly in arrival order (head-of-line
    blocking: nothing behind a parked head starts, the baseline);
    ``qos`` ranks parked requests by effective priority
    ``priority + aging_rate * (now - t_submit)`` (aging bounds
    starvation), breaks ties fair-share (sessions served least go
    first), and BACKFILLS — any admissible parked request may start;
  * preemptive eviction — under ``qos``, staging a new dataset evicts
    unleased residents lowest-priority-first (cost-ranked within a
    priority, priced at the CURRENT timeline state via
    `repro.core.datasvc.predict_stage_time`), protecting high-priority
    tenants' warm datasets; ``fifo`` keeps the serial cheapest-first
    rule.

A single session with no contention takes exactly the serial code path
(`StagingService.acquire` at the arrival time, `_admit` passing straight
through), so zero-contention results are bit-exact with driving the
service directly. `benchmarks/bench_qos.py` puts a heavy-tailed
open-loop load through both policies and reports P50/P99 session latency
and goodput under overload.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.datasvc import (DatasetEntry, DatasetState, Lease,
                                StagingService, predict_stage_time)
from repro.core.events import Event, EventLoop
from repro.core.telemetry import exact_percentile

# states already counted against the budget: acquiring one of these
# costs no new memory (hit / coalesce / repair)
_OCCUPIED = (DatasetState.STAGING, DatasetState.RESIDENT,
             DatasetState.DEGRADED)


@dataclass(frozen=True)
class QoSPolicy:
    """Scheduling policy knobs.

    ``name`` selects the discipline: ``"fifo"`` (strict arrival order,
    the baseline) or ``"qos"`` (priority + aging + fair-share +
    backfill). ``aging_rate`` is priority points gained per simulated
    second parked — any positive rate bounds starvation, since a parked
    request's effective priority eventually tops every fixed one.
    ``preempt`` enables priority-ordered eviction of unleased residents;
    ``fair_share`` breaks rank ties toward the session served least."""
    name: str = "qos"
    aging_rate: float = 1.0
    preempt: bool = True
    fair_share: bool = True

    def __post_init__(self) -> None:
        if self.name not in ("fifo", "qos"):
            raise ValueError(f"unknown policy {self.name!r}; "
                             f"expected 'fifo' or 'qos'")
        if self.aging_rate < 0:
            raise ValueError("aging_rate must be >= 0")


FIFO = QoSPolicy(name="fifo", aging_rate=0.0, preempt=False,
                 fair_share=False)
QOS = QoSPolicy()


@dataclass
class SessionRequest:
    """One session's timed request for one dataset lease.

    Lifecycle: ``submit`` (t_submit) -> possibly parked -> ``t_admit``
    (scheduler starts it) -> ``t_ready`` (replicas usable; latency is
    ``t_ready - t_submit``) -> held for ``hold`` simulated seconds ->
    ``t_release``."""
    session_id: str
    dataset: str
    priority: int = 0
    hold: float = 0.0
    t_submit: float = 0.0
    seq: int = -1
    nbytes: int = 0
    t_admit: float = math.nan
    t_ready: float = math.nan
    t_release: float = math.nan
    park_reason: Optional[str] = None   # why the scheduler parked it (if it
    #                                     did): "budget" (not admissible) or
    #                                     "fifo_head_of_line" (blocked behind
    #                                     a parked head under strict FIFO)
    lease: Optional[Lease] = None
    on_complete: Optional[Callable[["SessionRequest"], None]] = field(
        default=None, repr=False)

    @property
    def latency(self) -> float:
        """Submit-to-ready simulated seconds (the session's wait for
        usable data — the interactivity metric)."""
        return self.t_ready - self.t_submit

    @property
    def parked_time(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def done(self) -> bool:
        return not math.isnan(self.t_release)


class QoSScheduler:
    """Event-driven multi-session front end to a :class:`StagingService`.

    :meth:`submit` schedules arrivals on the shared loop; :meth:`run`
    drains the timeline. Releases fire as timeline events and wake
    parked requests — the event-driven replacement for the serial
    path's "queue on a pre-recorded future release" branch (which
    cannot exist here: no release is known ahead of its event)."""

    def __init__(self, service: StagingService,
                 policy: Optional[QoSPolicy] = None,
                 loop: Optional[EventLoop] = None):
        self.service = service
        self.policy = policy if policy is not None else QOS
        self.loop = loop if loop is not None else EventLoop()
        self.pending: List[SessionRequest] = []
        self.completed: List[SessionRequest] = []
        self.preemptions = 0
        self._served: Dict[str, int] = {}       # session -> completed count
        self._ds_priority: Dict[str, int] = {}  # dataset -> residency priority
        self._seq = 0

    # -- submission ----------------------------------------------------------
    def submit(self, session_id: str, dataset: str, t: float, *,
               priority: int = 0, hold: float = 0.0,
               on_complete: Optional[Callable[["SessionRequest"], None]]
               = None) -> SessionRequest:
        """Schedule `session_id`'s request for `dataset` arriving at
        simulated time `t`; it will hold the lease for `hold` seconds
        past readiness. Returns the (not yet started) request record."""
        req = SessionRequest(session_id=session_id, dataset=dataset,
                             priority=priority, hold=hold, t_submit=t,
                             seq=self._seq, on_complete=on_complete)
        self._seq += 1
        self.loop.schedule(t, lambda: self._arrive(req),
                           key=f"session:{session_id}")
        return req

    def at(self, t: float, fn: Callable[[], None], *,
           key: Optional[str] = None, priority: int = 0) -> Event:
        """Schedule an arbitrary callback on the shared timeline (fault
        injections, resizes, out-of-band work)."""
        return self.loop.schedule(t, fn, key=key, priority=priority)

    def fail_host_at(self, host: int, t: float) -> Event:
        """Inject a host death at `t`, absorbed mid-timeline (before any
        same-instant session event — deaths do not queue behind work)."""
        return self.at(t, lambda: self.service.fail_host(host, t),
                       key="fault", priority=-2)

    def recover_host_at(self, host: int, t: float) -> Event:
        return self.at(t, lambda: self.service.recover_host(host, t),
                       key="fault", priority=-2)

    def resize_at(self, n_hosts: int, t: float) -> Event:
        """Elastically resize the campaign at `t` on the shared timeline."""
        return self.at(t, lambda: self.service.resize(n_hosts, t),
                       key="fault", priority=-2)

    # -- admission test ------------------------------------------------------
    def _freeable(self, now: float) -> List[DatasetEntry]:
        """Unleased residents evictable at `now` (what admission could
        reclaim)."""
        return [e for e in self.service.catalog
                if e.state in (DatasetState.RESIDENT, DatasetState.DEGRADED)
                and not e.leases and e.t_unleased <= now]

    def admissible(self, req: SessionRequest, now: float) -> bool:
        """True when starting `req` at `now` needs no future release:
        its dataset is already budget-resident (hit/coalesce/repair), or
        fits after evicting at most the currently unleased residents."""
        entry = self.service.catalog[req.dataset]
        if entry.state in _OCCUPIED:
            return True
        headroom = (self.service.budget_bytes
                    - self.service.catalog.resident_bytes
                    + sum(e.nbytes for e in self._freeable(now)))
        return entry.nbytes <= headroom

    # -- start / finish ------------------------------------------------------
    def _arrive(self, req: SessionRequest) -> None:
        now = self.loop.now
        req.nbytes = self.service.catalog[req.dataset].nbytes
        fits = self.admissible(req, now)
        if fits and (self.policy.name == "qos" or not self.pending):
            # fifo: an arrival may not overtake a parked head — it only
            # starts straight away when nobody is queued ahead of it
            self._start(req, now)
        else:
            req.park_reason = "budget" if not fits else "fifo_head_of_line"
            tr = self.service.fabric.tracer
            if tr.enabled:
                tr.instant("qos.park", now, track="qos",
                           session=req.session_id, dataset=req.dataset,
                           reason=req.park_reason)
                tr.metrics.counter(f"qos.park.{req.park_reason}").inc()
            self.pending.append(req)

    def _start(self, req: SessionRequest, now: float) -> None:
        entry = self.service.catalog[req.dataset]
        fresh = entry.state not in _OCCUPIED
        if fresh and self.policy.name == "qos" and self.policy.preempt:
            self._make_room(entry.nbytes, now)
        if fresh:
            self._ds_priority[req.dataset] = req.priority
        else:
            self._ds_priority[req.dataset] = max(
                self._ds_priority.get(req.dataset, req.priority),
                req.priority)
        req.t_admit = now
        req.lease = self.service.acquire(req.session_id, req.dataset, now)
        req.t_ready = req.lease.t_ready
        # the lease is held for `hold` seconds of analysis past readiness;
        # the release is a first-class timeline event (priority -1: at an
        # equal instant, memory frees before new arrivals ask for it)
        self.loop.schedule(req.t_ready + req.hold, lambda: self._finish(req),
                           priority=-1, key=f"session:{req.session_id}")

    def _make_room(self, need: int, now: float) -> None:
        """Preemptive eviction, lowest residency priority first (then
        cheapest to restage under the CURRENT timeline state, then name)
        — the qos policy's protection of high-priority warm datasets.
        Leaves any remaining pressure to the serial ``_admit`` rule."""
        cat = self.service.catalog
        while cat.resident_bytes + need > self.service.budget_bytes:
            victims = self._freeable(now)
            if not victims:
                return
            victim = min(victims, key=lambda e: (
                self._ds_priority.get(e.name, 0),
                predict_stage_time(self.service.fabric, e.nbytes,
                                   len(e.paths), t=now),
                e.name))
            self.service._evict(victim, now)
            self.preemptions += 1

    def _finish(self, req: SessionRequest) -> None:
        now = self.loop.now
        self.service.release(req.session_id, req.dataset, now)
        req.t_release = now
        self.completed.append(req)
        self._served[req.session_id] = (
            self._served.get(req.session_id, 0) + 1)
        tr = self.service.fabric.tracer
        if tr.enabled:
            # record only: every timestamp below was computed above, untraced
            sp = tr.span("qos.request", req.t_submit, now, track="qos",
                         session=req.session_id, dataset=req.dataset,
                         priority=req.priority, park_reason=req.park_reason)
            if req.t_admit > req.t_submit:
                tr.span("qos.parked", req.t_submit, req.t_admit, track="qos",
                        parent=sp, reason=req.park_reason)
            if req.t_ready > req.t_admit:
                tr.span("qos.service", req.t_admit, req.t_ready, track="qos",
                        parent=sp)
            if now > req.t_ready:
                tr.span("qos.hold", req.t_ready, now, track="qos", parent=sp)
            tr.metrics.histogram("qos.latency_s").observe(req.latency)
            tr.metrics.counter("qos.completed").inc()
        if req.on_complete is not None:
            req.on_complete(req)
        self._wake(now)

    # -- wake-up discipline --------------------------------------------------
    def _rank(self, req: SessionRequest, now: float):
        aged = req.priority + self.policy.aging_rate * (now - req.t_submit)
        share = (self._served.get(req.session_id, 0)
                 if self.policy.fair_share else 0)
        return (-aged, share, req.t_submit, req.seq)

    def _wake(self, now: float) -> None:
        if self.policy.name == "fifo":
            # strict arrival order: drain the admissible PREFIX only —
            # a parked head blocks everything behind it (head-of-line
            # blocking, the baseline's P99 failure mode under overload)
            while self.pending and self.admissible(self.pending[0], now):
                self._start(self.pending.pop(0), now)
            return
        # qos: repeatedly start the best-ranked admissible request;
        # backfill means a blocked leader does not idle the budget, and
        # aging means it cannot be overtaken forever
        while self.pending:
            for req in sorted(self.pending, key=lambda r: self._rank(r, now)):
                if self.admissible(req, now):
                    self.pending.remove(req)
                    self._start(req, now)
                    break
            else:
                return

    # -- drain ---------------------------------------------------------------
    def run(self, until: float = math.inf) -> float:
        """Drain the shared timeline (up to `until`). A full drain that
        leaves requests parked means no release can ever admit them —
        the event-driven analogue of the serial path's "wedged" error,
        raised just as loudly."""
        t_end = self.loop.run(until=until)
        if self.pending and not math.isfinite(until):
            starved = [(r.session_id, r.dataset) for r in self.pending]
            raise RuntimeError(
                f"scheduler drained with {len(self.pending)} request(s) "
                f"still parked (no release left to wake them): {starved}")
        return t_end

    # -- reporting -----------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """P50/P99 session latency, goodput, and counters over the
        completed requests (simulated time throughout)."""
        if not self.completed:
            return {"completed": 0, "parked": len(self.pending),
                    "p50_latency": math.nan, "p99_latency": math.nan,
                    "mean_latency": math.nan, "goodput_bytes_per_s": 0.0,
                    "makespan": 0.0, "preemptions": self.preemptions}
        lat = np.array([r.latency for r in self.completed])
        t0 = min(r.t_submit for r in self.completed)
        t1 = max(r.t_release for r in self.completed)
        makespan = t1 - t0
        total = float(sum(r.nbytes for r in self.completed))
        return {
            "completed": len(self.completed),
            "parked": len(self.pending),
            "p50_latency": exact_percentile(lat, 50),
            "p99_latency": exact_percentile(lat, 99),
            "mean_latency": float(lat.mean()),
            "goodput_bytes_per_s": total / makespan if makespan > 0 else 0.0,
            "makespan": makespan,
            "preemptions": self.preemptions,
        }

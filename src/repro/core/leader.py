"""Leader communicator (paper §IV).

Exactly one I/O rank per node forms the leader group; the remaining ranks
never touch the shared FS during staging. Metadata is resolved by the group
root and broadcast. In the JAX runtime this maps to "one process per host"
(jax.process_index) doing I/O; in the simulated fabric, to Host.leader_rank.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple, TypeVar

from repro.core.fabric import Fabric

T = TypeVar("T")


def manifest_bytes(files: Sequence[str]) -> int:
    """Wire size of a resolved file manifest: the path strings plus an
    8-byte (size/offset) header per entry — the payload ``on_root``
    broadcasts after the root's glob."""
    return sum(len(f) for f in files) + 8 * len(files)


@dataclass
class LeaderGroup:
    """One member per host. Root = member 0 (metadata resolution)."""
    fabric: Fabric

    @property
    def members(self) -> List[int]:
        return [h.leader_rank() for h in self.fabric.hosts]

    @property
    def root(self) -> int:
        return self.members[0]

    def is_leader(self, rank: int) -> bool:
        return rank in set(self.members)

    def on_root(self, fn: Callable[[], T],
                payload_bytes: Optional[int] = None) -> Tuple[T, float]:
        """Run a metadata operation once (root) and broadcast its result
        to the other leaders.

        Returns ``(result, broadcast seconds)`` — the broadcast duration
        is simulated time the CALLER must place on its timeline and
        charge into ``StagingReport.broadcast_time`` (it is real wire
        traffic, accounted in ``Interconnect.bytes_moved`` here).
        ``payload_bytes`` overrides the wire-size estimate; by default
        the result is treated as a file manifest (:func:`manifest_bytes`).
        """
        result = fn()
        if payload_bytes is None:
            payload_bytes = manifest_bytes(result)  # type: ignore[arg-type]
        return result, self.broadcast_time(max(int(payload_bytes), 1))

    def broadcast_time(self, nbytes: int) -> float:
        """Duration of one leader-group broadcast of `nbytes`, planned
        over the fabric's topology (`repro.core.collectives`) and
        accounted on the interconnect's per-tier counters."""
        return self.fabric.net.broadcast(nbytes, self.fabric.n_hosts)


def jax_leader_process(process_index: int, processes_per_host: int = 1) -> bool:
    """JAX-runtime analogue: is this process its host's I/O leader?"""
    return process_index % processes_per_host == 0

"""Leader communicator (paper §IV).

Exactly one I/O rank per node forms the leader group; the remaining ranks
never touch the shared FS during staging. Metadata is resolved by the group
root and broadcast. In the JAX runtime this maps to "one process per host"
(jax.process_index) doing I/O; in the simulated fabric, to Host.leader_rank.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Sequence, TypeVar

from repro.core.fabric import Fabric

T = TypeVar("T")


@dataclass
class LeaderGroup:
    """One member per host. Root = member 0 (metadata resolution)."""
    fabric: Fabric

    @property
    def members(self) -> List[int]:
        return [h.leader_rank() for h in self.fabric.hosts]

    @property
    def root(self) -> int:
        return self.members[0]

    def is_leader(self, rank: int) -> bool:
        return rank in set(self.members)

    def on_root(self, fn: Callable[[], T]) -> T:
        """Run a metadata operation once (root), conceptually broadcast."""
        return fn()

    def broadcast_time(self, nbytes: int) -> float:
        return self.fabric.net.broadcast_time(nbytes, self.fabric.n_hosts)


def jax_leader_process(process_index: int, processes_per_host: int = 1) -> bool:
    """JAX-runtime analogue: is this process its host's I/O leader?"""
    return process_index % processes_per_host == 0

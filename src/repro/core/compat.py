"""Version-compat shims for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed two keywords along the way (``check_rep`` -> ``check_vma``,
``auto`` -> the complementary ``axis_names``). Callers in this repo use the
NEW spelling; this wrapper translates for older installs so the same source
runs on both.
"""
from __future__ import annotations

from typing import Iterable, Optional

try:                                       # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
    _NEW_API = True
except ImportError:                        # jax 0.4.x/0.5.x: experimental
    from jax.experimental.shard_map import shard_map as _shard_map
    _NEW_API = False


def make_auto_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types on jax >= 0.6, plain mesh on
    older versions (where ``jax.sharding.AxisType`` does not exist)."""
    import jax
    try:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except AttributeError:
        return jax.make_mesh(shape, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None,
              axis_names: Optional[Iterable[str]] = None):
    """``jax.shard_map`` with the new keyword spelling on any jax version.

    ``axis_names`` selects the manual axes (new API); on the old API it is
    translated to ``auto`` = the complement of the manual set.
    """
    kwargs = {}
    if _NEW_API:
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
    else:
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        if axis_names is not None:
            kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)

"""Streaming detector ingestion — frames land in node memory as produced.

The paper stages complete on-disk datasets into compute-node memory
(`repro.core.staging`); the follow-on literature (Welborn et al.,
"Streaming Detector Data Directly into Perlmutter Compute Nodes";
Poeschel et al., openPMD + ADIOS2 streaming pipelines) shows the next win
is skipping the shared-FS round trip entirely: the detector pushes each
frame over the fabric into node-local memory while acquisition is still
in flight, and analysis tasks become eligible the moment their frame
lands instead of when the scan closes.

Pieces:

  * :class:`DetectorSource` — a simulated detector emitting frames at a
    configurable ``rate_hz``; wraps an in-memory frame stack or replays
    files already resident on the shared FS.
  * :class:`StreamStager` — per-frame delivery: scatter each frame to its
    owning leader host (round-robin over hosts, the streaming analogue of
    the leader communicator), then a pipelined ring broadcast to every
    node-local store. Delivery reuses the zero-copy replica discipline of
    ``staging.py`` (:func:`repro.core.staging.readonly_view`): every store
    holds a read-only view of the single emitted buffer, so delivery is
    byte-exact with no per-host copies. The stager maintains a
    **sliding-window node-local cache**: a per-node byte budget with
    watermark-based eviction of consumed frames, pinning, and
    **backpressure** — when consumers fall behind and the window holds
    only unconsumed/pinned frames, admission of the next frame stalls
    until a consumer release frees space (the DAQ-buffer stall of a real
    streaming deployment).
  * :func:`stage_stream` — a one-shot staging engine registered as
    ``"stream"`` in `repro.core.api.ENGINES` (typed config:
    ``StreamConfig``; selectable via ``StagingClient.stage`` or the
    legacy ``run_io_hook(..., mode="stream")`` shim): the dataset is
    ingested from the source stream and never read back from the shared
    FS (``fs_bytes == 0``).
  * :class:`StreamScenario` — a simulator scenario bundling fabric +
    acquisition parameters (hosts, frame geometry, rate, consumer window),
    used by the examples, benchmarks and tests.

Units: all simulated times are SECONDS, all sizes BYTES, rates in frames
per simulated second. Frames move REAL bytes; only the clock is modeled.
Frame futures: a delivered frame's :class:`FrameRecord` carries
``t_avail``; ``Task.not_before`` / ``Dataflow.frame_task`` turn that into
scheduler eligibility (see `repro.core.manytask` / `repro.core.dataflow`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compression import CompressionLike, CompressionStats
from repro.core.fabric import (BGQ, Fabric, FabricConstants, pin_ref,
                               unpin_ref)
from repro.core.staging import (StagingReport, _close_stage_span,
                                readonly_view)
from repro.core.topology import TopologyLike, resolve_topology


@dataclass
class FrameRecord:
    """Delivery record for one streamed frame — the *frame future*.

    ``t_emit``  simulated s the detector finished producing the frame;
    ``t_avail`` simulated s the frame became resident on EVERY node-local
    store (feed this to ``Task.not_before``); ``stalled`` is the
    backpressure wait charged to this frame's admission (s).
    """
    frame_id: int
    path: str
    nbytes: int
    owner_host: int
    t_emit: float
    t_avail: float
    stalled: float = 0.0


@dataclass
class StreamReport:
    """Accounting for one streamed acquisition (all times simulated s)."""
    n_hosts: int
    n_frames: int = 0
    total_bytes: int = 0           # emitted frame bytes (pre-replication)
    acquisition_span: float = 0.0  # last t_emit - t0 (detector-limited)
    ingest_makespan: float = 0.0   # last t_avail - t0 (delivery-limited)
    mean_latency: float = 0.0      # mean(t_avail - t_emit) per frame
    stall_time: float = 0.0        # total backpressure wait across frames
    evictions: int = 0             # frames dropped from the sliding window
    peak_resident_bytes: int = 0   # high-water mark of the node window
    degraded_deliveries: int = 0   # frames delivered around dead hosts
    net_bytes: int = 0             # interconnect WIRE traffic (pull+broadcast)
    # interconnect WIRE bytes per topology tier (sums to net_bytes; the
    # compressed count on codec-elected tiers — `comp` has the split,
    # while total_bytes stays the logical/payload frame count)
    tier_bytes: Dict[str, int] = field(default_factory=dict)
    # codec accounting over this stream's executed plans
    comp: CompressionStats = field(default_factory=CompressionStats)
    # multi-consumer pub/sub accounting (registered consumers only;
    # empty / -1 / 0 for single-consumer streams):
    #   consumer_lag    per-consumer mean ack lag behind t_avail (s)
    #   watermark_frame highest frame id fully released by EVERY consumer
    #   watermark_lag   mean extra retention the slowest consumer adds
    #                   per fully-released frame (max ack - min ack, s)
    consumer_lag: Dict[str, float] = field(default_factory=dict)
    watermark_frame: int = -1
    watermark_lag: float = 0.0
    mode: str = "stream"


class DetectorSource:
    """Simulated detector: yields ``(frame_id, path, uint8 buffer, t_emit)``.

    ``rate_hz`` is the acquisition rate in frames per simulated second;
    frame ``i`` finishes exposure at ``t0 + (i + 1) / rate_hz``.
    ``rate_hz=None`` means the whole set is already available at ``t0``
    (replay mode — the degenerate case equivalent to batch input).
    """

    def __init__(self, buffers: Sequence[Tuple[str, np.ndarray]],
                 rate_hz: Optional[float] = None, t0: float = 0.0):
        self.buffers = list(buffers)
        self.rate_hz = rate_hz
        self.t0 = t0

    @classmethod
    def from_frames(cls, frames: np.ndarray, rate_hz: Optional[float] = None,
                    t0: float = 0.0, prefix: str = "scan") -> "DetectorSource":
        """Wrap a (F, H, W) frame stack; paths match ``stream_to_fs`` naming
        so batch and streaming runs of the same scan share file names."""
        bufs = [(f"{prefix}/frame_{i:05d}.bin",
                 np.ascontiguousarray(frames[i]).view(np.uint8).ravel())
                for i in range(len(frames))]
        return cls(bufs, rate_hz=rate_hz, t0=t0)

    @classmethod
    def replay_fs(cls, fabric: Fabric, paths: Sequence[str],
                  rate_hz: Optional[float] = None, t0: float = 0.0
                  ) -> "DetectorSource":
        """Replay files resident on the shared FS as a stream. The source
        taps the producer's buffer directly (detector -> compute push), so
        no FS read time or ``fs.bytes_read`` is charged."""
        return cls([(p, fabric.fs.files[p]) for p in paths],
                   rate_hz=rate_hz, t0=t0)

    def __len__(self) -> int:
        return len(self.buffers)

    def __iter__(self) -> Iterator[Tuple[int, str, np.ndarray, float]]:
        for i, (path, buf) in enumerate(self.buffers):
            t_emit = (self.t0 if self.rate_hz is None
                      else self.t0 + (i + 1) / self.rate_hz)
            yield i, path, buf, t_emit


class StreamStager:
    """Scatter + ring-broadcast delivery with a sliding-window node cache.

    Per frame: the detector link sends the frame to its owning leader host
    (``frame_id % P``, serialized on the NIC), the leader ring-broadcasts
    it to all hosts (serialized on the broadcast ring, *pipelined behind*
    the scatter — frame k+1's scatter overlaps frame k's broadcast, the
    streaming analogue of ``stage_pipelined``), and every node-local store
    writes one shared read-only view (zero-copy, byte-exact).

    Window policy (per-node budget ``window_bytes``):

      * admission above ``high_watermark * window_bytes`` evicts frames
        that are *released* (consumed) and unpinned, oldest-first, down to
        ``low_watermark * window_bytes``;
      * if the frame still does not fit, admission **stalls** until future
        consumer releases free enough space (backpressure; accumulated in
        ``stall_time``), and raises ``RuntimeError`` if no release can
        ever make it fit (window wedged by pinned/unconsumed frames).

    Incremental driver protocol::

        stager = StreamStager(fabric, window_bytes=...)
        for fid, path, buf, t_emit in source:
            rec = stager.ingest(path, buf, t_emit)
            ... consume; when done with a frame: stager.release(path, t)
        report = stager.finish()
    """

    def __init__(self, fabric: Fabric, window_bytes: int,
                 high_watermark: float = 0.9, low_watermark: float = 0.5,
                 t0: float = 0.0, topology: TopologyLike = None,
                 compression: CompressionLike = None):
        if not 0.0 < low_watermark <= high_watermark <= 1.0:
            raise ValueError("need 0 < low_watermark <= high_watermark <= 1")
        self.fabric = fabric
        self.window_bytes = int(window_bytes)
        self.high_watermark = high_watermark
        self.low_watermark = low_watermark
        self.t0 = t0
        # per-stager machine-model override: every delivery collective is
        # planned under this topology (None -> whatever the fabric runs)
        self._topology = (None if topology is None
                          else resolve_topology(topology))
        # per-stager codec: elected per tier by the planner on the ingest
        # hop and delivery broadcast (None -> the fabric binding, which
        # defaults to the bit-exact uncompressed path)
        self._compression = compression
        self.records: List[FrameRecord] = []
        self.stall_time = 0.0
        self.evictions = 0
        self.peak_resident = 0
        self.degraded_deliveries = 0    # frames that skipped dead hosts
        self._resident: Dict[str, int] = {}     # path -> bytes, arrival order
        self._released: Dict[str, float] = {}   # path -> simulated release t
        self._pinned: Dict[str, int] = {}       # path -> pin refcount
        self._consumers: set = set()            # registered shared consumers
        self._acks: Dict[str, Dict[str, float]] = {}  # path -> consumer -> t
        self._avail: Dict[str, float] = {}      # path -> t_avail (lag base)
        self._frame_of: Dict[str, int] = {}     # path -> frame id (watermark)
        self._lag_sum: Dict[str, float] = {}    # consumer -> sum ack lag
        self._lag_n: Dict[str, int] = {}        # consumer -> acked frames
        self._watermark_frame = -1              # highest fully-released fid
        self._watermark_extra = 0.0             # sum(max ack - min ack)
        self._full_releases = 0                 # frames acked by everyone
        self._nic_busy = t0                     # detector link serialization
        self._bcast_busy = t0                   # broadcast ring serialization
        self._net0 = fabric.net.bytes_moved
        self._tier0 = fabric.net.tier_snapshot()
        self._comp0 = fabric.net.comp_snapshot()

    # -- window bookkeeping -------------------------------------------------
    def _resident_bytes(self) -> int:
        return sum(self._resident.values())

    def _delivery_hosts(self, t: float) -> List["object"]:
        """The hosts a frame lands on: all of them on a healthy fabric
        (the exact pre-fault path), the LIVE set at simulated time `t`
        under a non-trivial fault schedule — a dead host's store receives
        nothing (degraded ingest: acquisition keeps running, the dead
        node just misses frames until it recovers and re-acquires)."""
        if self.fabric.faults.trivial:
            return self.fabric.hosts
        return self.fabric.live_hosts(t)

    def _pinned_anywhere(self, path: str) -> bool:
        """Pinned by this stager OR by any other holder in the node-local
        stores (e.g. a dataset-service lease on the same paths) — window
        eviction must respect foreign pins, not just its own. Store pins
        are symmetric across LIVE hosts (a dead host's pins were wiped
        with its store), so the first live host is representative."""
        hosts = self._delivery_hosts(self.fabric.net.now)
        return (path in self._pinned
                or (bool(hosts) and path in hosts[0].store.pinned))

    def _evictable(self, path: str, t: float) -> bool:
        return (not self._pinned_anywhere(path)
                and self._released.get(path, float("inf")) <= t)

    def _drop(self, path: str) -> None:
        del self._resident[path]
        self._released.pop(path, None)
        self._acks.pop(path, None)
        for host in self.fabric.hosts:
            host.store.drop(path)
        self.evictions += 1

    def _evict_down_to(self, target_bytes: float, t: float) -> None:
        for path in list(self._resident):       # insertion order = arrival
            if self._resident_bytes() <= target_bytes:
                break
            if self._evictable(path, t):
                self._drop(path)

    def _admit(self, nbytes: int, t_arrive: float) -> float:
        """Admission time for a frame of `nbytes` arriving at `t_arrive`:
        watermark eviction first, then backpressure on future releases."""
        t = t_arrive
        high = self.high_watermark * self.window_bytes
        if self._resident_bytes() + nbytes > high:
            self._evict_down_to(self.low_watermark * self.window_bytes, t)
        if self._resident_bytes() + nbytes <= self.window_bytes:
            return t
        # backpressure: advance to consumer releases, oldest release first
        pending = sorted((rt, p) for p, rt in self._released.items()
                         if p in self._resident
                         and not self._pinned_anywhere(p) and rt > t)
        for rt, path in pending:
            t = rt
            self._drop(path)
            if self._resident_bytes() + nbytes <= self.window_bytes:
                return t
        raise RuntimeError(
            f"stream window wedged: frame of {nbytes} B cannot fit in "
            f"{self.window_bytes} B window holding "
            f"{self._resident_bytes()} B of pinned/unconsumed frames")

    # -- public API ---------------------------------------------------------
    def _pull_time(self, nbytes: int, t: float) -> float:
        """Duration of THIS frame's detector->leader ingest hop, issued at
        `t`. The seam subclasses override to put a different wire model on
        the hop — `repro.core.wan.WanFanout` adds seeded loss/retransmits
        here — without touching any other delivery arithmetic. The default
        is exactly the lossless point-to-point plan."""
        return self.fabric.net.point_to_point_time(nbytes, t=t)

    def ingest(self, path: str, data: np.ndarray, t_emit: float,
               t_offer: Optional[float] = None) -> FrameRecord:
        """Deliver one frame to every node-local store.

        `data` is the emitted frame (any dtype; flattened to uint8);
        `t_emit` the simulated second the detector finished producing it.
        `t_offer` is when the frame is OFFERED to the fabric — ``None``
        means at emission, the push model; a flow-controlled producer
        (`repro.core.wan`) offers later, once it holds a send credit, and
        the frame's latency is still measured from `t_emit`.
        Returns the frame's :class:`FrameRecord` (its future).
        """
        buf = np.ascontiguousarray(data).view(np.uint8).ravel()
        view = readonly_view(buf)
        nbytes = int(buf.size)
        net = self.fabric.net
        c = self.fabric.constants

        t_arrive = max(t_emit if t_offer is None else t_offer,
                       self._nic_busy)
        t_admit = self._admit(nbytes, t_arrive)
        stalled = t_admit - t_arrive
        self.stall_time += stalled

        owner = len(self.records) % self.fabric.n_hosts
        with net.scoped_topology(self._topology), \
                net.scoped_codec(self._compression):
            # issue times feed the fault schedule: a degraded ingest tier
            # or a dead host at THIS frame's delivery slows/reroutes it
            self._nic_busy = t_admit + self._pull_time(nbytes, t_admit)
            t_bc = max(self._nic_busy, self._bcast_busy)
            self._bcast_busy = t_bc + net.broadcast(nbytes,
                                                    self.fabric.n_hosts,
                                                    t=t_bc)
        t_avail = self._bcast_busy + nbytes / c.local_bw

        targets = self._delivery_hosts(t_bc)
        self.degraded_deliveries += int(len(targets) < self.fabric.n_hosts)
        for host in targets:
            host.store.write(path, view, 0.0)
        self._resident[path] = nbytes
        self.peak_resident = max(self.peak_resident, self._resident_bytes())

        rec = FrameRecord(frame_id=len(self.records), path=path,
                          nbytes=nbytes, owner_host=owner, t_emit=t_emit,
                          t_avail=t_avail, stalled=stalled)
        self.records.append(rec)
        self._avail[path] = t_avail
        self._frame_of[path] = rec.frame_id

        tr = self.fabric.tracer
        if tr.enabled:
            # record only: every time below was computed above, untraced
            with tr.region("stream.frame", t_arrive, track="stream",
                           frame_id=rec.frame_id, path=path, nbytes=nbytes,
                           owner_host=owner) as sp:
                if stalled > 0:
                    tr.span("stream.stall", t_arrive, t_admit,
                            reason="window_backpressure")
                    tr.metrics.counter("stream.stalls").inc()
                tr.span("stream.scatter", t_admit, self._nic_busy)
                tr.span("stream.broadcast", t_bc, self._bcast_busy)
                tr.span("stream.local_write", self._bcast_busy, t_avail)
                sp.t_end = t_avail
            tr.metrics.counter("stream.frames").inc()
            tr.metrics.histogram("stream.frame_latency_s").observe(
                t_avail - t_emit)
            tr.metrics.gauge("stream.resident_bytes").record(
                t_admit, self._resident_bytes())
        return rec

    def register_consumer(self, consumer: str) -> None:
        """Declare a named consumer SHARING this window (e.g. two analysis
        sessions reducing the same acquisition). Once any consumer is
        registered, a frame only becomes evictable when EVERY registered
        consumer has released it — at the LATEST ack time, so the slowest
        session is what backpressures the detector. With no registered
        consumers, :meth:`release` keeps its single-consumer semantics."""
        self._consumers.add(consumer)

    def release(self, path: str, t: float,
                consumer: Optional[str] = None) -> None:
        """Consumer ack: `path` becomes evictable at simulated time `t`.

        With `consumer` (a name from :meth:`register_consumer`), the ack
        is per-consumer; the frame's release time is the max ack once all
        registered consumers have acked."""
        if consumer is None:
            self._released[path] = t
            return
        if consumer not in self._consumers:
            raise ValueError(
                f"unknown consumer {consumer!r}; registered: "
                f"{sorted(self._consumers)} (register_consumer first)")
        acks = self._acks.setdefault(path, {})
        acks[consumer] = t
        if set(acks) == self._consumers:
            t_rel = max(acks.values())
            self._released[path] = t_rel
            # pub/sub accounting: per-consumer ack lag behind delivery and
            # the retention the slowest consumer adds (watermark cost)
            avail = self._avail.get(path)
            if avail is not None:
                for name, ta in acks.items():
                    self._lag_sum[name] = (self._lag_sum.get(name, 0.0)
                                           + (ta - avail))
                    self._lag_n[name] = self._lag_n.get(name, 0) + 1
                self._watermark_extra += t_rel - min(acks.values())
                self._full_releases += 1
                self._watermark_frame = max(self._watermark_frame,
                                            self._frame_of.get(path, -1))

    def fully_released(self, path: str) -> bool:
        """True once `path` is evictable — released directly, or acked by
        EVERY registered consumer (the pub/sub watermark has passed it)."""
        return path in self._released

    def pin(self, path: str) -> None:
        """Exempt `path` from window eviction (it keeps counting against
        the budget); also pins it in every node-local store. Pins are
        refcounted (lease-aware): several holders — the I/O-hook pin
        directive, dataset-service leases — may pin the same frame, and
        it stays exempt until every one calls :meth:`unpin`. Only LIVE
        hosts take the store pin — a dead host holds no replica to
        shield, and a stranded refcount would survive its recovery."""
        pin_ref(self._pinned, path)
        for host in self._delivery_hosts(self.fabric.net.now):
            host.store.pin(path)

    def unpin(self, path: str) -> None:
        """Drop one pin reference on `path` (and the matching node-local
        store pin); after the last holder unpins, the frame is evictable
        again the moment it is also released. No-op when this stager
        holds no pin — other holders' store pins are never touched."""
        if unpin_ref(self._pinned, path):
            for host in self.fabric.hosts:
                host.store.unpin(path)

    def finish(self) -> StreamReport:
        """Close the stream and return the acquisition's accounting."""
        rep = StreamReport(n_hosts=self.fabric.n_hosts,
                           n_frames=len(self.records))
        if self.records:
            rep.total_bytes = sum(r.nbytes for r in self.records)
            rep.acquisition_span = max(r.t_emit for r in self.records) - self.t0
            rep.ingest_makespan = max(r.t_avail for r in self.records) - self.t0
            rep.mean_latency = float(np.mean(
                [r.t_avail - r.t_emit for r in self.records]))
        rep.stall_time = self.stall_time
        rep.evictions = self.evictions
        rep.peak_resident_bytes = self.peak_resident
        rep.degraded_deliveries = self.degraded_deliveries
        rep.net_bytes = self.fabric.net.bytes_moved - self._net0
        rep.tier_bytes = self.fabric.net.tier_delta(self._tier0)
        rep.comp = self.fabric.net.comp_delta(self._comp0)
        rep.consumer_lag = {
            name: self._lag_sum[name] / self._lag_n[name]
            for name in sorted(self._lag_sum)}
        rep.watermark_frame = self._watermark_frame
        if self._full_releases:
            rep.watermark_lag = self._watermark_extra / self._full_releases
        return rep

    def stage(self, source: DetectorSource, release_on_delivery: bool = False
              ) -> Tuple[StreamReport, List[FrameRecord]]:
        """Convenience: ingest a whole source with no external consumer.

        By default frames are never released, so everything stays resident
        (requires the window to hold the whole set). With
        ``release_on_delivery`` each frame is released the moment it lands:
        the window behaves as a pure sliding cache — once full, the oldest
        unpinned frames evict — which permits ``window_bytes`` smaller than
        the set (only the most recent frames remain resident at the end).
        """
        records = []
        for _, path, buf, t_emit in source:
            rec = self.ingest(path, buf, t_emit)
            if release_on_delivery:
                self.release(path, rec.t_avail)
            records.append(rec)
        return self.finish(), records


def stage_stream(fabric: Fabric, paths: Sequence[str], t0: float = 0.0,
                 rate_hz: Optional[float] = None,
                 window_bytes: Optional[int] = None,
                 pin_paths: Sequence[str] = (),
                 topology: TopologyLike = None,
                 compression: CompressionLike = None
                 ) -> Tuple[StagingReport, float]:
    """I/O-hook-compatible streaming engine (``mode="stream"``).

    Ingests `paths` from the producer stream straight into every node-local
    store — the shared FS is never read back (``fs_bytes == 0``), which is
    the whole point of streaming ingestion. `rate_hz=None` replays the set
    as fast as the fabric delivers it. ``window_bytes`` defaults to the
    whole set (every file ends resident, matching the batch engines); a
    smaller budget turns the node cache into a sliding window — frames are
    released as they land and the oldest unpinned ones evict, leaving only
    the most recent ``window_bytes`` resident. ``pin_paths`` are pinned AT
    INGEST (the I/O-hook pin directive): exempt from window eviction, so a
    bounded window too small for its pinned set fails loudly ("wedged")
    rather than silently evicting files the spec promised to keep.
    Returns ``(report, completion t)`` like the batch engines; the
    report's ``n_chunks`` is the frame count.
    """
    total = sum(fabric.fs.size(p) for p in paths)
    bounded = window_bytes is not None and window_bytes < total
    src = DetectorSource.replay_fs(fabric, paths, rate_hz=rate_hz, t0=t0)
    with fabric.tracer.region("stage.stream", t0, track="engine") as tsp:
        stager = StreamStager(fabric,
                              window_bytes=window_bytes or max(total, 1),
                              t0=t0, topology=topology,
                              compression=compression)
        pin_set = set(pin_paths)
        for _, path, buf, t_emit in src:
            rec = stager.ingest(path, buf, t_emit)
            if path in pin_set:
                stager.pin(path)
            elif bounded:
                stager.release(path, rec.t_avail)
        srep = stager.finish()

        rep = StagingReport(n_hosts=fabric.n_hosts, total_bytes=total,
                            mode="stream")
        rep.stage_time = 0.0                   # no FS read phase at all
        rep.write_time = total / fabric.constants.local_bw
        rep.comm_time = max(0.0, srep.ingest_makespan - rep.write_time)
        rep.fs_bytes = 0
        rep.net_bytes = srep.net_bytes
        rep.tier_bytes = dict(srep.tier_bytes)
        rep.comp = srep.comp
        rep.n_chunks = srep.n_frames
        _close_stage_span(fabric, tsp, rep, t0)
        return rep, t0 + srep.ingest_makespan


@dataclass
class StreamScenario:
    """One simulated acquisition: fabric + detector + consumer window.

    ``rate_hz`` in frames per simulated second; ``window_frames`` is the
    consumer's reduce batch; ``cache_frames`` bounds the per-node sliding
    window (``None`` -> the whole scan fits, no eviction/backpressure).
    """
    n_hosts: int = 64
    n_frames: int = 48
    frame_size: int = 128          # square detector, pixels per side
    n_spots: int = 6
    rate_hz: float = 10.0
    window_frames: int = 8
    cache_frames: Optional[int] = None
    seed: int = 0
    constants: FabricConstants = field(default_factory=lambda: BGQ)

    @property
    def frame_bytes(self) -> int:
        return self.frame_size * self.frame_size * 4      # float32 pixels

    @property
    def window_bytes(self) -> int:
        return (self.cache_frames or self.n_frames) * self.frame_bytes

    def make_fabric(self) -> Fabric:
        return Fabric(n_hosts=self.n_hosts, constants=self.constants)

    def make_frames(self) -> Tuple[np.ndarray, np.ndarray]:
        """Synthetic (frames, dark) for this scenario's detector geometry."""
        from repro.hedm.pipeline import simulate_detector_frames
        return simulate_detector_frames(self.n_frames, size=self.frame_size,
                                        n_spots=self.n_spots, seed=self.seed)

    def make_source(self, frames: np.ndarray) -> DetectorSource:
        return DetectorSource.from_frames(frames, rate_hz=self.rate_hz)

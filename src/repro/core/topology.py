"""Hierarchical interconnect topology (hosts -> racks -> cluster).

The paper's headline result was measured on a 5D-torus Blue Gene/Q; the
follow-on streaming literature (Welborn et al., Perlmutter detector
streaming; Poeschel et al., openPMD/ADIOS2 pipelines) shows delivery cost
is dominated by WHICH NETWORK TIER the bytes cross. A flat per-link model
cannot express that, so the communication model is layered:

  * this module — the pure machine description: :class:`LinkTier`
    (bandwidth, latency, optional bisection cap per tier) and
    :class:`Topology` (hosts grouped into racks/pods, one intra-rack and
    one optional inter-rack tier, plus the pipeline segment size);
  * `repro.core.collectives` — the :class:`~repro.core.collectives.
    CollectivePlanner` that turns a topology into explicit collective
    algorithms with per-tier byte accounting;
  * `repro.core.fabric.Interconnect` — executes planned collectives and
    accumulates the per-tier traffic counters.

Canned instances:

  * :data:`FLAT` — the backward-compatibility anchor: one tier whose
    bandwidth/latency INHERIT the fabric's ``link_bw``/``link_latency``
    constants, with the legacy ring algorithms pinned, so a FLAT fabric
    reproduces the pre-topology accounting bit-for-bit.
  * :data:`BGQ_TORUS` — Blue Gene/Q flavored: 512-node midplanes on 5D
    torus links, optical inter-midplane links with a bisection cap.
  * :data:`TPU_POD_ICI_DCN` — TPU-pod flavored: 64-host ICI slices,
    DCN between slices.

:class:`TopologyConfig` is the typed, JSON-serializable selector that
rides on the `repro.core.api` engine configs (name into
:data:`TOPOLOGIES` + per-field overrides).

Units: bandwidths bytes/s, latencies SIMULATED seconds (see
`repro.core.fabric` for the sim-vs-wall discipline), sizes bytes.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field, replace
from typing import Any, Dict, Mapping, Optional, Tuple, Union


@dataclass(frozen=True)
class LinkTier:
    """One class of links in the machine.

    ``bw``/``latency`` of ``None`` inherit the fabric's calibrated
    ``link_bw``/``link_latency`` at planning time (how :data:`FLAT` stays
    calibration-agnostic). ``bisection_cap`` is the AGGREGATE bytes/s the
    tier's cut sustains: when ``concurrent`` transfers would exceed it,
    they share the cap instead of each getting a full link.

    ``scale`` is the fault-injection degradation multiplier (see
    `repro.core.faults`): the tier delivers ``scale`` times its healthy
    bandwidth (and bisection cap). Healthy tiers carry the default 1.0 and
    the planner skips the multiplication entirely, so zero-fault plans are
    bit-exact with the pre-fault model."""
    name: str
    bw: Optional[float] = None           # bytes/s per link (None: inherit)
    latency: Optional[float] = None      # s per message (None: inherit)
    bisection_cap: Optional[float] = None  # aggregate bytes/s across the cut
    scale: float = 1.0                   # degradation multiplier in (0, 1]

    def __post_init__(self) -> None:
        if not 0.0 <= self.scale <= 1.0:
            raise ValueError(
                f"tier scale must be in [0, 1], got {self.scale}")


@dataclass(frozen=True)
class Topology:
    """A two-level machine: hosts grouped into racks (pods/midplanes).

    ``hosts_per_rack <= 0`` (or ``inter is None``) means every host sits
    in ONE rack — the flat machine. ``pinned_algorithms`` maps a
    collective op name (``"broadcast"``/``"allgather"``/``"scatter"``) to
    a fixed algorithm, bypassing cost-model selection — :data:`FLAT` pins
    the legacy ring algorithms so it stays a numeric regression anchor.
    ``seg_bytes`` is the pipeline segment used by ring broadcasts."""
    name: str
    hosts_per_rack: int = 0
    intra: LinkTier = field(default_factory=lambda: LinkTier("link"))
    inter: Optional[LinkTier] = None
    seg_bytes: int = 1 << 20
    pinned_algorithms: Mapping[str, str] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.seg_bytes <= 0:
            raise ValueError(
                f"seg_bytes must be a positive pipeline segment size in "
                f"bytes, got {self.seg_bytes}")
        # freeze the mapping so canned instances are safely shareable
        object.__setattr__(self, "pinned_algorithms",
                           dict(self.pinned_algorithms))

    @property
    def is_flat(self) -> bool:
        """True when the machine has a single tier (one rack)."""
        return self.inter is None or self.hosts_per_rack <= 0

    def racks(self, n_hosts: int) -> Tuple[int, int]:
        """``(n_racks, max_rack_hosts)`` for a job spanning `n_hosts`.

        The flat machine is one rack of everything; otherwise hosts fill
        racks in order (rack-major placement), the last rack possibly
        short. ``max_rack_hosts`` is what parallel intra-rack phases are
        charged for (the fullest rack dominates)."""
        if n_hosts <= 0:
            return 0, 0
        if self.is_flat or n_hosts <= self.hosts_per_rack:
            return 1, n_hosts
        h = self.hosts_per_rack
        return -(-n_hosts // h), h

    @property
    def ingest_tier(self) -> LinkTier:
        """The tier an off-machine point-to-point hop (detector NIC ->
        leader host) crosses: the outermost tier present."""
        return self.inter if self.inter is not None else self.intra

    def tier_names(self) -> Tuple[str, ...]:
        if self.inter is None:
            return (self.intra.name,)
        return (self.intra.name, self.inter.name)

    def degraded(self, factors: Mapping[str, float]) -> "Topology":
        """A copy with the named tiers' ``scale`` multiplied by `factors`
        (fault-injection brownouts; see `repro.core.faults.FaultSchedule.
        tier_factors`). Unknown tier names are ignored; an empty mapping
        returns ``self`` unchanged so the healthy path shares the canned
        instance."""
        if not factors:
            return self
        intra, inter = self.intra, self.inter
        if intra.name in factors:
            intra = replace(intra, scale=intra.scale * factors[intra.name])
        if inter is not None and inter.name in factors:
            inter = replace(inter, scale=inter.scale * factors[inter.name])
        if intra is self.intra and inter is self.inter:
            return self
        return replace(self, intra=intra, inter=inter)


# -- canned machines ---------------------------------------------------------

#: Backward-compat anchor: one tier inheriting the fabric link constants,
#: legacy ring algorithms pinned — numerically identical to the
#: pre-topology ``Interconnect`` accounting on every calibration.
FLAT = Topology(
    name="flat",
    pinned_algorithms={"broadcast": "pipelined_ring", "allgather": "ring",
                       "scatter": "binomial"},
)

#: Blue Gene/Q flavored 5D torus: 512-node midplanes on torus links,
#: optical inter-midplane links (higher latency, capped bisection).
BGQ_TORUS = Topology(
    name="bgq_torus",
    hosts_per_rack=512,
    intra=LinkTier("torus", bw=2e9, latency=2.5e-6),
    inter=LinkTier("optical", bw=2e9, latency=6e-6, bisection_cap=64e9),
)

#: TPU-pod flavored: 64-host ICI slices, DCN between slices.
TPU_POD_ICI_DCN = Topology(
    name="tpu_pod_ici_dcn",
    hosts_per_rack=64,
    intra=LinkTier("ici", bw=50e9, latency=1e-6),
    inter=LinkTier("dcn", bw=12.5e9, latency=1e-5, bisection_cap=400e9),
)

#: Cross-facility beamline: the detector lives OUTSIDE the machine, across
#: a wide-area tier (Welborn et al.'s detector -> Perlmutter push). The
#: whole compute pod sits in one 4096-host "rack" on cluster links, so any
#: job P <= 4096 collapses to a single rack — every delivery collective
#: (scatter/broadcast fan-out) stays on the fast ``cluster`` tier — while
#: the off-machine ingest hop (:attr:`Topology.ingest_tier`) crosses the
#: ``wan`` tier: ~10 Gb/s, 25 ms RTT-class latency, bisection-capped at
#: the link rate (one far-away pipe, not a fat fabric). WAN weather
#: (seeded jitter, brownouts) rides `repro.core.faults.FaultSchedule.
#: wan_jitter` windows scaling this tier (`repro.core.wan`).
WAN_BEAMLINE = Topology(
    name="wan_beamline",
    hosts_per_rack=4096,
    intra=LinkTier("cluster", bw=2e9, latency=2.5e-6),
    inter=LinkTier("wan", bw=1.25e9, latency=25e-3, bisection_cap=1.25e9),
)

#: Name -> canned :class:`Topology` — what :class:`TopologyConfig`
#: resolves against. Custom machines register here once.
TOPOLOGIES: Dict[str, Topology] = {
    t.name: t for t in (FLAT, BGQ_TORUS, TPU_POD_ICI_DCN, WAN_BEAMLINE)
}


@dataclass(frozen=True)
class TopologyConfig:
    """Typed, JSON-serializable topology selector for engine configs.

    ``name`` picks a canned machine from :data:`TOPOLOGIES`;
    ``hosts_per_rack``/``seg_bytes`` optionally override it (e.g. model a
    half-populated midplane without defining a new machine). Rides the
    `repro.core.api` engine configs and round-trips through spec JSON."""
    name: str = "flat"
    hosts_per_rack: Optional[int] = None
    seg_bytes: Optional[int] = None

    def __post_init__(self) -> None:
        if self.name not in TOPOLOGIES:
            raise ValueError(
                f"unknown topology {self.name!r}; available: "
                f"{', '.join(sorted(TOPOLOGIES))}")
        if self.hosts_per_rack is not None and self.hosts_per_rack <= 0:
            raise ValueError(
                f"hosts_per_rack override must be positive, got "
                f"{self.hosts_per_rack}")
        if self.seg_bytes is not None and self.seg_bytes <= 0:
            raise ValueError(
                f"seg_bytes override must be positive, got {self.seg_bytes}")

    def resolve(self) -> Topology:
        """The concrete :class:`Topology` this config selects."""
        topo = TOPOLOGIES[self.name]
        overrides = {}
        if self.hosts_per_rack is not None:
            overrides["hosts_per_rack"] = self.hosts_per_rack
        if self.seg_bytes is not None:
            overrides["seg_bytes"] = self.seg_bytes
        return replace(topo, **overrides) if overrides else topo

    def to_dict(self) -> Dict[str, Any]:
        """Primitive dict for JSON round-trips (drops None overrides)."""
        return {k: v for k, v in asdict(self).items() if v is not None}

    @classmethod
    def coerce(cls, value: "TopologyLike") -> "TopologyConfig":
        """Normalize a loose topology spelling to a config: a config
        passes through; a name string or a JSON dict builds one; a canned
        :class:`Topology` is matched back to its registered name."""
        if isinstance(value, TopologyConfig):
            return value
        if isinstance(value, str):
            return cls(name=value)
        if isinstance(value, Mapping):
            return cls(**value)
        if isinstance(value, Topology):
            reg = TOPOLOGIES.get(value.name)
            if reg is not None:
                overrides = {}
                if value.hosts_per_rack != reg.hosts_per_rack:
                    overrides["hosts_per_rack"] = value.hosts_per_rack
                if value.seg_bytes != reg.seg_bytes:
                    overrides["seg_bytes"] = value.seg_bytes
                if replace(reg, **overrides) == value:
                    # the instance is the registered machine, possibly
                    # with overrides a config can carry — keep them
                    return cls(name=value.name, **overrides)
            raise ValueError(
                f"topology {value.name!r} is not the registered instance "
                f"(or differs in fields a TopologyConfig cannot carry — "
                f"tiers, pinned algorithms); register it in TOPOLOGIES to "
                f"reference it from a TopologyConfig, or bind it to the "
                f"fabric directly (Fabric(..., topology=<Topology>))")
        raise TypeError(
            f"cannot coerce {type(value).__name__} to a TopologyConfig "
            f"(expected a TopologyConfig, a topology name, a dict, or a "
            f"registered Topology)")


TopologyLike = Union[Topology, TopologyConfig, str, Mapping, None]


def resolve_topology(value: TopologyLike) -> Topology:
    """Any loose topology spelling -> a concrete :class:`Topology`
    (``None`` means :data:`FLAT`, the backward-compat default)."""
    if value is None:
        return FLAT
    if isinstance(value, Topology):
        return value
    return TopologyConfig.coerce(value).resolve()

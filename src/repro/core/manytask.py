"""Many-task execution engine — the Swift/T + ADLB analogue (paper §III).

Event-driven simulator with real payload execution (optional): tasks carry
either a declared duration (for makespan studies matching Figs. 12/13) or a
Python callable (for real JAX work; wall time is measured and used as the
duration). Features mirroring the production requirements:

  * dynamic load balancing via work stealing (ADLB),
  * data-locality-aware dispatch (prefer hosts whose node-local store holds
    the task's inputs — "send work to data", §III),
  * straggler mitigation: speculative backup tasks after a median-based
    deadline (first completion wins),
  * fault tolerance: worker failure -> heartbeat-detected re-queue + retry,
  * per-task I/O accounting against the node-local cache (staged inputs hit
    the cache; unstaged inputs fall back to shared-FS reads),
  * frame futures (``Task.not_before``): a task keyed to a streamed
    detector frame becomes eligible the moment the frame lands on the
    node-local stores (its ``FrameRecord.t_avail``), not when the whole
    dataset closes — the scheduling half of `repro.core.streaming`.
"""
from __future__ import annotations

import heapq
import math
import random
import time as _time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.fabric import Fabric


@dataclass
class Task:
    task_id: int
    duration: Optional[float] = None          # simulated seconds
    fn: Optional[Callable[[], Any]] = None    # real payload (measured)
    inputs: Tuple[str, ...] = ()              # file deps (node-local or FS)
    deps: Tuple[int, ...] = ()                # task-id dependencies
    not_before: float = 0.0                   # earliest eligibility (sim s):
    #   a frame future — set to FrameRecord.t_avail so the task becomes
    #   runnable the moment its frame lands, not when the dataset closes
    session: Optional[str] = None             # analysis-session tenant tag
    #   (AnalysisSession.tag); per-session accounting lands in
    #   EngineStats.sessions
    priority: int = 0                         # QoS class: higher dispatches
    #   first among queued-and-eligible tasks (ties keep FIFO order, so
    #   all-default workloads schedule exactly as before)
    retries: int = 0
    result: Any = None


@dataclass
class TaskEvent:
    task_id: int
    worker: int
    start: float
    end: float
    kind: str = "run"          # run | backup | retry


@dataclass
class SessionStats:
    """Per-analysis-session slice of an engine run (multi-tenant view)."""
    tasks: int = 0
    input_read_time: float = 0.0      # simulated input time, this session
    busy_time: float = 0.0            # sum of event durations
    makespan: float = 0.0             # last completion of a session task


@dataclass
class EngineStats:
    makespan: float = 0.0
    events: List[TaskEvent] = field(default_factory=list)
    steals: int = 0
    backups_launched: int = 0
    backups_won: int = 0
    failures_recovered: int = 0
    input_read_time: float = 0.0      # total simulated input time
    cache_hits: int = 0
    cache_misses: int = 0
    sessions: Dict[str, SessionStats] = field(default_factory=dict)

    def cpu_seconds(self) -> float:
        return sum(e.end - e.start for e in self.events)

    def session(self, session_id: str) -> SessionStats:
        return self.sessions.setdefault(session_id, SessionStats())


class ManyTaskEngine:
    """ADLB-style scheduler over `n_workers` ranks spread across fabric hosts.

    Workers pull from a shared queue (ADLB server analogue). Locality: tasks
    whose inputs are resident on a host's node-local store are preferentially
    matched to that host's workers.
    """

    def __init__(self, fabric: Fabric, n_workers: Optional[int] = None,
                 seed: int = 0, straggler_factor: float = 0.0,
                 backup_threshold: float = 2.0,
                 failure_times: Optional[Dict[int, float]] = None,
                 heartbeat: float = 1.0):
        self.fabric = fabric
        self.n_workers = n_workers or fabric.n_ranks
        self.rng = random.Random(seed)
        self.straggler_factor = straggler_factor   # prob a run is straggling
        self.backup_threshold = backup_threshold   # x p95 before backup
        self.failure_times = failure_times or {}   # worker -> failure time
        self.heartbeat = heartbeat

    def host_of(self, worker: int) -> int:
        per = max(1, self.n_workers // self.fabric.n_hosts)
        return min(worker // per, self.fabric.n_hosts - 1)

    # ------------------------------------------------------------------
    def _input_time(self, task: Task, worker: int, stats: EngineStats
                    ) -> float:
        """Simulated time to acquire inputs: node-local hit is RAM-speed;
        miss falls back to an uncoordinated shared-FS read."""
        host = self.fabric.hosts[self.host_of(worker)]
        t = 0.0
        for path in task.inputs:
            data = host.store.read(path)
            if data is not None:
                stats.cache_hits += 1
                t += data.size / self.fabric.constants.local_read_bw
            else:
                stats.cache_misses += 1
                if path not in self.fabric.fs.files:
                    # streamed frames never touch the shared FS: once the
                    # sliding window evicts one, there is nowhere to
                    # re-fetch it from — fail loudly, not with a KeyError
                    raise RuntimeError(
                        f"task {task.task_id} input {path!r} is neither "
                        f"node-local nor on the shared FS (streamed frame "
                        f"evicted before use? pin it or enlarge the "
                        f"stream window)")
                size = self.fabric.fs.size(path)
                _, t_done = self.fabric.fs.read(path, 0, size, 0.0,
                                                coordinated=False)
                t += self.fabric.constants.fs_op_latency + \
                    size / self.fabric.constants.fs_rand_bw
        return t

    def _duration(self, task: Task) -> float:
        """Run the payload (if any) and return the charged duration:
        declared duration wins; otherwise measured wall time."""
        measured = None
        if task.fn is not None:
            t0 = _time.perf_counter()
            task.result = task.fn()
            measured = _time.perf_counter() - t0
        if task.duration is None:
            return measured or 0.0
        d = float(task.duration)
        if self.straggler_factor and self.rng.random() < self.straggler_factor:
            d *= self.rng.uniform(3.0, 8.0)       # pathological slowdown
        return d

    # ------------------------------------------------------------------
    def run(self, tasks: Sequence[Task]) -> EngineStats:
        stats = EngineStats()
        tasks = list(tasks)
        by_id = {t.task_id: t for t in tasks}
        remaining_deps = {t.task_id: set(t.deps) for t in tasks}
        dependents: Dict[int, List[int]] = {}
        for t in tasks:
            for d in t.deps:
                dependents.setdefault(d, []).append(t.task_id)

        queue: List[int] = []                      # shared ADLB queue
        done: set = set()
        running: Dict[int, Tuple[int, float, float, str]] = {}  # tid -> (worker,s,e,kind)
        backups: Dict[int, int] = {}               # original tid -> backup worker
        dead: set = set()
        durations_seen: List[float] = []

        # event heap: (time, seq, kind, payload)
        seq = 0
        heap: List[Tuple[float, int, str, Any]] = []
        idle: List[int] = list(range(self.n_workers))
        now = 0.0

        for w, ft in self.failure_times.items():
            heapq.heappush(heap, (ft, seq, "fail", w)); seq += 1

        def schedule(tid: int, t_now: float, front: bool = False):
            """Enqueue a dep-free task, honoring its frame future: a task
            whose `not_before` is still ahead waits on a release event."""
            nonlocal seq
            nb = by_id[tid].not_before
            if nb > t_now:
                heapq.heappush(heap, (nb, seq, "release", tid)); seq += 1
            elif front:
                queue.insert(0, tid)
            else:
                queue.append(tid)

        for tid in sorted(t.task_id for t in tasks if not t.deps):
            schedule(tid, 0.0)

        # priority dispatch costs a queue scan per pop; skip it entirely
        # for all-default workloads (100k-task campaigns stay O(1)-pop)
        prioritized = any(t.priority != 0 for t in tasks)

        def dispatch(t_now: float):
            nonlocal seq
            while queue and idle:
                # stable first-max pop: highest Task.priority wins, FIFO
                # among equals — an all-default queue pops the head
                best = 0
                if prioritized:
                    for i in range(1, len(queue)):
                        if (by_id[queue[i]].priority
                                > by_id[queue[best]].priority):
                            best = i
                tid = queue.pop(best)
                if tid in done or tid in running:
                    continue
                task = by_id[tid]
                # locality-aware worker choice
                widx = None
                if task.inputs:
                    for i, w in enumerate(idle):
                        host = self.fabric.hosts[self.host_of(w)]
                        if all(p in host.store.data for p in task.inputs):
                            widx = i
                            break
                if widx is None:
                    widx = 0
                else:
                    stats.steals += 0   # locality match, not a steal
                w = idle.pop(widx)
                if w in dead:
                    continue
                t_in = self._input_time(task, w, stats)
                stats.input_read_time += t_in
                if task.session:
                    stats.session(task.session).input_read_time += t_in
                dur = self._duration(task)
                durations_seen.append(dur)
                start, end = t_now, t_now + t_in + dur
                running[tid] = (w, start, end, "run")
                heapq.heappush(heap, (end, seq, "done", (tid, w, start, "run")))
                seq += 1
                # straggler watchdog: a run exceeding backup_threshold x
                # median-duration gets a speculative backup (median is robust
                # to the stragglers themselves, unlike upper quantiles)
                if self.backup_threshold and len(durations_seen) >= 8:
                    d_sorted = sorted(durations_seen)
                    p50 = d_sorted[len(d_sorted) // 2]
                    deadline = t_now + t_in + self.backup_threshold * p50
                    if deadline < end:
                        heapq.heappush(heap, (deadline, seq, "check", tid))
                        seq += 1

        dispatch(now)
        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == "fail":
                w = payload
                dead.add(w)
                if w in idle:
                    idle.remove(w)
                # re-queue this worker's running tasks after heartbeat detect
                for tid, (tw, s, e, k) in list(running.items()):
                    if tw == w:
                        del running[tid]
                        by_id[tid].retries += 1
                        stats.failures_recovered += 1
                        heapq.heappush(heap, (now + self.heartbeat, seq,
                                              "requeue", tid)); seq += 1
            elif kind == "requeue":
                tid = payload
                if tid not in done:
                    schedule(tid, now, front=True)
                dispatch(now)
            elif kind == "release":
                tid = payload
                if tid not in done and tid not in running \
                        and tid not in queue:
                    queue.append(tid)
                dispatch(now)
            elif kind == "check":
                tid = payload
                if tid in running and tid not in backups:
                    if idle:
                        # speculative backup (first completion wins)
                        w = idle.pop(0)
                        task = by_id[tid]
                        t_in = self._input_time(task, w, stats)
                        dur = float(task.duration or 0.0)  # nominal draw
                        backups[tid] = w
                        stats.backups_launched += 1
                        heapq.heappush(heap, (now + t_in + dur, seq, "done",
                                              (tid, w, now, "backup")))
                        seq += 1
                    else:
                        # all workers busy: re-check once capacity frees up
                        d = durations_seen[-1] if durations_seen else 1.0
                        heapq.heappush(heap, (now + max(d * 0.5, 1e-3), seq,
                                              "check", tid))
                        seq += 1
            elif kind == "done":
                tid, w, start, runkind = payload
                if w in dead:
                    continue
                if tid in done:
                    idle.append(w)          # losing duplicate
                    dispatch(now)
                    continue
                done.add(tid)
                if runkind == "backup":
                    stats.backups_won += 1
                    # release the straggling primary's worker notionally
                    if tid in running:
                        pw = running.pop(tid)[0]
                        if pw not in dead:
                            idle.append(pw)
                else:
                    running.pop(tid, None)
                stats.events.append(TaskEvent(tid, w, start, now, runkind))
                if by_id[tid].session:
                    s = stats.session(by_id[tid].session)
                    s.tasks += 1
                    s.busy_time += now - start
                    s.makespan = max(s.makespan, now)
                idle.append(w)
                for dep in dependents.get(tid, ()):  # release dependents
                    remaining_deps[dep].discard(tid)
                    if not remaining_deps[dep] and dep not in done:
                        schedule(dep, now)
                dispatch(now)
        stats.makespan = max((e.end for e in stats.events), default=0.0)
        missing = set(by_id) - done
        if missing:
            raise RuntimeError(f"tasks never completed: {sorted(missing)[:5]}")
        return stats

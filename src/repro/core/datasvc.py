"""Multi-tenant dataset catalog + staging service (long-lived residency).

The paper's interactivity claim rests on data being "staged into and
cached in compute node memory for EXTENDED PERIODS, during which time
VARIOUS PROCESSING TASKS may efficiently access it" — i.e. on a
long-lived *service* managing resident datasets, not on any single
one-shot transfer (the same lesson the streaming follow-ons draw:
Welborn et al., Perlmutter detector streaming; Poeschel et al.,
openPMD/ADIOS2 pipelines). The one-shot engines live in
`repro.core.staging`; this module is the service above them:

  * :class:`DataCatalog` — per-dataset lifecycle bookkeeping
    (``REGISTERED -> STAGING -> RESIDENT -> EVICTING -> GONE``, with
    ``GONE -> STAGING`` on transparent re-stage), lease counts held by
    concurrent analysis sessions, stage/coalesce/hit counters, and a
    transition history for every dataset.
  * :class:`StagingService` — admission control over a global per-node
    memory budget: requests for the same dataset COALESCE (two sessions
    asking for one dataset share one collective stage), unleased
    residents evict cheapest-to-restage-first under pressure, admissions
    QUEUE on future lease releases when nothing is evictable yet, and
    evicted datasets re-stage transparently on the next acquire. Staged
    files are lease-pinned in every node-local store (refcounted —
    `repro.core.fabric.NodeLocalStore.pin`), so a dataset leased by any
    session can never be evicted under it.
  * write-back — the missing output path: session results become dirty
    node-local replicas (:meth:`StagingService.put_result`) and are
    flushed to the shared FS with the collective
    :func:`repro.core.staging.stage_out` (disjoint 1/P stripe writes via
    ``SharedFilesystem.write_gather``; the naive every-host-writes
    baseline is kept for comparison).
  * :class:`AnalysisSession` — a tenant handle: leases, result writes,
    and session-tagged `repro.core.manytask` tasks (``Task.session``).

Driving model: like the rest of the simulator, the service is driven by
callers passing explicit SIMULATED times ``t`` (seconds); it keeps no
clock of its own. Interleave calls from several sessions in any program
order — causality is carried by the time arguments, so a session
acquiring at a ``t`` inside another session's in-flight stage window
joins that stage (coalescing), and a release recorded with a future
timestamp is what a queued admission waits on. Replicas move REAL bytes
(zero-copy read-only views, byte-exact); see `repro.core.fabric` for the
sim-vs-wall time discipline.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import staging as _staging
from repro.core.api import ENGINES
from repro.core.compression import CompressionLike, resolve_codec
from repro.core.fabric import Fabric, FaultEvent, FaultKind, Host
from repro.core.staging import (LostStripesError, ReplicaLossError,
                                ReplicaPlacement, StagingReport,
                                _coll_overhead, readonly_view, stage_out,
                                stage_out_naive)


class DatasetState(enum.Enum):
    """Dataset lifecycle. Legal transitions::

        REGISTERED -> STAGING -> RESIDENT -> EVICTING -> GONE -> STAGING
                                    |  ^
                                    v  | (repair: re_replicate)
                                 DEGRADED -> STAGING   (no live copy left)
                                    |
                                    v
                                 EVICTING              (give up residency)

    DEGRADED means residency LOST REDUNDANCY (a holder died, a grown host
    lacks its replica) but live leases keep working off the surviving
    replicas — it is not an error state, it is a repair-pending state.
    """
    REGISTERED = "registered"
    STAGING = "staging"
    RESIDENT = "resident"
    DEGRADED = "degraded"
    EVICTING = "evicting"
    GONE = "gone"


_LEGAL = {
    DatasetState.REGISTERED: {DatasetState.STAGING},
    DatasetState.STAGING: {DatasetState.RESIDENT},
    DatasetState.RESIDENT: {DatasetState.EVICTING, DatasetState.DEGRADED},
    DatasetState.DEGRADED: {DatasetState.RESIDENT, DatasetState.STAGING,
                            DatasetState.EVICTING},
    DatasetState.EVICTING: {DatasetState.GONE},
    DatasetState.GONE: {DatasetState.STAGING},
}


@dataclass
class Lease:
    """One session's hold on one resident dataset.

    ``t_request`` is when the session asked (simulated s); ``t_ready``
    when the replicas are usable on every node-local store — equal to
    ``t_request`` for a residency hit, later for a (joined) stage."""
    session_id: str
    dataset: str
    t_request: float
    t_ready: float


@dataclass
class DatasetEntry:
    """Catalog record for one dataset (a named set of shared-FS files)."""
    name: str
    paths: List[str]
    nbytes: int                      # total dataset bytes (per-node cost)
    state: DatasetState = DatasetState.REGISTERED
    t_ready: float = 0.0             # completion of the in-flight/last stage
    t_unleased: float = 0.0          # when the lease count last hit zero
    leases: Dict[str, int] = field(default_factory=dict)   # session -> holds
    stage_count: int = 0             # completed stagings (= residencies)
    acquires: int = 0
    hits: int = 0                    # served from residency
    coalesced: int = 0               # joined an in-flight stage
    repairs: int = 0                 # re_replicate operations on this entry
    # which hosts currently hold this dataset's replicas/stripes (full
    # replication: every host written at stage time; striped: the stripe
    # owners). Host death discards the victim; repair restores coverage.
    holders: Set[int] = field(default_factory=set)
    # striped R-way placement (stage_replicated engine); None = fully
    # replicated on every holder
    placement: Optional[ReplicaPlacement] = None
    last_report: Optional[StagingReport] = None
    history: List[Tuple[float, DatasetState]] = field(default_factory=list)

    def to_state(self, state: DatasetState, t: float) -> None:
        if state not in _LEGAL[self.state]:
            raise RuntimeError(f"illegal dataset transition "
                               f"{self.state.value} -> {state.value} "
                               f"({self.name!r} at t={t:.3f})")
        self.state = state
        self.history.append((t, state))

    @property
    def lease_count(self) -> int:
        return sum(self.leases.values())

    def state_at(self, t: float) -> DatasetState:
        """The state as observed at simulated time `t`: a dataset whose
        stage completes at ``t_ready > t`` is still STAGING then."""
        if self.state is DatasetState.RESIDENT and t < self.t_ready:
            return DatasetState.STAGING
        return self.state


class DataCatalog:
    """Name -> :class:`DatasetEntry` bookkeeping (no I/O of its own)."""

    def __init__(self) -> None:
        self._entries: Dict[str, DatasetEntry] = {}

    def add(self, entry: DatasetEntry) -> DatasetEntry:
        if entry.name in self._entries:
            raise ValueError(f"dataset {entry.name!r} already registered")
        self._entries[entry.name] = entry
        return entry

    def __getitem__(self, name: str) -> DatasetEntry:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(
                f"unknown dataset {name!r}; registered: "
                f"{sorted(self._entries)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self):
        return iter(self._entries.values())

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def resident_bytes(self) -> int:
        """Bytes counted against the node budget: STAGING + RESIDENT +
        DEGRADED (a degraded dataset still occupies its surviving
        replicas' memory — losing redundancy does not free the budget)."""
        return sum(e.nbytes for e in self._entries.values()
                   if e.state in (DatasetState.STAGING,
                                  DatasetState.RESIDENT,
                                  DatasetState.DEGRADED))

    def states(self) -> Dict[str, str]:
        return {n: e.state.value for n, e in self._entries.items()}


@dataclass
class ServiceStats:
    """Service-wide accounting (all times simulated seconds)."""
    stages: int = 0              # collective stage operations actually run
    restages: int = 0            # of those, re-stages of evicted datasets
    coalesced: int = 0           # acquires that joined an in-flight stage
    hits: int = 0                # acquires served from residency
    evictions: int = 0
    queue_waits: int = 0         # admissions that waited on a lease release
    queue_wait_time: float = 0.0
    host_deaths: int = 0         # death events the catalog absorbed
    recoveries: int = 0          # recovery events absorbed
    degraded_events: int = 0     # RESIDENT -> DEGRADED transitions
    repairs: int = 0             # re_replicate operations (not re-stages)
    repaired_bytes: int = 0      # bytes moved by repair collectives
    repair_time: float = 0.0     # total repair collective time
    resizes: int = 0             # elastic grow/shrink operations
    stage_time: float = 0.0      # total stage engine time
    metadata_time: float = 0.0   # registration glob phase
    broadcast_time: float = 0.0  # registration manifest broadcasts (on_root)
    writeback_reports: List[StagingReport] = field(default_factory=list)

    @property
    def writeback_time(self) -> float:
        return sum(r.total_time for r in self.writeback_reports)


def predict_stage_time(fabric: Fabric, nbytes: int, n_files: int,
                       t: Optional[float] = None,
                       codec: CompressionLike = None) -> float:
    """Predicted simulated seconds to collectively stage a dataset of
    `nbytes` across `n_files` files — the eviction cost model (mirrors
    the ``stage_collective`` formula on an idle fabric, without touching
    any traffic counters). The replication phase is PLANNED through the
    fabric topology's `repro.core.collectives` planner (pure cost query),
    so the prediction tracks whatever collective algorithm the fabric's
    machine model would actually pick.

    `t` is the simulated issue time the prediction is FOR: under a
    non-trivial fault schedule the comm phase is planned over the hosts
    live at `t` with that moment's degraded tier bandwidths — the
    candidate's CURRENT timeline state, which is what an eviction
    ranking at `t` must compare. ``t=None`` (or a trivial schedule)
    prices the healthy fabric, bit-exact with the pre-fault formula.

    `codec` (any `repro.core.compression` spelling) prices the comm
    phase under the service engine's compression config: the planner
    runs the same per-tier compress-at-source election a real stage
    would, so eviction rankings stay truthful when staging ships
    compressed. ``None`` predicts the raw wire, bit-exact."""
    c = fabric.constants
    P = fabric.n_hosts
    active = resolve_codec(codec)
    t_read = (nbytes / c.fs_seq_bw + n_files * _coll_overhead(fabric)
              + c.fs_op_latency)
    stripe = max(1, (nbytes + P - 1) // P)
    if t is None or fabric.faults.trivial:
        t_comm = fabric.net.planner.plan_allgather(stripe, P,
                                                   codec=active).time
    else:
        planner, dead = fabric.net._fault_state(t, P)
        t_comm = planner.plan_allgather(stripe, P - dead, dead=dead,
                                        codec=active).time
    return t_read + t_comm + nbytes / c.local_bw


class StagingService:
    """Long-lived staging service over one :class:`~repro.core.fabric.Fabric`.

    ``budget_bytes`` bounds the PER-NODE memory the catalog may hold
    resident (every staged dataset is fully replicated on every node, so
    per-node and aggregate-fraction budgets coincide). The staging engine
    used for every stage comes from the `repro.core.api.ENGINES`
    registry: pass either a typed config via ``engine=`` (e.g.
    ``PipelinedConfig(chunk_bytes=...)``) or the legacy ``mode`` name
    ("collective"/"pipelined"/"naive") plus ``stage_kw`` keywords.

    Dirty write-back replicas (:meth:`put_result`) are small reduced
    results (the paper's 8 MB frame -> ~1 MB binary) and are tracked
    outside the dataset budget; :meth:`flush` frees them.
    """

    def __init__(self, fabric: Fabric, budget_bytes: int,
                 mode: str = "collective",
                 stage_kw: Optional[Dict] = None,
                 engine=None, registry=None):
        reg = registry if registry is not None else ENGINES
        if engine is not None:
            if mode != "collective" or stage_kw is not None:
                raise ValueError(
                    "pass either engine= (a typed config) or the legacy "
                    "mode=/stage_kw= arguments, not both — the loose "
                    "keywords would be silently discarded")
            entry = reg.entry_for(engine)
            # re-resolve with the batch constraint: a registered non-batch
            # engine (e.g. stream) gets the "not batch-capable" message,
            # not a misleading "unknown mode"
            entry = reg.entry(entry.name, batch_only=True)
            self._stage_fn = entry.stage_fn
            self._stage_kw = engine.to_kw()
        else:
            config = reg.config_for(mode, batch_only=True,
                                    **(stage_kw or {}))
            self._stage_fn = reg.stage_fn(mode)
            self._stage_kw = config.to_kw()
        # the engine's staging codec (None = raw), fed to every
        # predict_stage_time eviction ranking so the cost model prices
        # the wire the engine would actually use
        self._codec = resolve_codec(self._stage_kw.get("compression"))
        self.fabric = fabric
        self.budget_bytes = int(budget_bytes)
        self.catalog = DataCatalog()
        self.stats = ServiceStats()
        self._dirty: Dict[str, Dict[str, np.ndarray]] = {}  # session -> paths

    # -- registration -------------------------------------------------------
    def session(self, session_id: str) -> "AnalysisSession":
        return AnalysisSession(self, session_id)

    def register(self, name: str, patterns: Optional[Sequence[str]] = None,
                 paths: Optional[Sequence[str]] = None, t: float = 0.0
                 ) -> Tuple[DatasetEntry, float]:
        """Register dataset `name`, idempotently.

        Either `patterns` (fnmatch globs, resolved ONCE by the leader root
        and broadcast — charges metadata + broadcast time) or explicit
        `paths` (no metadata charge). Returns ``(entry, completion t)``;
        a re-registration returns the existing entry at `t` unchanged.
        """
        if name in self.catalog:
            return self.catalog[name], t
        if (patterns is None) == (paths is None):
            raise ValueError("register() needs exactly one of "
                             "patterns= or paths=")
        if patterns is not None:
            from repro.core.iohook import resolve_manifest_timed
            files, t_done, bcast = resolve_manifest_timed(
                self.fabric, patterns, t)
            self.stats.metadata_time += t_done - t - bcast
            self.stats.broadcast_time += bcast
        else:
            files, t_done = list(paths), t
        if not files:
            raise ValueError(f"dataset {name!r} resolved to no files")
        nbytes = sum(self.fabric.fs.size(p) for p in files)
        if nbytes > self.budget_bytes:
            raise ValueError(
                f"dataset {name!r} ({nbytes} B) exceeds the service "
                f"budget ({self.budget_bytes} B) and could never stage")
        entry = DatasetEntry(name=name, paths=files, nbytes=nbytes)
        entry.history.append((t_done, DatasetState.REGISTERED))
        return self.catalog.add(entry), t_done

    # -- replica key / pin bookkeeping ---------------------------------------
    def _entry_keys(self, entry: DatasetEntry, t: float
                    ) -> Iterator[Tuple[Host, str]]:
        """``(host, store key)`` pairs of `entry`'s replicas on the hosts
        LIVE at `t` (the trivial schedule yields every host — the
        pre-fault path). Full replication: every path on every host;
        striped: each stripe's key on its owners."""
        fab = self.fabric
        hosts = fab.hosts if fab.faults.trivial else fab.live_hosts(t)
        if entry.placement is None:
            for host in hosts:
                for p in entry.paths:
                    yield host, p
        else:
            live = {h.host_id for h in hosts}
            n = len(fab.hosts)
            for i, own in entry.placement.owners.items():
                for o in own:
                    if o in live and o < n:
                        for p in entry.paths:
                            yield (fab.hosts[o],
                                   ReplicaPlacement.stripe_key(p, i))

    def _pin_once(self, entry: DatasetEntry, t: float) -> None:
        for host, key in self._entry_keys(entry, t):
            host.store.pin(key)

    def _unpin_once(self, entry: DatasetEntry, t: float) -> None:
        for host, key in self._entry_keys(entry, t):
            host.store.unpin(key)

    def _drop_replicas(self, entry: DatasetEntry) -> None:
        """Drop every replica key of `entry` from every store (any pins
        go with them — `NodeLocalStore.drop` semantics)."""
        if entry.placement is None:
            keys = list(entry.paths)
        else:
            keys = [ReplicaPlacement.stripe_key(p, i)
                    for i in entry.placement.owners for p in entry.paths]
        for host in self.fabric.hosts:
            for key in keys:
                host.store.drop(key)

    def _after_stage(self, entry: DatasetEntry, rep: StagingReport,
                     t_done: float) -> None:
        """Record who holds the fresh replicas (stage engines deliver to
        every live host; the replicated engine reports its placement)."""
        entry.placement = rep.placement
        if rep.placement is not None:
            entry.holders = set(rep.placement.hosts())
        else:
            fab = self.fabric
            entry.holders = (set(range(fab.n_hosts)) if fab.faults.trivial
                             else set(fab.live_ids(t_done)))

    def _trans(self, entry: DatasetEntry, state: DatasetState,
               t: float) -> None:
        """`DatasetEntry.to_state` plus telemetry: one instant event per
        lifecycle transition, so a trace shows WHEN each dataset moved
        through REGISTERED/STAGING/RESIDENT/DEGRADED/EVICTING/GONE (the
        validation and history bookkeeping are unchanged)."""
        entry.to_state(state, t)
        tr = self.fabric.tracer
        if tr.enabled:
            tr.instant(f"dataset.{state.value}", t, track="svc",
                       dataset=entry.name)
            tr.metrics.counter(f"svc.transition.{state.value}").inc()

    # -- lease lifecycle ----------------------------------------------------
    def acquire(self, session_id: str, name: str, t: float) -> Lease:
        """Lease dataset `name` for `session_id` at simulated time `t`.

        RESIDENT at `t`  -> lease immediately (``t_ready == t``).
        STAGING at `t`   -> coalesce: join the in-flight stage, share its
                            completion time. No second stage is run.
        DEGRADED at `t`  -> repair (:meth:`re_replicate`) and lease at the
                            repair's completion — never a wedge.
        REGISTERED/GONE  -> stage (transparent re-stage on miss), possibly
                            evicting unleased datasets or queueing on a
                            future lease release first.

        The dataset's replica keys are lease-pinned in the live node-local
        stores until the matching :meth:`release`.
        """
        entry = self.catalog[name]
        entry.acquires += 1
        t_admit = t
        if entry.state is DatasetState.RESIDENT:
            if t < entry.t_ready:            # the stage is still in flight
                entry.coalesced += 1
                self.stats.coalesced += 1
                outcome = "coalesced"
            else:
                entry.hits += 1
                self.stats.hits += 1
                outcome = "hit"
            t_ready = max(t, entry.t_ready)
        elif entry.state is DatasetState.DEGRADED:
            # acquire on a degraded dataset triggers repair, not a wedge;
            # counted as a repair (neither a hit nor a stage) so the
            # fault-free invariant acquires == stages+coalesced+hits
            # extends to ... + repairs under injected failures
            _, t_ready = self.re_replicate(name, t)
            outcome = "repair"
        else:                                # REGISTERED or GONE
            restage = entry.state is DatasetState.GONE
            outcome = "restage" if restage else "stage"
            t_admit = self._admit(entry, t)
            self._trans(entry, DatasetState.STAGING, t_admit)
            rep, t_done = self._stage_fn(self.fabric, entry.paths, t_admit,
                                         **self._stage_kw)
            entry.last_report = rep
            entry.t_ready = t_done
            entry.stage_count += 1
            self._trans(entry, DatasetState.RESIDENT, t_done)
            self._after_stage(entry, rep, t_done)
            self.stats.stages += 1
            self.stats.restages += int(restage)
            self.stats.stage_time += rep.total_time
            t_ready = t_done
        entry.leases[session_id] = entry.leases.get(session_id, 0) + 1
        self._pin_once(entry, t_ready)
        tr = self.fabric.tracer
        if tr.enabled:
            # coalesced-acquire attribution: the span covers [t, t_ready),
            # i.e. the tail of the in-flight stage this request joined
            sp = tr.span("svc.acquire", t, t_ready, track="svc",
                         dataset=name, session=session_id, outcome=outcome)
            if t_admit > t:
                tr.span("svc.queue_wait", t, t_admit, track="svc",
                        parent=sp, dataset=name)
            tr.metrics.counter(f"svc.acquire.{outcome}").inc()
            tr.metrics.histogram("svc.acquire_latency_s").observe(
                t_ready - t)
        return Lease(session_id=session_id, dataset=name,
                     t_request=t, t_ready=t_ready)

    def release(self, session_id: str, name: str, t: float) -> None:
        """Return one lease on `name` at simulated time `t`. When the last
        lease goes, the dataset becomes evictable from `t` on (queued
        admissions may be waiting on exactly this moment)."""
        entry = self.catalog[name]
        held = entry.leases.get(session_id, 0)
        if not held:
            raise RuntimeError(f"session {session_id!r} holds no lease on "
                               f"dataset {name!r}")
        if held == 1:
            del entry.leases[session_id]
        else:
            entry.leases[session_id] = held - 1
        self._unpin_once(entry, t)
        if not entry.leases:
            entry.t_unleased = max(entry.t_unleased, t)

    # -- admission / eviction -----------------------------------------------
    def _evict(self, entry: DatasetEntry, t: float) -> None:
        self._trans(entry, DatasetState.EVICTING, t)
        self._drop_replicas(entry)
        self._trans(entry, DatasetState.GONE, t)  # drop: free bookkeeping
        entry.holders = set()
        entry.placement = None
        self.stats.evictions += 1

    def _admit(self, entry: DatasetEntry, t: float) -> float:
        """Admission time for staging `entry` requested at `t`: evict
        unleased residents cheapest-to-restage first; if pressure remains,
        queue on the earliest already-recorded future lease release; if no
        release can ever free enough memory, fail loudly."""
        need = entry.nbytes
        t_admit = t
        while self.catalog.resident_bytes + need > self.budget_bytes:
            free = [e for e in self.catalog
                    if e.state in (DatasetState.RESIDENT,
                                   DatasetState.DEGRADED)
                    and not e.leases]
            now = [e for e in free if e.t_unleased <= t_admit]
            if now:
                # cost-aware: cheapest to bring back if needed again,
                # priced under the timeline state AT admission time
                victim = min(now, key=lambda e: (predict_stage_time(
                    self.fabric, e.nbytes, len(e.paths), t=t_admit,
                    codec=self._codec), e.name))
                self._evict(victim, t_admit)
                continue
            future = [e for e in free if e.t_unleased > t_admit]
            if not future:
                held = {e.name: sorted(e.leases) for e in self.catalog
                        if e.state in (DatasetState.RESIDENT,
                                       DatasetState.DEGRADED) and e.leases}
                raise RuntimeError(
                    f"staging service wedged admitting {entry.name!r} "
                    f"({need} B): budget {self.budget_bytes} B holds "
                    f"{self.catalog.resident_bytes} B, all leased: {held}")
            # queued admission: wait for the earliest release, then evict
            victim = min(future, key=lambda e: (e.t_unleased, e.name))
            self.stats.queue_wait_time += victim.t_unleased - t_admit
            t_admit = victim.t_unleased
            self._evict(victim, t_admit)
        if t_admit > t:
            self.stats.queue_waits += 1
        return t_admit

    # -- fault handling / self-healing ---------------------------------------
    def sync_faults(self, t: float) -> List[FaultEvent]:
        """Advance the fabric's fault clock to `t` and absorb the events
        into the catalog: a host death discards the victim from every
        dataset's holders and degrades affected residents; a recovery
        brings a BLANK host back, degrading fully-replicated residents
        (which must cover every live host) until repaired. Returns the
        events applied. Live leases are untouched either way — they keep
        reading the surviving replicas."""
        events = self.fabric.advance_faults(t)
        for ev in events:
            if ev.kind is FaultKind.HOST_DEATH:
                self._on_host_death(ev.host, ev.t)
            elif ev.kind is FaultKind.HOST_RECOVERY:
                self._on_host_recovery(ev.host, ev.t)
        return events

    def _on_host_death(self, host: int, t: float) -> None:
        self.stats.host_deaths += 1
        for entry in self.catalog:
            if host in entry.holders:
                entry.holders.discard(host)
                if entry.state is DatasetState.RESIDENT:
                    self._trans(entry, DatasetState.DEGRADED, t)
                    self.stats.degraded_events += 1

    def _on_host_recovery(self, host: int, t: float) -> None:
        self.stats.recoveries += 1
        for entry in self.catalog:
            # full replication promises a replica on EVERY live host; the
            # recovered host came back blank, so coverage is broken until
            # repair broadcasts it a copy. Striped placements only need
            # their R owners, which the recovered host is not — they stay
            # RESIDENT.
            if (entry.state is DatasetState.RESIDENT
                    and entry.placement is None
                    and host not in entry.holders):
                self._trans(entry, DatasetState.DEGRADED, t)
                self.stats.degraded_events += 1

    def fail_host(self, host: int, t: float) -> List[FaultEvent]:
        """Inject a host death at `t` and absorb it immediately."""
        self.fabric.faults.inject(
            FaultEvent(t, FaultKind.HOST_DEATH, host=host))
        return self.sync_faults(t)

    def recover_host(self, host: int, t: float) -> List[FaultEvent]:
        """Inject a host recovery (blank store) at `t` and absorb it."""
        self.fabric.faults.inject(
            FaultEvent(t, FaultKind.HOST_RECOVERY, host=host))
        return self.sync_faults(t)

    def re_replicate(self, name: str, t: float
                     ) -> Tuple[StagingReport, float]:
        """Repair dataset `name` back to RESIDENT at simulated time `t`.

        Striped datasets copy only the LOST stripes from surviving owners
        (`repro.core.staging.re_replicate` — cost ~ lost/P of the
        dataset); fully replicated datasets broadcast complete replicas
        to the live hosts missing one (recovered-blank or grown). When no
        live copy survives at all, falls back to a full re-stage from the
        shared FS (DEGRADED -> STAGING -> RESIDENT). Live leases keep
        their pins throughout — repaired hosts are pinned up to the
        current lease count, so repair is lease-preserving.

        Returns ``(repair report, completion time)``. RESIDENT is a
        no-op; any other state is an error."""
        entry = self.catalog[name]
        if entry.state is DatasetState.RESIDENT:
            return (StagingReport(n_hosts=self.fabric.n_hosts,
                                  total_bytes=0, mode="re_replicate"),
                    max(t, entry.t_ready))
        if entry.state is not DatasetState.DEGRADED:
            raise RuntimeError(
                f"cannot repair dataset {name!r} in state "
                f"{entry.state.value} (repair applies to DEGRADED)")
        live = self.fabric.live_ids(t)
        count = entry.lease_count
        if entry.placement is not None:
            old = {i: set(own)
                   for i, own in entry.placement.owners.items()}
            try:
                rep, t_done = _staging.re_replicate(
                    self.fabric, entry.paths, entry.placement, t0=t,
                    live=live)
            except LostStripesError:
                return self._restage_degraded(entry, t)
            entry.holders = set(entry.placement.hosts())
            if count:
                # lease-preserving: freshly written owners take over the
                # dead owners' pins at the current lease depth
                for i, own in entry.placement.owners.items():
                    for o in set(own) - old[i]:
                        for p in entry.paths:
                            key = ReplicaPlacement.stripe_key(p, i)
                            for _ in range(count):
                                self.fabric.hosts[o].store.pin(key)
        else:
            alive = set(live)
            sources = sorted(entry.holders & alive)
            targets = sorted(alive - entry.holders)
            if not sources:
                return self._restage_degraded(entry, t)
            if targets:
                rep, t_done = _staging.re_replicate_full(
                    self.fabric, entry.paths, targets, t0=t,
                    sources=sources)
                if count:
                    for o in targets:
                        for p in entry.paths:
                            for _ in range(count):
                                self.fabric.hosts[o].store.pin(p)
            else:
                # every live host already holds a replica: the dead host
                # simply leaves the residency set — repaired around, no
                # bytes moved
                rep = StagingReport(n_hosts=len(live), total_bytes=0,
                                    mode="re_replicate")
                t_done = t
            entry.holders = alive
        self._trans(entry, DatasetState.RESIDENT, t_done)
        entry.t_ready = max(entry.t_ready, t_done)
        entry.repairs += 1
        self.stats.repairs += 1
        self.stats.repaired_bytes += rep.net_bytes
        self.stats.repair_time += rep.total_time
        return rep, t_done

    def _restage_degraded(self, entry: DatasetEntry, t: float
                          ) -> Tuple[StagingReport, float]:
        """No live copy survives: the only way back is the shared FS.
        The entry's bytes already count against the budget (DEGRADED
        occupies it), so no admission pass — straight to STAGING. Live
        leases are re-pinned onto the fresh replicas."""
        count = entry.lease_count
        self._drop_replicas(entry)          # stale stripes + pins go
        self._trans(entry, DatasetState.STAGING, t)
        rep, t_done = self._stage_fn(self.fabric, entry.paths, t,
                                     **self._stage_kw)
        entry.last_report = rep
        entry.t_ready = t_done
        entry.stage_count += 1
        self._trans(entry, DatasetState.RESIDENT, t_done)
        self._after_stage(entry, rep, t_done)
        self.stats.stages += 1
        self.stats.restages += 1
        self.stats.stage_time += rep.total_time
        for _ in range(count):
            self._pin_once(entry, t_done)
        return rep, t_done

    # -- elasticity ----------------------------------------------------------
    def resize(self, n_hosts: int, t: float) -> List[int]:
        """Elastically grow or shrink the campaign to `n_hosts` hosts at
        simulated time `t` (`repro.core.fabric.Fabric.resize`).

        Growing appends BLANK hosts: fully replicated residents degrade
        (the new hosts lack replicas) until repaired; striped placements
        keep their stripe geometry and stay RESIDENT. Shrinking removes
        the highest-id hosts and their replicas: striped residents that
        lose an owner degrade; fully replicated residents stay RESIDENT
        (every surviving host still holds a copy). Returns the affected
        host ids."""
        grow = n_hosts > self.fabric.n_hosts
        changed = self.fabric.resize(n_hosts)
        self.stats.resizes += 1
        if grow:
            for entry in self.catalog:
                if (entry.state is DatasetState.RESIDENT
                        and entry.placement is None):
                    self._trans(entry, DatasetState.DEGRADED, t)
                    self.stats.degraded_events += 1
        else:
            removed = set(changed)
            for entry in self.catalog:
                entry.holders -= removed
                if (entry.state is DatasetState.RESIDENT
                        and entry.placement is not None
                        and any(o in removed
                                for own in entry.placement.owners.values()
                                for o in own)):
                    self._trans(entry, DatasetState.DEGRADED, t)
                    self.stats.degraded_events += 1
        return changed

    # -- write-back ---------------------------------------------------------
    def put_result(self, session_id: str, name: str, data: np.ndarray,
                   t: float) -> Tuple[str, float]:
        """Install a session result as a DIRTY node-local replica.

        Results are produced replicated (every host ran the same reduction
        over the same staged replicas), so one shared read-only view lands
        on every node-local store, charged at ``local_bw``; the buffer is
        remembered for :meth:`flush`. Returns ``(result path, completion
        t)``. Result replicas are pinned until flushed and tracked outside
        the dataset budget (reduced outputs are small — paper §VI-A)."""
        path = f"results/{session_id}/{name}.bin"
        buf = np.ascontiguousarray(data).view(np.uint8).ravel()
        view = readonly_view(buf)
        t_done = t
        for host in self.fabric.hosts:
            t_done = max(t_done, host.store.write(path, view, t))
            host.store.pin(path)
        self._dirty.setdefault(session_id, {})[path] = buf
        return path, t_done

    def flush(self, session_id: str, t: float, collective: bool = True
              ) -> Tuple[StagingReport, float]:
        """Flush the session's dirty results to the shared FS.

        ``collective=True`` uses :func:`repro.core.staging.stage_out`
        (disjoint 1/P stripe writes, the ``MPI_File_write_all`` mirror);
        ``False`` the naive every-host-writes-everything baseline. The
        flushed node-local replicas are dropped (their memory returns to
        the nodes). Returns ``(report, completion t)``; flushing with
        nothing dirty returns an empty report at `t`."""
        outputs = self._dirty.pop(session_id, {})
        if not outputs:
            return (StagingReport(n_hosts=self.fabric.n_hosts, total_bytes=0,
                                  mode="stage_out"), t)
        fn = stage_out if collective else stage_out_naive
        rep, t_done = fn(self.fabric, outputs, t)
        for host in self.fabric.hosts:
            for path in outputs:
                host.store.drop(path)
        self.stats.writeback_reports.append(rep)
        return rep, t_done

    @property
    def dirty_bytes(self) -> int:
        return sum(b.size for bufs in self._dirty.values()
                   for b in bufs.values())


@dataclass
class AnalysisSession:
    """A tenant of the staging service: its leases, results, and tasks.

    Thin sugar over the service with the session id filled in, plus
    :meth:`tag` for session-tagged many-task work (the scheduler then
    reports per-session accounting in ``EngineStats.sessions``).

    A context manager: ``__exit__`` calls :meth:`close`, releasing every
    lease this session still holds — even when the body raised — so a
    direct ``datasvc`` user can no longer leak leases and wedge later
    admissions. The release time is caller-supplied (``close(t=...)``)
    or defaults to the last simulated time the session observed
    (floored per dataset at its ``t_ready``: a lease cannot be returned
    before its replicas exist)."""
    service: StagingService
    session_id: str
    _t_last: float = field(default=0.0, repr=False, compare=False)

    def note(self, t: float) -> float:
        """Record `t` as the latest simulated time this session observed
        (the default :meth:`close` release time). Returns `t`."""
        if t > self._t_last:
            self._t_last = t
        return t

    def acquire(self, name: str, t: float) -> Lease:
        lease = self.service.acquire(self.session_id, name, t)
        self.note(lease.t_ready)
        return lease

    def release(self, name: str, t: float) -> None:
        self.service.release(self.session_id, name, self.note(t))

    def put_result(self, name: str, data: np.ndarray, t: float
                   ) -> Tuple[str, float]:
        path, t_done = self.service.put_result(self.session_id, name, data, t)
        return path, self.note(t_done)

    def flush(self, t: float, collective: bool = True
              ) -> Tuple[StagingReport, float]:
        rep, t_done = self.service.flush(self.session_id, t,
                                         collective=collective)
        return rep, self.note(t_done)

    def tag(self, task):
        """Stamp a `repro.core.manytask.Task` with this session's id."""
        task.session = self.session_id
        return task

    def held(self) -> Dict[str, int]:
        """Dataset name -> lease count this session currently holds."""
        return {e.name: e.leases[self.session_id]
                for e in self.service.catalog
                if self.session_id in e.leases}

    def close(self, t: Optional[float] = None) -> None:
        """Release every lease this session still holds, at simulated
        time `t` (default: the last time this session observed), floored
        per dataset at its ``t_ready``. Idempotent."""
        t_close = self._t_last if t is None else self.note(t)
        for name, count in self.held().items():
            t_ds = max(t_close, self.service.catalog[name].t_ready)
            for _ in range(count):
                self.service.release(self.session_id, name, t_ds)

    def __enter__(self) -> "AnalysisSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

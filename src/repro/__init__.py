"""repro — collective data staging + many-task execution framework for TPU pods.

Reproduction and beyond-paper extension of:
  "Big Data Staging with MPI-IO for Interactive X-ray Science"
  (Wozniak, Sharma, Armstrong, Wilde, Almer, Foster).

Layers:
  repro.core         -- staging, I/O hook, leader groups, node-local cache,
                        many-task executor, dataflow futures (the paper).
  repro.models       -- pure-JAX model zoo (10 assigned architectures).
  repro.kernels      -- Pallas TPU kernels (flash attention, SSD scan, WKV6,
                        HEDM stage-1 reduction) + jnp oracles.
  repro.data         -- staged input pipeline + detector-stream simulator.
  repro.train        -- optimizer, train_step, grad compression.
  repro.serve        -- KV-cache serving, prefill/decode, continuous batching.
  repro.distributed  -- mesh + sharding rules (FSDP x TP x EP x SP).
  repro.checkpoint   -- sharded checkpoints w/ collective-staged restore.
  repro.runtime      -- fault tolerance, elastic rescale, restart driver.
  repro.hedm         -- the paper's application (NF/FF-HEDM stages).
  repro.configs      -- assigned architecture configs + shapes.
  repro.launch       -- mesh/dryrun/train/serve entry points.
"""

__version__ = "1.0.0"

"""Compression-aware tiered staging: codec crossover + WAN wire wins.

Four studies over the `repro.core.compression` codec model and the
planner's per-tier compress-at-source election:

  * **anchor** — the identity codec (``"none"``) against the plain
    uncompressed path on every staging engine family: asserted byte- and
    time-exact per run (the regression anchor; ``run.py --compression
    --quick`` re-checks it against the recorded JSON on CI);
  * **crossover sweep** — raw-vs-compressed as a function of codec
    compress throughput and tier bandwidth at P = 1024/4096/8192: each
    cell records which side the planner elected and asserts it matches
    the closed-form inequality  n/Cc + n/Cd + (n/r)/bw < n/bw;
  * **hierarchical compounding** — ``frame-fast`` on the ``bgq_torus``
    machine elects BOTH the torus and optical tiers, so the win
    compounds through the hierarchical broadcast at scale;
  * **WAN ingest headline** — ``frame-lossless`` on ``wan_beamline``
    under seeded loss: every (re)transmission ships the compressed
    frame, asserted >= 2x wire-byte reduction on the wan tier (the
    codec's 3.2x ratio, exactly, since election is all-or-nothing per
    tier).

Everything is simulated seconds over real bytes. Emits
``BENCH_compression.json`` (with an embedded telemetry metrics
snapshot) next to this file and harness CSV rows via :func:`rows`
(wired into ``benchmarks.run --compression``).

Run directly:  PYTHONPATH=src python -m benchmarks.bench_compression
"""
from __future__ import annotations

import json
import os
import sys
from dataclasses import fields, replace
from typing import List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_compression.json")

# which staging API surface this bench drives (run.py summary column)
API_PATH = "planner codec election (CollectivePlanner / stage_wan)"

N_HOSTS = 64
N_FRAMES = 48
FRAME_SIZE = 128
FRAME_BYTES = FRAME_SIZE * FRAME_SIZE * 4
RATE_HZ = 100.0
LOSS_RATE = 0.15
LOSS_SEED = 7
PAYLOAD = 8 << 20                       # crossover-sweep payload
SWEEP_P = (1024, 4096, 8192)
SWEEP_CODEC_BW = (0.5e9, 1e9, 2e9, 4e9, 8e9, 16e9)
SWEEP_TIER_BW = (1.25e9, 2e9, 12.5e9, 50e9)


def _fabric(topology=None):
    from repro.core.fabric import BGQ, Fabric
    fab = Fabric(n_hosts=N_HOSTS, constants=BGQ, topology=topology)
    rng = np.random.default_rng(7)
    paths = []
    for i in range(N_FRAMES):
        p = f"scan/frame_{i:05d}.bin"
        fab.fs.put(p, rng.integers(0, 255, FRAME_BYTES, dtype=np.uint8))
        paths.append(p)
    return fab, paths


def bench_anchor() -> dict:
    """Identity codec vs plain path on every engine family: exact."""
    from repro.core.api import (CollectiveConfig, NaiveConfig,
                                PipelinedConfig, ReplicatedConfig,
                                StagingClient, StreamConfig,
                                WanStreamConfig)
    configs = [
        CollectiveConfig(topology="wan_beamline"),
        PipelinedConfig(topology="wan_beamline"),
        NaiveConfig(topology="wan_beamline"),
        ReplicatedConfig(topology="wan_beamline", replication=2),
        StreamConfig(topology="wan_beamline", rate_hz=RATE_HZ),
        WanStreamConfig(topology="wan_beamline", rate_hz=RATE_HZ,
                        loss_rate=LOSS_RATE, loss_seed=LOSS_SEED),
    ]
    makespans = {}
    for cfg in configs:
        f1, _ = _fabric("wan_beamline")
        f2, _ = _fabric("wan_beamline")
        r1 = StagingClient(f1).stage("scan/*.bin", cfg)
        r2 = StagingClient(f2).stage("scan/*.bin",
                                     replace(cfg, compression="none"))
        exact = r1.total_time == r2.total_time and all(
            getattr(r1.reports[0], f.name) == getattr(r2.reports[0], f.name)
            for f in fields(r1.reports[0]))
        for h1, h2 in zip(f1.hosts, f2.hosts):
            exact = exact and set(h1.store.data) == set(h2.store.data) \
                and all(np.array_equal(h1.store.data[p], h2.store.data[p])
                        for p in h1.store.data)
        assert exact, (f"identity codec diverged from the uncompressed "
                       f"path on {type(cfg).__name__}")
        makespans[r1.engine] = r1.total_time
    return {
        "name": "anchor_identity_codec",
        "engines": sorted(makespans),
        "makespan_s": makespans,
        "byte_exact": True,
    }


def bench_crossover() -> List[dict]:
    """Raw-vs-compressed crossover vs codec throughput x tier bandwidth."""
    from repro.core.collectives import CollectivePlanner
    from repro.core.compression import CODECS
    from repro.core.fabric import BGQ
    from repro.core.topology import resolve_topology
    base = CODECS["frame-lossless"]
    flat = resolve_topology("flat")
    out = []
    for P in SWEEP_P:
        for tier_bw in SWEEP_TIER_BW:
            topo = replace(flat, intra=replace(flat.intra, bw=tier_bw))
            pl = CollectivePlanner(topo, BGQ)
            raw = pl.plan_broadcast(PAYLOAD, P)
            for cbw in SWEEP_CODEC_BW:
                codec = replace(base, compress_bw=cbw,
                                decompress_bw=2 * cbw)
                w = codec.compressed_size(PAYLOAD)
                expect = (PAYLOAD / cbw + PAYLOAD / (2 * cbw)
                          + w / tier_bw < PAYLOAD / tier_bw)
                plan = pl.plan_broadcast(PAYLOAD, P, codec=codec)
                elected = bool(plan.compressed_tiers)
                assert elected == expect, (
                    f"planner election diverged from the closed form at "
                    f"P={P} tier_bw={tier_bw:g} codec_bw={cbw:g}")
                out.append({
                    "n_hosts": P,
                    "tier_bw_gbs": tier_bw / 1e9,
                    "codec_bw_gbs": cbw / 1e9,
                    "compressed": elected,
                    "raw_time_s": raw.time,
                    "time_s": plan.time,
                    "wire_bytes": plan.total_bytes,
                    "payload_bytes": plan.payload_bytes,
                    "speedup": raw.time / plan.time if plan.time else 1.0,
                })
    return out


def bench_hierarchical() -> List[dict]:
    """frame-fast on bgq_torus: the win compounds across both tiers."""
    from repro.core.collectives import CollectivePlanner
    from repro.core.compression import CODECS
    from repro.core.fabric import BGQ
    from repro.core.topology import resolve_topology
    pl = CollectivePlanner(resolve_topology("bgq_torus"), BGQ)
    codec = CODECS["frame-fast"]
    out = []
    for P in SWEEP_P:
        raw = pl.plan_broadcast(PAYLOAD, P)
        cmp_ = pl.plan_broadcast(PAYLOAD, P, codec=codec)
        assert set(cmp_.compressed_tiers) == set(cmp_.tier_bytes), \
            "frame-fast must elect every bgq_torus tier it touches"
        assert cmp_.time < raw.time
        out.append({
            "name": f"hierarchical_p{P}",
            "n_hosts": P,
            "algorithm": cmp_.algorithm,
            "compressed_tiers": list(cmp_.compressed_tiers),
            "raw_time_s": raw.time,
            "compressed_time_s": cmp_.time,
            "speedup": raw.time / cmp_.time,
            "raw_wire_bytes": raw.total_bytes,
            "compressed_wire_bytes": cmp_.total_bytes,
            "bytes_saved": cmp_.bytes_saved,
        })
    return out


def bench_wan_headline() -> dict:
    """frame-lossless compress-at-source on the lossy WAN ingest tier."""
    from repro.core.api import StagingClient, WanStreamConfig
    from repro.core.telemetry import Tracer

    def run(compression, trace=False):
        fab, _ = _fabric("wan_beamline")
        client = StagingClient(fab, trace=trace)
        rep = client.stage("scan/*.bin", WanStreamConfig(
            topology="wan_beamline", rate_hz=RATE_HZ,
            loss_rate=LOSS_RATE, loss_seed=LOSS_SEED,
            compression=compression))
        return rep, fab

    raw, _ = run(None)
    cmp_, fab = run("frame-lossless", trace=True)
    rw, cw = raw.reports[0], cmp_.reports[0]
    metrics = fab.tracer.metrics.snapshot()
    ratio = rw.wan.wan_bytes / cw.wan.wan_bytes
    assert ratio >= 2.0, (
        f"the default detector-frame codec must cut WAN wire bytes "
        f">= 2x, got {ratio:.2f}x")
    assert cmp_.delivered_bytes == raw.delivered_bytes, \
        "compression must never change the delivered payload"
    assert cmp_.payload_net_bytes == raw.net_bytes, \
        "wire + saved bytes must reconcile with the raw wire"
    snap = metrics["counters"]
    return {
        "name": "wan_headline_frame_lossless",
        "metrics": metrics,
        "codec": "frame-lossless",
        "loss_rate": LOSS_RATE,
        "retransmits": cw.wan.retransmits,
        "raw_wan_bytes": rw.wan.wan_bytes,
        "compressed_wan_bytes": cw.wan.wan_bytes,
        "wan_bytes_ratio": ratio,
        "raw_makespan_s": raw.total_time,
        "compressed_makespan_s": cmp_.total_time,
        "bytes_saved": cmp_.bytes_saved,
        "codec_time_s": cmp_.comp.codec_time,
        "compression_metrics": {
            k: v for k, v in sorted(snap.items())
            if k.startswith("comp.")},
    }


def run_benchmarks() -> dict:
    from repro.core.fabric import BGQ
    report = {
        "config": {
            "calibration": BGQ.name,
            "api_path": API_PATH,
            "n_hosts": N_HOSTS, "n_frames": N_FRAMES,
            "frame_bytes": FRAME_BYTES, "rate_hz": RATE_HZ,
            "sweep_payload_bytes": PAYLOAD,
            "sweep_n_hosts": list(SWEEP_P),
            "loss_rate": LOSS_RATE, "loss_seed": LOSS_SEED,
        },
        "anchor": bench_anchor(),
        "crossover": bench_crossover(),
        "hierarchical": bench_hierarchical(),
        "wan_headline": bench_wan_headline(),
    }
    # surface the traced headline run's telemetry (comp.* counters +
    # span histograms) at the top level, the BENCH_*.json convention
    report["metrics"] = report["wan_headline"].pop("metrics")
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    return report


def quick_check() -> None:
    """CI smoke: recompute the identity-codec anchor and compare it
    against the recorded JSON, then re-assert the WAN >= 2x headline
    (no JSON rewrite)."""
    anchor = bench_anchor()
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as f:
            recorded = json.load(f)["anchor"]
        assert recorded["makespan_s"] == anchor["makespan_s"], (
            "identity-codec anchor drifted from the recorded "
            "BENCH_compression.json — staging arithmetic changed; re-run "
            "benchmarks/run.py --compression to refresh the baseline")
    headline = bench_wan_headline()
    print("bench_compression quick: identity anchor exact on "
          f"{len(anchor['engines'])} engines, WAN wire reduction "
          f"{headline['wan_bytes_ratio']:.2f}x")


def rows(report=None, quick=False) -> List[Row]:
    """Harness CSV rows (name, us_per_call, derived) for benchmarks.run.
    us_per_call carries the simulated makespan/plan time in µs.
    ``quick`` re-checks the anchor against the recorded JSON only."""
    if quick:
        quick_check()
        return [("bench_compression_anchor_quick", 0.0,
                 "identity_codec_exact=True")]
    if report is None:
        report = run_benchmarks()
    wan = report["wan_headline"]
    out: List[Row] = [
        ("bench_compression_anchor",
         report["anchor"]["makespan_s"]["wan"] * 1e6,
         "identity_codec_exact=True"),
        ("bench_compression_wan_headline",
         wan["compressed_makespan_s"] * 1e6,
         f"wan_bytes_ratio={wan['wan_bytes_ratio']:.2f}x"),
    ]
    for r in report["hierarchical"]:
        out.append((f"bench_compression_{r['name']}",
                    r["compressed_time_s"] * 1e6,
                    f"speedup={r['speedup']:.2f}x"))
    crossed = sum(1 for r in report["crossover"] if r["compressed"])
    out.append(("bench_compression_crossover_sweep", 0.0,
                f"compressed_cells={crossed}/{len(report['crossover'])}"))
    return out


def main() -> None:
    report = run_benchmarks()
    a = report["anchor"]
    print(f"{a['name']}: identity codec byte- and time-exact on "
          f"{', '.join(a['engines'])}")
    for r in report["hierarchical"]:
        print(f"{r['name']}: {r['raw_time_s'] * 1e3:.3f}ms raw -> "
              f"{r['compressed_time_s'] * 1e3:.3f}ms compressed "
              f"({r['speedup']:.2f}x, tiers {r['compressed_tiers']})")
    w = report["wan_headline"]
    print(f"{w['name']}: {w['raw_wan_bytes']} B raw -> "
          f"{w['compressed_wan_bytes']} B over the WAN "
          f"({w['wan_bytes_ratio']:.2f}x fewer wire bytes, "
          f"{w['retransmits']} retransmits resent compressed)")
    by_bw = {}
    for r in report["crossover"]:
        key = (r["tier_bw_gbs"], r["codec_bw_gbs"])
        by_bw.setdefault(key, r["compressed"])
    for (tbw, cbw), comp in sorted(by_bw.items()):
        print(f"crossover tier {tbw:5.2f} GB/s x codec {cbw:5.1f} GB/s: "
              f"{'compressed' if comp else 'raw'}")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    if "--quick" in sys.argv:
        quick_check()
    else:
        main()
